"""Labeled counter / gauge / histogram registry with JSON snapshots.

The serving stack's numeric telemetry: counters (monotone totals - tokens
emitted, requests finished), gauges (last-value samples - slot occupancy,
KV block-pool utilization) and histograms (distributions with percentile
snapshots - per-phase step timings, kernel dispatch wall time). Instruments
are memoized per ``(name, labels)`` so hot-path lookups after the first are
one dict get, and a :func:`MetricsRegistry.snapshot` serializes everything
to plain JSON (written next to the benchmark rows / ``--metrics-out``).

Like :mod:`repro.obs.trace` this is dependency-free and disabled-by-default:
:data:`NULL_METRICS` hands back shared no-op instruments (zero allocation
after the singletons exist), so un-instrumented serving pays one attribute
call per would-be observation.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional, Tuple


def metric_key(name: str, labels: Dict[str, Any]) -> str:
    """Stable flat key: ``name`` or ``name{a=1,b=x}`` (labels sorted)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone total."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-value sample."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    """Exact-value histogram (serving runs observe thousands of samples,
    not millions - storing raw values keeps percentiles exact)."""

    __slots__ = ("values", "_lock")

    def __init__(self):
        self.values: List[float] = []
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self.values.append(float(v))

    @staticmethod
    def _percentile(sorted_vals: List[float], q: float) -> float:
        """Linear-interpolated percentile (numpy's default method), on an
        already-sorted list."""
        n = len(sorted_vals)
        if n == 1:
            return sorted_vals[0]
        pos = q / 100.0 * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        frac = pos - lo
        return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac

    def summary(self) -> dict:
        with self._lock:
            vals = sorted(self.values)
        if not vals:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        total = sum(vals)
        return {
            "count": len(vals),
            "sum": total,
            "min": vals[0],
            "max": vals[-1],
            "mean": total / len(vals),
            "p50": self._percentile(vals, 50),
            "p90": self._percentile(vals, 90),
            "p99": self._percentile(vals, 99),
        }


class MetricsRegistry:
    """Instrument factory + JSON snapshot. Thread-safe."""

    recording = True

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def _get(self, store: dict, cls, name: str, labels: dict):
        key = metric_key(name, labels)
        with self._lock:
            inst = store.get(key)
            if inst is None:
                inst = store[key] = cls()
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(self._histograms, Histogram, name, labels)

    def clear(self) -> None:
        """Drop every recorded value (e.g. after a jit-warmup run)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def snapshot(self) -> dict:
        """Plain-JSON view of every instrument (the ``--metrics-out`` /
        ``ServeReport.to_json()['metrics']`` payload)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.summary() for k, h in sorted(hists.items())},
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path


class _NullInstrument:
    """Shared no-op counter/gauge/histogram."""

    __slots__ = ()
    value = 0.0
    values: tuple = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def summary(self) -> dict:
        return {"count": 0}


_NULL_INSTRUMENT = _NullInstrument()


class NullMetricsRegistry:
    """No-op registry: every factory returns ONE shared instrument."""

    recording = False

    def counter(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def clear(self) -> None:
        pass

    def snapshot(self) -> dict:
        return {}


NULL_METRICS = NullMetricsRegistry()


class ScopedMetrics:
    """A registry view that stamps fixed labels onto every instrument.

    The multi-tenant gateway hands each tenant's ``BatchServer``-style
    plumbing ``ScopedMetrics(registry, tenant="acme")``: every existing
    serve counter/gauge/histogram (slot occupancy, KV-pool utilization,
    prefix hits) then lands under ``name{tenant=acme,...}`` in the SHARED
    registry with no new sinks and no call-site changes. Call-site labels
    win on collision (a call that explicitly passes ``tenant=`` overrides
    the scope). Scoping a scope composes; scoping :data:`NULL_METRICS`
    stays a no-op."""

    def __init__(self, registry, **labels):
        self._registry = registry
        self._labels = labels

    @property
    def recording(self) -> bool:
        return self._registry.recording

    def counter(self, name: str, **labels):
        return self._registry.counter(name, **{**self._labels, **labels})

    def gauge(self, name: str, **labels):
        return self._registry.gauge(name, **{**self._labels, **labels})

    def histogram(self, name: str, **labels):
        return self._registry.histogram(name, **{**self._labels, **labels})

    def clear(self) -> None:
        self._registry.clear()

    def snapshot(self) -> dict:
        return self._registry.snapshot()


# ---------------------------------------------------------------------------
# Snapshot validation (CI checks the emitted --metrics-out file)
# ---------------------------------------------------------------------------


def validate_metrics_snapshot(obj: Any) -> int:
    """Validate a :func:`MetricsRegistry.snapshot` JSON object; returns the
    instrument count. Raises ``ValueError`` on shape violations."""
    if not isinstance(obj, dict):
        raise ValueError("metrics: snapshot is not an object")
    n = 0
    for section in ("counters", "gauges", "histograms"):
        if section not in obj:
            raise ValueError(f"metrics: missing section {section!r}")
        sec = obj[section]
        if not isinstance(sec, dict):
            raise ValueError(f"metrics: {section!r} is not a mapping")
        for k, v in sec.items():
            n += 1
            if section == "histograms":
                if not isinstance(v, dict) or "count" not in v:
                    raise ValueError(f"metrics: histogram {k!r} malformed")
                if v["count"] > 0 and not all(
                        key in v for key in ("sum", "mean", "p50", "p99")):
                    raise ValueError(
                        f"metrics: histogram {k!r} missing percentiles")
            elif not isinstance(v, (int, float)):
                raise ValueError(f"metrics: {section[:-1]} {k!r} non-numeric")
    return n


def main(argv: Optional[List[str]] = None) -> None:
    """``python -m repro.obs.metrics FILE...`` - validate snapshots."""
    import sys

    paths = argv if argv is not None else sys.argv[1:]
    if not paths:
        raise SystemExit("usage: python -m repro.obs.metrics METRICS.json ...")
    for p in paths:
        with open(p) as f:
            n = validate_metrics_snapshot(json.load(f))
        print(f"ok {p}: {n} instruments")


if __name__ == "__main__":
    main()
