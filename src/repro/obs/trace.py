"""Thread-safe span tracer exporting Chrome trace-event JSON.

One :class:`Tracer` records *spans* (context-managed, nested, timed with a
monotonic clock), *instant events*, and *counter samples* across any number
of threads, and exports them in the Chrome trace-event format - the JSON
``chrome://tracing`` and Perfetto (https://ui.perfetto.dev) load directly.
Each recording thread gets its own track (``tid``) lazily, so spans opened
on worker threads never interleave with the serve loop's track; named
tracks (``track=``) carry retroactive per-request lifecycle spans.

Design constraints (this is the serving hot path's instrumentation):

  * dependency-free - stdlib only, no jax import;
  * disabled-by-default at near-zero cost: :data:`NULL_TRACER` is a
    module-level singleton whose ``span()`` returns one shared no-op
    context manager - no allocation, no clock read, no lock (the
    zero-allocation fast path ``tests/test_obs.py`` pins);
  * thread-safe when enabled: event appends take one lock, span state
    lives on the span object itself (never shared).

Timebase: microseconds since the tracer's construction (``epoch``, a
``time.monotonic()`` stamp). Callers that timestamp on their own monotonic
clock convert with ``(t_monotonic - tracer.epoch)``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

PID = 1  # single-process serving: one constant chrome pid
_VALID_PH = {"X", "i", "M", "C"}


class _Span:
    """One in-flight span; emits a chrome 'X' (complete) event on exit."""

    __slots__ = ("_tr", "name", "args", "_t0", "_tid")

    def __init__(self, tr: "Tracer", name: str, args: Dict[str, Any]):
        self._tr = tr
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        self._tid = self._tr._thread_tid()
        self._t0 = self._tr._now_us()
        return self

    def __exit__(self, *exc) -> None:
        tr = self._tr
        t1 = tr._now_us()
        tr._emit({"name": self.name, "cat": "serve", "ph": "X",
                  "ts": self._t0, "dur": t1 - self._t0, "pid": PID,
                  "tid": self._tid, "args": self.args})


class _NullSpan:
    """Shared no-op span: entering/exiting records nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Span/instant/counter recorder with Chrome trace-event export."""

    recording = True

    def __init__(self, process_name: str = "repro.serve"):
        self.process_name = process_name
        self.epoch = time.monotonic()
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._tids: Dict[Any, int] = {}
        self._track_names: Dict[int, str] = {}

    # -- clocks & tracks -----------------------------------------------------

    def _now_us(self) -> float:
        return (time.monotonic() - self.epoch) * 1e6

    def _tid_for(self, key: Any, name: str) -> int:
        with self._lock:
            tid = self._tids.get(key)
            if tid is None:
                tid = len(self._tids)
                self._tids[key] = tid
                self._track_names[tid] = name
            return tid

    def _thread_tid(self) -> int:
        # keyed by (ident, name): the OS reuses idents once a thread exits,
        # and a recycled ident must not inherit the dead thread's track
        t = threading.current_thread()
        return self._tid_for(("thread", t.ident, t.name), t.name)

    def track(self, name: str) -> int:
        """tid of a named (non-thread) track, created on first use."""
        return self._tid_for(("track", name), name)

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    # -- recording API -------------------------------------------------------

    def span(self, name: str, **args):
        """Context manager timing one phase on the calling thread's track."""
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        self._emit({"name": name, "cat": "serve", "ph": "i", "s": "t",
                    "ts": self._now_us(), "pid": PID,
                    "tid": self._thread_tid(), "args": args})

    def counter(self, name: str, **series) -> None:
        """One sample of a chrome counter track (gauges over time)."""
        self._emit({"name": name, "cat": "serve", "ph": "C",
                    "ts": self._now_us(), "pid": PID,
                    "tid": self._thread_tid(), "args": series})

    def complete(self, name: str, t_begin_s: float, t_end_s: float,
                 track: Optional[str] = None, **args) -> None:
        """Retroactive span: explicit [begin, end] in tracer-relative
        SECONDS, optionally on a named track (per-request lifecycle spans
        are emitted at finish time, when their bounds are known)."""
        tid = self.track(track) if track is not None else self._thread_tid()
        self._emit({"name": name, "cat": "serve", "ph": "X",
                    "ts": t_begin_s * 1e6,
                    "dur": max(0.0, (t_end_s - t_begin_s) * 1e6),
                    "pid": PID, "tid": tid, "args": args})

    # -- export --------------------------------------------------------------

    @property
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        """Drop recorded events (e.g. after a jit-warmup run) but keep the
        epoch and track assignments, so later spans stay comparable."""
        with self._lock:
            self._events.clear()

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (Perfetto/chrome://tracing)."""
        with self._lock:
            meta = [{"name": "process_name", "ph": "M", "pid": PID, "tid": 0,
                     "args": {"name": self.process_name}}]
            for tid, name in sorted(self._track_names.items()):
                meta.append({"name": "thread_name", "ph": "M", "pid": PID,
                             "tid": tid, "args": {"name": name}})
            return {"traceEvents": meta + list(self._events),
                    "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


class NullTracer:
    """No-op tracer: every recording call is a constant-time no-op and
    ``span()`` hands back ONE shared context manager (no allocation)."""

    recording = False
    epoch = 0.0

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, **series) -> None:
        pass

    def complete(self, name: str, t_begin_s: float, t_end_s: float,
                 track: Optional[str] = None, **args) -> None:
        pass

    @property
    def events(self) -> tuple:
        return ()

    def clear(self) -> None:
        pass

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------------
# Schema validation (tests golden-check it; CI validates emitted traces)
# ---------------------------------------------------------------------------


def validate_chrome_trace(obj: Any) -> int:
    """Validate a Chrome trace-event JSON object; returns the event count.

    The checked contract is what Perfetto/chrome://tracing require to load
    the file: a ``traceEvents`` list whose entries carry ``name``/``ph``/
    ``pid``/``tid``, complete ('X') events with numeric ``ts`` and
    non-negative ``dur``, instants with ``ts``, counters with a numeric
    ``args`` mapping. Raises ``ValueError`` on the first violation."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace: missing top-level 'traceEvents'")
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("trace: 'traceEvents' is not a list")
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            raise ValueError(f"trace[{i}]: event is not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"trace[{i}]: missing {key!r}")
        ph = ev["ph"]
        if ph not in _VALID_PH:
            raise ValueError(f"trace[{i}]: unknown phase {ph!r}")
        if ph in ("X", "i", "C"):
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"trace[{i}]: {ph!r} event needs numeric ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"trace[{i}]: 'X' event needs non-negative dur, got {dur!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                raise ValueError(
                    f"trace[{i}]: 'C' event needs a numeric args mapping")
    return len(evs)


def validate_chrome_trace_file(path: str) -> int:
    with open(path) as f:
        return validate_chrome_trace(json.load(f))


def main(argv: Optional[List[str]] = None) -> None:
    """``python -m repro.obs.trace FILE...`` - validate emitted traces."""
    import sys

    paths = argv if argv is not None else sys.argv[1:]
    if not paths:
        raise SystemExit("usage: python -m repro.obs.trace TRACE.json ...")
    for p in paths:
        n = validate_chrome_trace_file(p)
        print(f"ok {p}: {n} trace events")


if __name__ == "__main__":
    main()
