"""Sim-vs-measured gap tracking: confront the model with the stopwatch.

CIMinus argues sparse-CIM systems live or die by faithful workload modeling
and CIM-Tuner closes the loop between the mapping search and measured
hardware. This module is that loop for this repo: the analytic model
(``core.perf_model``) and the event-driven simulator (``repro.sched``)
predict where a step's cycles go (reload / compute / feature-map / ctrl);
the tracer and metrics registry measure where its wall time actually went.
The comparator turns both into one regression-trackable number plus a
per-phase share table, emitted into ``BENCH_serve.json`` /
``BENCH_sched.json``.

Reading the ratio: ``sim_vs_measured = measured_s / predicted_s``. The
prediction is CIM cycles at ``hw.cim_freq`` on the modeled MARS fabric;
the measurement is host wall time on whatever backend served the run (CPU
interpret-mode Pallas in CI), so the ratio is NOT expected to be ~1 - it
is expected to be FINITE, POSITIVE and STABLE. A drifting ratio means
either the runtime regressed or the model lies; that drift, not the
absolute value, is the tracked signal. Per-phase SHARES, by contrast, are
directly comparable: if the simulator says reload dominates and the trace
says all-gather does, the model is missing the collective - exactly the
7x sharded-row diagnosis this exists for.

Heavy imports (perf_model, sched) are deferred into the functions so the
obs core (trace/metrics) stays dependency-free.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence


def _shares(d: Dict[str, float]) -> Dict[str, float]:
    total = sum(v for v in d.values() if v > 0)
    if total <= 0:
        return {k: 0.0 for k in d}
    return {k: round(max(v, 0.0) / total, 4) for k, v in d.items()}


def gap_report(predicted_s: float, measured_s: float,
               predicted_phases: Optional[Dict[str, float]] = None,
               measured_phases: Optional[Dict[str, float]] = None,
               **extra) -> dict:
    """The first-class gap metric: measured wall time over simulated time.

    ``predicted_phases`` / ``measured_phases`` are per-phase totals in any
    consistent unit (cycles and seconds respectively are fine) - only
    their normalized SHARES are reported, which is what makes them
    comparable across the cycle/wall-clock divide."""
    if predicted_s <= 0 or not math.isfinite(predicted_s):
        raise ValueError(f"gap: predicted_s must be finite > 0, got {predicted_s}")
    if measured_s <= 0 or not math.isfinite(measured_s):
        raise ValueError(f"gap: measured_s must be finite > 0, got {measured_s}")
    out = {
        "predicted_s": predicted_s,
        "measured_s": measured_s,
        "sim_vs_measured": round(measured_s / predicted_s, 4),
        **extra,
    }
    if predicted_phases:
        out["predicted_phase_shares"] = _shares(predicted_phases)
    if measured_phases:
        out["measured_phase_shares"] = _shares(measured_phases)
    return out


def measured_phase_shares(snapshot: dict,
                          metric: str = "serve_phase_s") -> Dict[str, float]:
    """Per-phase wall-time totals out of a ``MetricsRegistry.snapshot()``:
    every ``serve_phase_s{phase=X}`` histogram's sum, keyed by X."""
    out: Dict[str, float] = {}
    for key, h in snapshot.get("histograms", {}).items():
        if not key.startswith(metric + "{"):
            continue
        labels = key[len(metric) + 1:-1]
        phase = dict(part.split("=", 1) for part in labels.split(",")).get("phase")
        if phase is not None:
            out[phase] = out.get(phase, 0.0) + float(h.get("sum", 0.0))
    return out


# ---------------------------------------------------------------------------
# Predictions: decode-step cost from the PR 1 simulator / analytic model
# ---------------------------------------------------------------------------


def predicted_serve_step(cfg, sparsity_gs: float, seq_len: int = 1,
                         hw=None) -> dict:
    """Simulated cost of ONE decode step (all CIM projections at
    ``seq_len`` rows) on the modeled fabric, with the event-driven
    simulator's per-phase cycle breakdown.

    ``sparsity_gs`` is the zero-group-set fraction of the served packing
    (the pruning target is the honest proxy when the per-layer profile is
    not tracked). Returns predicted cycles, seconds at ``hw.cim_freq`` and
    the reload/compute/fm/stall phase cycles."""
    from ..core.perf_model import DEFAULT_HW
    from ..sched import lm_graph, simulate

    hw = hw or DEFAULT_HW
    graph = lm_graph(cfg, seq_len=seq_len, sparsity_gs=sparsity_gs)
    sim = simulate(graph, hw=hw, w_bits=cfg.w_bits, a_bits=cfg.a_bits,
                   keep_events=False)
    phases = {
        "compute": sum(l.compute_cycles for l in sim.layers),
        "reload": sum(l.reload_cycles for l in sim.layers),
        "fm": sum(l.fm_cycles for l in sim.layers),
        "stall": sum(l.stall_cycles for l in sim.layers),
    }
    return {"cycles": sim.cycles, "predicted_s": sim.cycles / hw.cim_freq,
            "phases": phases}


def serve_gap(cfg, measured_step_s: float, sparsity_gs: float,
              measured_phases: Optional[Dict[str, float]] = None,
              hw=None) -> dict:
    """BENCH_serve's gap row: measured decode-step wall time (fenced, from
    the instrumented server) against the simulator's predicted one-token
    step on the modeled fabric."""
    pred = predicted_serve_step(cfg, sparsity_gs, seq_len=1, hw=hw)
    return gap_report(
        pred["predicted_s"], measured_step_s,
        predicted_phases=pred["phases"], measured_phases=measured_phases,
        predicted_cycles=round(pred["cycles"], 1),
        sparsity_gs=sparsity_gs,
    )


def kernel_gap(m: int, k: int, n: int, tile, sparsity: float,
               w_bits: int = 8, a_bits: int = 8, repeats: int = 3,
               hw=None) -> dict:
    """BENCH_sched's gap row: ONE real BSR Pallas dispatch, fenced and
    timed through the :mod:`repro.kernels.timing` hook, against the
    analytic model's cycles for the same (m, k) @ (k, n) matmul at the
    same tile and sparsity.

    This is the CIM-Tuner loop in miniature: the mapping search trusts
    ``perf_model``; this row records what the searched tile's kernel
    actually costs on the current backend so the constants can be re-fit
    (ROADMAP item 4) and regressions in either side show up as ratio
    drift."""
    import numpy as np

    from ..core import perf_model as PM
    from ..core.sparsity import prune_mask_2d
    from ..kernels import ops
    from ..kernels.cim_bsr_matmul import bsr_matmul
    from ..kernels.timing import DispatchTimer
    import dataclasses as _dc
    import jax.numpy as jnp

    hw = hw or PM.DEFAULT_HW
    bk, bn = int(tile[0]), int(tile[1])
    rng = np.random.default_rng(0)
    w = rng.standard_normal((k, n)).astype(np.float32) * 0.05
    if sparsity > 0:
        w = w * np.asarray(prune_mask_2d(jnp.asarray(w), bk, bn, sparsity))
    p = ops.pack_for_kernel(w, bits=w_bits, bk=bk, bn=bn)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    interp = ops.default_interpret()
    args = (x, jnp.asarray(p["blocks"]), jnp.asarray(p["scales"]),
            jnp.asarray(p["row_idx"]), jnp.asarray(p["nnz"]))
    kw = {"interpret": interp}
    timer = DispatchTimer(enabled=True)
    timer.timed("bsr_matmul", (m, k, n), (bk, bn), bsr_matmul, *args, **kw)
    timer.clear()  # first dispatch is trace+compile, excluded
    for _ in range(repeats):
        timer.timed("bsr_matmul", (m, k, n), (bk, bn), bsr_matmul, *args, **kw)
    measured_s = min(r.seconds for r in timer.records)

    # the analytic model sees the matmul as a 1x1 conv with m output pixels
    hw_t = _dc.replace(hw, group=bk, alpha=bn)
    layer = PM.ConvLayer(1, 1, k, n, 1, m, sparsity)
    perf = PM.evaluate_network([layer], w_bits, a_bits, hw=hw_t)[0]
    phases = PM.layer_phase_cycles(layer, w_bits, a_bits, hw=hw_t)
    return gap_report(
        perf.cycles_mars / hw.cim_freq, measured_s,
        predicted_phases=phases, predicted_cycles=round(perf.cycles_mars, 1),
        shape=[m, k, n], tile=[bk, bn], sparsity=sparsity,
        backend=timer.records[-1].backend,
    )
