"""Sim-vs-measured gap tracking: confront the model with the stopwatch.

CIMinus argues sparse-CIM systems live or die by faithful workload modeling
and CIM-Tuner closes the loop between the mapping search and measured
hardware. This module is that loop for this repo: the analytic model
(``core.perf_model``) and the event-driven simulator (``repro.sched``)
predict where a step's cycles go (reload / compute / feature-map / ctrl);
the tracer and metrics registry measure where its wall time actually went.
The comparator turns both into one regression-trackable number plus a
per-phase share table, emitted into ``BENCH_serve.json`` /
``BENCH_sched.json``.

Reading the ratio: ``sim_vs_measured = measured_s / predicted_s``. The
prediction is CIM cycles at ``hw.cim_freq`` on the modeled MARS fabric;
the measurement is host wall time on whatever backend served the run (CPU
interpret-mode Pallas in CI), so the ratio is NOT expected to be ~1 - it
is expected to be FINITE, POSITIVE and STABLE. A drifting ratio means
either the runtime regressed or the model lies; that drift, not the
absolute value, is the tracked signal. Per-phase SHARES, by contrast, are
directly comparable: if the simulator says reload dominates and the trace
says all-gather does, the model is missing the collective - exactly the
7x sharded-row diagnosis this exists for.

Heavy imports (perf_model, sched) are deferred into the functions so the
obs core (trace/metrics) stays dependency-free.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence


# Floor for fenced wall-clock samples: a coarse monotonic clock can return
# an exact 0.0 for a sub-resolution dispatch; clamping (instead of dividing)
# keeps gap rows finite without hiding that the sample was degenerate.
MIN_MEASURED_S = 1e-9


def _shares(d: Dict[str, float]) -> Dict[str, float]:
    """Normalize phase totals to shares, dropping non-finite entries (an
    inf phase would turn every share into NaN via inf/inf)."""
    clean = {k: v for k, v in d.items()
             if isinstance(v, (int, float)) and math.isfinite(v)}
    total = sum(v for v in clean.values() if v > 0)
    if total <= 0:
        return {k: 0.0 for k in clean}
    return {k: round(max(v, 0.0) / total, 4) for k, v in clean.items()}


def clamp_measured(samples: Sequence[float]) -> float:
    """Min over finite positive fenced samples, floored at
    :data:`MIN_MEASURED_S`. Raises ``ValueError`` when every sample is
    non-finite or negative (an empty/broken measurement table) - the loud
    failure belongs here, not as an inf ratio in a bench row."""
    usable = [float(s) for s in samples if math.isfinite(float(s)) and s >= 0]
    if not usable:
        raise ValueError(
            f"gap: no usable measured samples in {list(samples)!r} "
            "(empty phase table or non-finite timings)")
    return max(min(usable), MIN_MEASURED_S)


def gap_report(predicted_s: float, measured_s: float,
               predicted_phases: Optional[Dict[str, float]] = None,
               measured_phases: Optional[Dict[str, float]] = None,
               **extra) -> dict:
    """The first-class gap metric: measured wall time over simulated time.

    ``predicted_phases`` / ``measured_phases`` are per-phase totals in any
    consistent unit (cycles and seconds respectively are fine) - only
    their normalized SHARES are reported, which is what makes them
    comparable across the cycle/wall-clock divide."""
    if predicted_s <= 0 or not math.isfinite(predicted_s):
        raise ValueError(f"gap: predicted_s must be finite > 0, got {predicted_s}")
    if measured_s <= 0 or not math.isfinite(measured_s):
        raise ValueError(f"gap: measured_s must be finite > 0, got {measured_s}")
    out = {
        "predicted_s": predicted_s,
        "measured_s": measured_s,
        "sim_vs_measured": round(measured_s / predicted_s, 4),
        **extra,
    }
    if predicted_phases:
        out["predicted_phase_shares"] = _shares(predicted_phases)
    if measured_phases:
        out["measured_phase_shares"] = _shares(measured_phases)
    return out


def measured_phase_shares(snapshot: dict,
                          metric: str = "serve_phase_s") -> Dict[str, float]:
    """Per-phase wall-time totals out of a ``MetricsRegistry.snapshot()``:
    every ``serve_phase_s{phase=X}`` histogram's sum, keyed by X."""
    out: Dict[str, float] = {}
    for key, h in snapshot.get("histograms", {}).items():
        if not key.startswith(metric + "{") or not isinstance(h, dict):
            continue
        labels = key[len(metric) + 1:-1]
        phase = dict(part.split("=", 1) for part in labels.split(",")
                     if "=" in part).get("phase")
        if phase is None:
            continue
        try:
            total = float(h.get("sum", 0.0))
        except (TypeError, ValueError):
            continue
        if math.isfinite(total):
            out[phase] = out.get(phase, 0.0) + total
    return out


# ---------------------------------------------------------------------------
# Predictions: decode-step cost from the PR 1 simulator / analytic model
# ---------------------------------------------------------------------------


def predicted_serve_step(cfg, sparsity_gs: float, seq_len: int = 1,
                         hw=None, n_devices: int = 1) -> dict:
    """Simulated cost of ONE decode step (all CIM projections at
    ``seq_len`` rows) on the modeled fabric, with the event-driven
    simulator's per-phase cycle breakdown.

    ``sparsity_gs`` is the zero-group-set fraction of the served packing
    (the pruning target is the honest proxy when the per-layer profile is
    not tracked). With ``n_devices > 1`` the macro-mesh sharded path is
    modeled: every column-sharded projection ends in a ring all-gather of
    its output activations (``hw.allgather_cycles``), reported as a
    ``collective`` phase - the piece whose absence made the sharded bench
    row's gap meaningless (the 7x regression in ROADMAP). Returns
    predicted cycles, seconds at ``hw.cim_freq`` and the
    compute/reload/fm/stall[/collective] phase cycles."""
    from ..core.perf_model import DEFAULT_HW
    from ..sched import lm_graph, simulate

    hw = hw or DEFAULT_HW
    graph = lm_graph(cfg, seq_len=seq_len, sparsity_gs=sparsity_gs)
    sim = simulate(graph, hw=hw, w_bits=cfg.w_bits, a_bits=cfg.a_bits,
                   keep_events=False)
    phases = {
        "compute": sum(l.compute_cycles for l in sim.layers),
        "reload": sum(l.reload_cycles for l in sim.layers),
        "fm": sum(l.fm_cycles for l in sim.layers),
        "stall": sum(l.stall_cycles for l in sim.layers),
    }
    cycles = sim.cycles
    if n_devices > 1:
        # fp32 output activations of each sharded projection go around the
        # ring once; the kernels shard every projection on the macro axis
        collective = sum(
            hw.allgather_cycles(l.out_h * l.out_w * l.cout * 4, n_devices)
            for l in graph.layers())
        phases["collective"] = collective
        cycles += collective
    return {"cycles": cycles, "predicted_s": cycles / hw.cim_freq,
            "phases": phases}


def serve_gap(cfg, measured_step_s: float, sparsity_gs: float,
              measured_phases: Optional[Dict[str, float]] = None,
              hw=None, n_devices: int = 1) -> dict:
    """BENCH_serve's gap row: measured decode-step wall time (fenced, from
    the instrumented server) against the simulator's predicted one-token
    step on the modeled fabric (all-gather included when sharded)."""
    measured_step_s = clamp_measured([measured_step_s])
    pred = predicted_serve_step(cfg, sparsity_gs, seq_len=1, hw=hw,
                                n_devices=n_devices)
    return gap_report(
        pred["predicted_s"], measured_step_s,
        predicted_phases=pred["phases"], measured_phases=measured_phases,
        predicted_cycles=round(pred["cycles"], 1),
        sparsity_gs=sparsity_gs, n_devices=n_devices,
    )


def kernel_gap(m: int, k: int, n: int, tile, sparsity: float,
               w_bits: int = 8, a_bits: int = 8, repeats: int = 3,
               hw=None) -> dict:
    """BENCH_sched's gap row: ONE real BSR Pallas dispatch, fenced and
    timed through the :mod:`repro.kernels.timing` hook, against the
    analytic model's cycles for the same (m, k) @ (k, n) matmul at the
    same tile and sparsity.

    This is the CIM-Tuner loop in miniature: the mapping search trusts
    ``perf_model``; this row records what the searched tile's kernel
    actually costs on the current backend so the constants can be re-fit
    (ROADMAP item 4) and regressions in either side show up as ratio
    drift."""
    import numpy as np

    from ..core import perf_model as PM
    from ..core.sparsity import prune_mask_2d
    from ..kernels import ops
    from ..kernels.cim_bsr_matmul import bsr_matmul
    from ..kernels.timing import DispatchTimer
    import dataclasses as _dc
    import jax.numpy as jnp

    hw = hw or PM.DEFAULT_HW
    bk, bn = int(tile[0]), int(tile[1])
    rng = np.random.default_rng(0)
    w = rng.standard_normal((k, n)).astype(np.float32) * 0.05
    if sparsity > 0:
        w = w * np.asarray(prune_mask_2d(jnp.asarray(w), bk, bn, sparsity))
    p = ops.pack_for_kernel(w, bits=w_bits, bk=bk, bn=bn)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    interp = ops.default_interpret()
    args = (x, jnp.asarray(p["blocks"]), jnp.asarray(p["scales"]),
            jnp.asarray(p["row_idx"]), jnp.asarray(p["nnz"]))
    kw = {"interpret": interp}
    timer = DispatchTimer(enabled=True)
    timer.timed("bsr_matmul", (m, k, n), (bk, bn), bsr_matmul, *args, **kw)
    timer.clear()  # first dispatch is trace+compile, excluded
    for _ in range(repeats):
        timer.timed("bsr_matmul", (m, k, n), (bk, bn), bsr_matmul, *args, **kw)
    measured_s = clamp_measured([r.seconds for r in timer.records])

    # the analytic model sees the matmul as a 1x1 conv with m output pixels
    hw_t = _dc.replace(hw, group=bk, alpha=bn)
    layer = PM.ConvLayer(1, 1, k, n, 1, m, sparsity)
    perf = PM.evaluate_network([layer], w_bits, a_bits, hw=hw_t)[0]
    phases = PM.layer_phase_cycles(layer, w_bits, a_bits, hw=hw_t)
    return gap_report(
        perf.cycles_mars / hw.cim_freq, measured_s,
        predicted_phases=phases, predicted_cycles=round(perf.cycles_mars, 1),
        shape=[m, k, n], tile=[bk, bn], sparsity=sparsity,
        backend=timer.records[-1].backend,
    )
