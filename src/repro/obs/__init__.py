"""repro.obs - observability for the serving stack.

Three dependency-free cores:

  * :mod:`trace` - thread-safe span tracer (context-manager spans, instant
    events, counter samples, per-thread tracks) exporting Chrome
    trace-event JSON loadable in Perfetto / ``chrome://tracing``;
  * :mod:`metrics` - labeled counter / gauge / histogram registry with
    JSON snapshots;
  * :mod:`gap` - the CIMinus/CIM-Tuner loop: measured per-phase timings
    confronted with ``core.perf_model`` / the ``repro.sched`` simulator's
    predictions, emitting the ``sim_vs_measured`` ratio the benchmarks
    regression-track;
  * :mod:`history` - append-only JSONL bench history keyed by (git sha,
    backend, arch) with the tolerance-band regression gate CI runs
    (``python -m repro.obs.history``).

Everything is disabled-by-default at near-zero cost: :data:`NULL_TRACER`
and :data:`NULL_METRICS` are shared no-op singletons (zero allocation on
the hot path), so an un-instrumented ``BatchServer`` pays only a handful
of attribute calls per step. ``repro.kernels.timing`` is the companion
fenced-dispatch hook for per-(shape, tile, backend) kernel wall times.
"""
from __future__ import annotations

import time

from . import gap, history, metrics, trace  # noqa: F401
from .metrics import (MetricsRegistry, NullMetricsRegistry,  # noqa: F401
                      NULL_METRICS, ScopedMetrics,
                      validate_metrics_snapshot)
from .trace import (NullTracer, NULL_TRACER, Tracer,  # noqa: F401
                    validate_chrome_trace, validate_chrome_trace_file)


class _PhaseScope:
    """Span + phase-latency histogram in one context manager."""

    __slots__ = ("_tracer", "_metrics", "_name", "_args", "_span", "_t0")

    def __init__(self, tracer, metrics_reg, name, args):
        self._tracer = tracer
        self._metrics = metrics_reg
        self._name = name
        self._args = args

    def __enter__(self) -> "_PhaseScope":
        self._t0 = time.perf_counter()
        self._span = self._tracer.span(self._name, **self._args)
        self._span.__enter__()
        return self

    def __exit__(self, *exc) -> None:
        self._span.__exit__(*exc)
        self._metrics.histogram("serve_phase_s", phase=self._name).observe(
            time.perf_counter() - self._t0)


def phase_scope(tracer, metrics_reg, name: str, **args):
    """One instrumented phase: a tracer span plus a
    ``serve_phase_s{phase=name}`` histogram observation. With both sinks
    disabled this returns the shared no-op span - the zero-cost path."""
    if not (tracer.recording or metrics_reg.recording):
        return trace._NULL_SPAN
    return _PhaseScope(tracer, metrics_reg, name, args)


__all__ = [
    "MetricsRegistry", "NullMetricsRegistry", "NULL_METRICS",
    "ScopedMetrics", "NullTracer", "NULL_TRACER", "Tracer",
    "gap", "history", "metrics", "phase_scope", "trace",
    "validate_chrome_trace", "validate_chrome_trace_file",
    "validate_metrics_snapshot",
]
