"""Append-only bench history + tolerance-band regression gate.

Every BENCH_*.json the benchmarks emit is a one-shot snapshot; the gap
contract (``repro.obs.gap``) says the tracked signal is DRIFT, which needs
history. This module is that history: one JSONL row per bench run, keyed by
git sha / backend / arch, carrying the flattened regression-trackable
numbers (gap ratios, tokens/s, searched FPS). ``check_history`` compares
the newest row of each (backend, arch) group against the median of its
predecessors inside a tolerance band - gap ratios may drift by at most a
multiplicative factor either way, throughput may drop by at most a
fraction - and the ``python -m repro.obs.history`` CLI turns that into a
CI gate (warn-only on noisy forced-CPU runners; malformed history ALWAYS
fails hard, schema rot is never a warning).

Dependency-free like the rest of the obs core: stdlib only.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, List, Optional, Sequence

SCHEMA_VERSION = 1

# Regression tolerances. The gap band is deliberately generous: CI runs
# interpret-mode Pallas on shared runners where 2-3x wall-clock noise is
# real; 4x either way is drift no runner explains.
GAP_TOL = 4.0
DROP_TOL = 0.5
MIN_HISTORY = 1  # prior rows required in a group before gating


# ---------------------------------------------------------------------------
# Row construction: flatten BENCH_*.json into regression-trackable metrics
# ---------------------------------------------------------------------------


def flatten_sched(bench: Dict[str, Any]) -> Dict[str, float]:
    """BENCH_sched.json -> {metric: value} (gap ratios + searched FPS)."""
    out: Dict[str, float] = {}
    for key, e in bench.items():
        gap = e.get("sim_vs_measured", {})
        if isinstance(gap, dict) and "sim_vs_measured" in gap:
            out[f"sched.{key}.gap"] = float(gap["sim_vs_measured"])
            post = gap.get("post_refit")
            if isinstance(post, dict) and "gap" in post:
                out[f"sched.{key}.gap_post_refit"] = float(post["gap"])
        if "fps_searched" in e:
            out[f"sched.{key}.fps_searched"] = float(e["fps_searched"])
    return out


def flatten_serve(bench: Dict[str, Any]) -> Dict[str, float]:
    """BENCH_serve.json -> {metric: value} (gap ratio + tokens/s rows)."""
    out: Dict[str, float] = {}
    gap = bench.get("sim_vs_measured")
    if isinstance(gap, dict) and "sim_vs_measured" in gap:
        out["serve.gap"] = float(gap["sim_vs_measured"])
    sharded_gap = bench.get("sharded", {}).get("sim_vs_measured") \
        if isinstance(bench.get("sharded"), dict) else None
    if isinstance(sharded_gap, dict) and "sim_vs_measured" in sharded_gap:
        out["serve.sharded.gap"] = float(sharded_gap["sim_vs_measured"])
    for name, row in bench.items():
        if isinstance(row, dict) and "tokens_per_s" in row:
            out[f"serve.{name}.tokens_per_s"] = float(row["tokens_per_s"])
    pfx = bench.get("prefix_skew")
    if isinstance(pfx, dict) and "hit_rate" in pfx:
        # prefix-cache effectiveness on the skewed trace: a drop means the
        # radix trie stopped matching (or admissions stopped adopting)
        out["serve.prefix_skew.hit_rate"] = float(pfx["hit_rate"])
    spec = bench.get("spec_vs_scan")
    if isinstance(spec, dict) and "acceptance_rate" in spec:
        # draft-vs-target agreement: a drop means the draft family stopped
        # predicting the target (speculation decays toward pure overhead
        # long before tokens/s shows it on a noisy runner)
        out["serve.spec_vs_scan.acceptance_rate"] = \
            float(spec["acceptance_rate"])
    gw = bench.get("gateway_two_tenant")
    if isinstance(gw, dict):
        # per-tenant gateway health: goodput gates like throughput, and
        # SLO attainment dropping means admission stopped protecting the
        # high-priority tenant (visible long before pooled tokens/s moves)
        for tname, trow in sorted((gw.get("tenants") or {}).items()):
            if not isinstance(trow, dict):
                continue
            if "goodput_tokens_per_s" in trow:
                out[f"serve.gateway_two_tenant.{tname}.goodput_tokens_per_s"] \
                    = float(trow["goodput_tokens_per_s"])
            att = trow.get("slo_attainment")
            if isinstance(att, dict) and "ttft" in att:
                out[f"serve.gateway_two_tenant.{tname}.slo_attainment"] = \
                    float(att["ttft"])
    return out


def make_row(metrics: Dict[str, float], git_sha: str = "unknown",
             backend: str = "unknown", arch: str = "unknown",
             ts: Optional[str] = None) -> Dict[str, Any]:
    if ts is None:
        ts = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
    return {"schema": SCHEMA_VERSION, "ts": ts, "git_sha": git_sha,
            "backend": backend, "arch": arch,
            "metrics": {k: float(v) for k, v in sorted(metrics.items())}}


def append_row(path: str, row: Dict[str, Any]) -> None:
    validate_row(row, where=f"{path} (new row)")
    with open(path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")


# ---------------------------------------------------------------------------
# Loading + validation: malformed history is a HARD failure, always
# ---------------------------------------------------------------------------


def validate_row(row: Any, where: str = "row") -> None:
    if not isinstance(row, dict):
        raise ValueError(f"history: {where}: not an object")
    schema = row.get("schema")
    if not isinstance(schema, int) or schema < 1:
        raise ValueError(f"history: {where}: bad schema {schema!r}")
    if schema > SCHEMA_VERSION:
        raise ValueError(f"history: {where}: schema {schema} is newer than "
                         f"supported {SCHEMA_VERSION}")
    for field in ("ts", "git_sha", "backend", "arch"):
        if not isinstance(row.get(field), str):
            raise ValueError(f"history: {where}: missing/bad field {field!r}")
    metrics = row.get("metrics")
    if not isinstance(metrics, dict):
        raise ValueError(f"history: {where}: metrics is not a mapping")
    for k, v in metrics.items():
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            raise ValueError(f"history: {where}: metric {k!r} non-numeric")


def load_history(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"history: {path}:{i}: not JSON ({e})")
            validate_row(row, where=f"{path}:{i}")
            rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# The regression detector
# ---------------------------------------------------------------------------


def _is_gap(metric: str) -> bool:
    return metric.endswith(".gap") or metric.endswith(".gap_post_refit") \
        or metric == "serve.gap"


def _is_throughput(metric: str) -> bool:
    # acceptance_rate gates like throughput: higher is better, a large
    # relative drop is the regression
    return metric.endswith(".tokens_per_s") \
        or metric.endswith(".fps_searched") \
        or metric.endswith(".acceptance_rate") \
        or metric.endswith(".slo_attainment") \
        or metric.endswith(".goodput_tokens_per_s")


def _median(vals: Sequence[float]) -> float:
    s = sorted(vals)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


def check_history(rows: Sequence[Dict[str, Any]], gap_tol: float = GAP_TOL,
                  drop_tol: float = DROP_TOL,
                  min_history: int = MIN_HISTORY) -> List[dict]:
    """Tolerance-band regression check: newest row of every (backend, arch)
    group vs the median of its prior rows. Returns finding dicts (empty =
    green). Gap metrics regress when latest/baseline leaves the
    [1/gap_tol, gap_tol] band; throughput metrics regress when the latest
    drops more than ``drop_tol`` below baseline. Groups with fewer than
    ``min_history`` prior rows are skipped (no baseline, no verdict)."""
    groups: Dict[tuple, List[Dict[str, Any]]] = {}
    for r in rows:
        groups.setdefault((r["backend"], r["arch"]), []).append(r)
    findings: List[dict] = []
    for (backend, arch), grp in sorted(groups.items()):
        *prior, latest = grp
        if len(prior) < min_history:
            continue
        for metric, value in latest["metrics"].items():
            base_vals = [
                p["metrics"][metric] for p in prior
                if metric in p["metrics"]
                and math.isfinite(p["metrics"][metric])
                and p["metrics"][metric] > 0]
            if not base_vals or not math.isfinite(value):
                continue
            baseline = _median(base_vals)
            common = {"backend": backend, "arch": arch, "metric": metric,
                      "latest": value, "baseline": baseline,
                      "n_baseline": len(base_vals)}
            if _is_gap(metric) and value > 0:
                ratio = value / baseline
                if ratio > gap_tol or ratio < 1.0 / gap_tol:
                    findings.append({**common, "kind": "gap-drift",
                                     "ratio": round(ratio, 4),
                                     "tol": gap_tol})
            elif _is_throughput(metric):
                if value < baseline * (1.0 - drop_tol):
                    findings.append({**common, "kind": "throughput-drop",
                                     "drop": round(1.0 - value / baseline, 4),
                                     "tol": drop_tol})
    return findings


# ---------------------------------------------------------------------------
# CLI: the CI gate
# ---------------------------------------------------------------------------


def _git_sha() -> str:
    import subprocess

    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def main(argv: Optional[List[str]] = None) -> None:
    """``python -m repro.obs.history append|check ...`` - build history
    rows out of BENCH_*.json files and gate on drift."""
    import argparse

    ap = argparse.ArgumentParser(prog="python -m repro.obs.history")
    sub = ap.add_subparsers(dest="cmd", required=True)
    a = sub.add_parser("append", help="flatten BENCH_*.json into a history row")
    a.add_argument("--out", required=True, help="history JSONL path")
    a.add_argument("--sched", help="BENCH_sched.json path")
    a.add_argument("--serve", help="BENCH_serve.json path")
    a.add_argument("--sha", default=None, help="git sha (default: HEAD)")
    a.add_argument("--backend", default=None,
                   help="backend label (default: jax.default_backend())")
    a.add_argument("--arch", default=None,
                   help="arch label (default: the serve report's, or 'bench')")
    c = sub.add_parser("check", help="regression gate over a history file")
    c.add_argument("history", help="history JSONL path")
    c.add_argument("--gap-tol", type=float, default=GAP_TOL)
    c.add_argument("--drop-tol", type=float, default=DROP_TOL)
    c.add_argument("--min-history", type=int, default=MIN_HISTORY)
    c.add_argument("--warn-only", action="store_true",
                   help="report findings without failing (noisy runners); "
                        "malformed history still fails hard")
    args = ap.parse_args(argv)

    if args.cmd == "append":
        metrics: Dict[str, float] = {}
        arch = args.arch
        if args.sched:
            with open(args.sched) as f:
                metrics.update(flatten_sched(json.load(f)))
        if args.serve:
            with open(args.serve) as f:
                serve = json.load(f)
            metrics.update(flatten_serve(serve))
            if arch is None and isinstance(serve.get("arch"), str):
                arch = serve["arch"]
        if not metrics:
            raise SystemExit("history append: no metrics (pass --sched/--serve)")
        backend = args.backend
        if backend is None:
            import jax

            backend = jax.default_backend()
        row = make_row(metrics, git_sha=args.sha or _git_sha(),
                       backend=backend, arch=arch or "bench")
        append_row(args.out, row)
        print(f"appended {len(metrics)} metrics to {args.out} "
              f"(backend={row['backend']}, arch={row['arch']}, "
              f"sha={row['git_sha'][:12]})")
        return

    # check: malformed history exits 2 regardless of --warn-only
    try:
        rows = load_history(args.history)
    except (ValueError, OSError) as e:
        import sys

        print(f"history: MALFORMED: {e}", file=sys.stderr)
        raise SystemExit(2)
    findings = check_history(rows, gap_tol=args.gap_tol,
                             drop_tol=args.drop_tol,
                             min_history=args.min_history)
    if not findings:
        print(f"ok {args.history}: {len(rows)} rows, no regressions")
        return
    for f in findings:
        print(f"REGRESSION[{f['kind']}] {f['backend']}/{f['arch']} "
              f"{f['metric']}: latest {f['latest']:.6g} vs baseline "
              f"{f['baseline']:.6g} (n={f['n_baseline']}, tol={f['tol']})")
    if args.warn_only:
        print(f"warn-only: {len(findings)} finding(s) reported, not failing")
        return
    raise SystemExit(1)


if __name__ == "__main__":
    main()
