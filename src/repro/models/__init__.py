from . import cnn, config, encdec, layers, registry, ssm, transformer  # noqa: F401
from .config import ModelConfig, ShapeConfig, SHAPES  # noqa: F401
