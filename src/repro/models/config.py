"""Model configuration shared by every architecture in the zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from ..core.cim_layer import CIMConfig
from ..core.quant import QuantConfig
from ..core.sparsity import SparsityConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    expert_split: int = 1  # sub-expert FFN split so E*split matches the mesh

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0  # 0 -> d_inner // 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # attention patterns
    window: int = 0  # sliding-window size for local layers (0 = full)
    local_global_ratio: int = 0  # gemma3: this many local layers per global
    attn_every: int = 0  # zamba2: shared attention block every k ssm layers

    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500  # precomputed frame embeddings (frontend stub)

    # vlm (llava)
    n_patches: int = 0  # precomputed patch embeddings (frontend stub)

    # numerics / distribution
    dtype: str = "bfloat16"
    rope_theta: float = 10000.0
    remat: str = "full"  # full | none
    scan_unroll: bool = False  # fully unroll layer scans (dry-run cost analysis)
    tie_embeddings: bool = False

    # --- beyond-paper performance knobs (EXPERIMENTS.md §Perf) ---
    # 0 = paper-faithful baseline (naive S^2 attention); >0 = online-softmax
    # chunked attention over KV blocks of this size
    attn_chunk: int = 0
    # 1 = baseline; 16 = pad Q/KV head counts up to a multiple that divides
    # the TP axis (zero-initialized pad heads -> numerically identical)
    head_pad: int = 1
    # MoE dispatch token-group size (smaller -> smaller one-hot tensors and
    # less capacity slack)
    moe_group_size: int = 512
    # SSD intra-chunk math in bf16 (decays still exp/cumsum in f32)
    ssd_lowp: bool = False
    # split the fused mamba in_proj/conv into shard-aligned segments
    # (z|x, b|c, dt separate weights - numerically identical layout change)
    ssm_split_proj: bool = False
    # pad the vocab so the LM head shards over the TP axis (kills the
    # full-logits partial-sum all-reduce when vocab % 16 != 0)
    vocab_pad_multiple: int = 1
    # explicit sharding hints inside the MoE block (prevents GSPMD's
    # "involuntary full rematerialization" of dispatch/combine tensors)
    moe_hints: bool = False
    # Megatron-SP: shard the residual stream's sequence dim over the TP
    # axis between layers (activation ARs become RS+AG pairs)
    seq_shard_residual: bool = False

    # MARS compression (the paper's technique, first-class)
    cim_mode: str = "dense"  # dense | qat
    w_bits: int = 8
    a_bits: int = 8
    lambda_g: float = 0.0
    cim_alpha: int = 128  # TPU-native tile (MXU-aligned); paper CNNs use 16
    cim_n: int = 128

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_heads_eff(self) -> int:
        """Q heads after TP padding (zero-init pads keep math identical)."""
        if self.head_pad <= 1 or self.n_heads == 0:
            return self.n_heads
        return -(-self.n_heads // self.head_pad) * self.head_pad

    @property
    def n_kv_heads_eff(self) -> int:
        """KV heads are never padded: _expand_kv replicates by the TRUE
        H/KV ratio and zero-pads the expanded heads, so the real heads'
        math is unchanged."""
        return self.n_kv_heads

    @property
    def vocab_eff(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return -(-self.vocab // m) * m

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    @property
    def cim(self) -> CIMConfig:
        return CIMConfig(
            quant=QuantConfig(w_bits=self.w_bits, a_bits=self.a_bits,
                              group_size=self.cim_alpha, a_signed=True),
            sparsity=SparsityConfig(alpha=self.cim_alpha, n=self.cim_n,
                                    lambda_g=self.lambda_g),
            mode=self.cim_mode,
        )

    def layer_kinds(self) -> Tuple[int, ...]:
        """Per-layer kind codes. dense/moe/vlm: 0=full attn, 1=windowed.
        hybrid: 1 where the shared attention block fires."""
        if self.local_global_ratio > 0:
            # gemma3 pattern: (ratio) local then 1 global, repeating
            period = self.local_global_ratio + 1
            return tuple(
                0 if (i % period == self.local_global_ratio) else 1
                for i in range(self.n_layers)
            )
        if self.attn_every > 0:
            return tuple(
                1 if (i % self.attn_every == self.attn_every - 1) else 0
                for i in range(self.n_layers)
            )
        return tuple(0 for _ in range(self.n_layers))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the assigned input-shape cells."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
