"""Decoder-only LM covering the dense / moe / vlm / ssm / hybrid families.

Structure is scan-over-layers with stacked parameters (compile time O(1) in
depth - essential for 512-device dry-runs on this CPU container). Family
quirks:

  * gemma3: per-layer sliding window + RoPE theta via stacked (L,) arrays.
  * moe (phi3.5 / grok): MoE MLP with grouped capacity dispatch.
  * vlm (llava): precomputed patch embeddings (frontend stub per spec)
    prepended to text embeddings through a projector.
  * ssm (mamba2): stacked Mamba2 blocks, no attention anywhere.
  * hybrid (zamba2): scan over super-layers of ``attn_every`` mamba blocks
    followed by one invocation of a SHARED attention+MLP block (weights
    shared across invocations, per-invocation gate) - plus a mamba tail.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as SSM
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _attn_layer_init(key, cfg: ModelConfig, dtype):
    d, dh = cfg.d_model, cfg.dh
    nh, nkv = cfg.n_heads_eff, cfg.n_kv_heads_eff
    ks = jax.random.split(key, 8)
    s = 1.0 / d**0.5

    def _padded(key, shape, pad_axis, true_n, eff_n):
        """Zero-init the TP-padding head slices (forward-identical)."""
        w = jax.random.normal(key, shape, dtype) * s
        if eff_n == true_n:
            return w
        m = (jnp.arange(eff_n * dh) < true_n * dh).astype(dtype)
        return w * (m[None, :] if pad_axis == 1 else m[:, None])

    p = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "wq": _padded(ks[0], (d, nh * dh), 1, cfg.n_heads, nh),
        "wk": _padded(ks[1], (d, nkv * dh), 1, cfg.n_kv_heads, nkv),
        "wv": _padded(ks[2], (d, nkv * dh), 1, cfg.n_kv_heads, nkv),
        "wo": _padded(ks[3], (nh * dh, d), 0, cfg.n_heads, nh)
        * (d**0.5 / (nh * dh) ** 0.5),
        "ln2": jnp.zeros((d,), jnp.float32),
    }
    if cfg.family == "moe":
        e = cfg.n_experts
        p["router"] = jax.random.normal(ks[4], (d, e), jnp.float32) * s
        e_eff = e * cfg.expert_split
        ff = cfg.d_ff // cfg.expert_split
        p["w_gate"] = jax.random.normal(ks[5], (e_eff, d, ff), dtype) * s
        p["w_up"] = jax.random.normal(ks[6], (e_eff, d, ff), dtype) * s
        p["w_down"] = jax.random.normal(ks[7], (e_eff, ff, d), dtype) * (
            1.0 / cfg.d_ff**0.5
        )
    else:
        p["w_gate"] = jax.random.normal(ks[5], (d, cfg.d_ff), dtype) * s
        p["w_up"] = jax.random.normal(ks[6], (d, cfg.d_ff), dtype) * s
        p["w_down"] = jax.random.normal(ks[7], (cfg.d_ff, d), dtype) * (
            1.0 / cfg.d_ff**0.5
        )
    return p


def _stack(layer_fn, keys):
    return jax.vmap(layer_fn)(keys)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cfg.param_dtype
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_eff, d), dtype) * 0.02,
        "final_ln": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(keys[1], (d, cfg.vocab_eff), dtype) * 0.02

    if cfg.family in ("dense", "moe", "vlm"):
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = _stack(
            functools.partial(_attn_layer_init, cfg=cfg, dtype=dtype), lkeys
        )
    elif cfg.family == "ssm":
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = _stack(
            lambda k: {"ln": jnp.zeros((d,), jnp.float32), **SSM.mamba_init(k, cfg, dtype)},
            lkeys,
        )
    elif cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        n_body = n_super * cfg.attn_every
        bkeys = jax.random.split(keys[2], n_body).reshape(n_super, cfg.attn_every, 2)
        params["layers_body"] = jax.vmap(
            jax.vmap(lambda k: {"ln": jnp.zeros((d,), jnp.float32),
                                **SSM.mamba_init(k, cfg, dtype)})
        )(bkeys)
        n_tail = cfg.n_layers - n_body
        if n_tail:
            tkeys = jax.random.split(keys[3], n_tail)
            params["layers_tail"] = _stack(
                lambda k: {"ln": jnp.zeros((d,), jnp.float32), **SSM.mamba_init(k, cfg, dtype)},
                tkeys,
            )
        params["shared_attn"] = _attn_layer_init(keys[4], cfg, dtype)
        params["attn_gate"] = jnp.ones((n_super,), jnp.float32)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        params["mm_proj"] = jax.random.normal(keys[5], (d, d), dtype) * (1.0 / d**0.5)
    return params


# ---------------------------------------------------------------------------
# Per-layer bodies
# ---------------------------------------------------------------------------


def _attn_mlp_body(p, x, cfg: ModelConfig, window, theta, positions):
    """One transformer block (full-seq). Returns (x, aux, (k, v))."""
    cfg_l = cfg if theta is None else _with_theta(cfg, theta)
    h = L.rmsnorm(x, p["ln1"])
    attn, kv = L.self_attention(p, h, cfg_l, window=window, positions=positions)
    x = x + attn
    h = L.rmsnorm(x, p["ln2"])
    if cfg.family == "moe":
        y, aux = L.moe_block(p, h, cfg)
    else:
        y, aux = L.gated_mlp(p, h, cfg.cim), jnp.zeros((), jnp.float32)
    x = x + y
    if cfg.seq_shard_residual:
        from jax.sharding import PartitionSpec as _PS
        x = jax.lax.with_sharding_constraint(x, _PS("data", "model", None))
    return x, aux, kv


class _ThetaCfg:
    """Tiny proxy so a traced per-layer rope theta can override the config."""

    def __init__(self, cfg, theta):
        object.__setattr__(self, "_cfg", cfg)
        object.__setattr__(self, "rope_theta", theta)

    def __getattr__(self, k):
        return getattr(self._cfg, k)


def _with_theta(cfg, theta):
    return _ThetaCfg(cfg, theta)


def _layer_kind_arrays(cfg: ModelConfig):
    kinds = cfg.layer_kinds()
    window = jnp.asarray(
        [cfg.window if k == 1 else 0 for k in kinds], jnp.int32
    )
    if cfg.local_global_ratio > 0:
        theta = jnp.asarray(
            [cfg.rope_theta if k == 1 else 1e6 for k in kinds], jnp.float32
        )
    else:
        theta = jnp.full((cfg.n_layers,), cfg.rope_theta, jnp.float32)
    return window, theta


def _maybe_remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        # selective: keep matmul/einsum outputs, recompute elementwise only
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _scan(body, init, xs, cfg):
    return jax.lax.scan(body, init, xs, unroll=True if cfg.scan_unroll else 1)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch, cfg: ModelConfig):
    x = L.embed(params["embed"], batch["tokens"], cfg.param_dtype)
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(cfg.param_dtype)
        patches = patches @ params["mm_proj"].astype(patches.dtype)
        x = jnp.concatenate([patches, x], axis=1)
    return x


def forward_hidden(params, batch, cfg: ModelConfig, collect_cache: bool = False):
    """Returns (hidden (B,S,D), aux_loss, cache-or-None)."""
    x = _embed_inputs(params, batch, cfg)
    Bsz, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    if cfg.family in ("dense", "moe", "vlm"):
        window_arr, theta_arr = _layer_kind_arrays(cfg)

        def body(carry, xs):
            x, aux = carry
            p, w, t = xs
            x, a, kv = _attn_mlp_body(p, x, cfg, w, t, positions)
            return (x, aux + a), kv if collect_cache else None

        body = _maybe_remat(body, cfg)
        (x, aux), kvs = _scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], window_arr, theta_arr), cfg,
        )
        cache = None
        if collect_cache:
            cache = {"k": kvs[0], "v": kvs[1]}  # (L,B,S,KV,dh)

    elif cfg.family == "ssm":

        def body(carry, p):
            x, aux = carry
            h = L.rmsnorm(x, p["ln"])
            y, (conv_tail, h_last) = SSM.mamba_block(p, h, cfg)
            return (x + y, aux), (conv_tail, h_last) if collect_cache else None

        body = _maybe_remat(body, cfg)
        (x, aux), tails = _scan(
            body, (x, jnp.zeros((), jnp.float32)), params["layers"], cfg
        )
        cache = None
        if collect_cache:
            cache = {"conv": tails[0], "ssm": tails[1]}

    elif cfg.family == "hybrid":
        x, aux, cache = _hybrid_forward(params, x, cfg, positions, collect_cache)
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(x, params["final_ln"])
    return x, aux, cache


def _hybrid_forward(params, x, cfg: ModelConfig, positions, collect_cache):
    shared = params["shared_attn"]
    aux0 = jnp.zeros((), jnp.float32)

    def mamba_one(x, p):
        h = L.rmsnorm(x, p["ln"])
        y, tail = SSM.mamba_block(p, h, cfg)
        return x + y, tail

    def super_body(carry, xs):
        x, aux = carry
        p_stack, gate = xs

        def inner(x, p):
            x, tail = mamba_one(x, p)
            return x, tail

        x, tails = jax.lax.scan(inner, x, p_stack)
        h = L.rmsnorm(x, shared["ln1"])
        attn, kv = L.self_attention(shared, h, cfg, window=cfg.window,
                                    positions=positions)
        x = x + gate.astype(x.dtype) * attn
        h = L.rmsnorm(x, shared["ln2"])
        x = x + gate.astype(x.dtype) * L.gated_mlp(shared, h, cfg.cim)
        out = (tails, kv) if collect_cache else None
        return (x, aux), out

    super_body = _maybe_remat(super_body, cfg)
    (x, aux), outs = _scan(
        super_body, (x, aux0), (params["layers_body"], params["attn_gate"]), cfg
    )
    cache = None
    if collect_cache:
        tails, kvs = outs
        cache = {
            "conv": tails[0],  # (n_super, attn_every, B, W-1, C)
            "ssm": tails[1],
            "k": kvs[0],  # (n_super, B, S, KV, dh)
            "v": kvs[1],
        }

    if "layers_tail" in params:

        def tail_body(carry, p):
            x, aux = carry
            x, tail = mamba_one(x, p)
            return (x, aux), tail if collect_cache else None

        tail_body = _maybe_remat(tail_body, cfg)
        (x, aux), tails = _scan(tail_body, (x, aux), params["layers_tail"], cfg)
        if collect_cache:
            cache["conv_tail"] = tails[0]
            cache["ssm_tail"] = tails[1]
    return x, aux, cache


# ---------------------------------------------------------------------------
# Losses / serving entry points
# ---------------------------------------------------------------------------


def train_loss(params, batch, cfg: ModelConfig) -> jnp.ndarray:
    """Next-token CE (+ MoE aux). batch: tokens (B,S) [, patch_embeds,
    loss_mask]. For vlm, patches prepend - labels cover text only."""
    hidden, aux, _ = forward_hidden(params, batch, cfg)
    head = params["head"] if "head" in params else params["embed"].T
    if cfg.family == "vlm":
        npatch = batch["patch_embeds"].shape[1]
        hidden = hidden[:, npatch:, :]
    logits = L.logits_out(head, hidden, cfg.cim)
    if cfg.vocab_eff != cfg.vocab:
        # padded vocab columns never win: mask before the softmax
        pad_mask = jnp.arange(cfg.vocab_eff) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None, :], L.NEG_INF, logits.astype(jnp.float32))
    labels = batch["tokens"][:, 1:]
    logits = logits[:, :-1, :]
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:]
    loss = L.cross_entropy(logits, labels, mask)
    return loss + 0.01 * aux


def prefill(params, batch, cfg: ModelConfig):
    """Returns (last-position logits (B,V), cache dict with 'pos')."""
    hidden, _, cache = forward_hidden(params, batch, cfg, collect_cache=True)
    head = params["head"] if "head" in params else params["embed"].T
    logits = L.logits_out(head, hidden[:, -1:, :], cfg.cim)[:, 0, : cfg.vocab]
    cache = dict(cache)
    total = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        total += batch["patch_embeds"].shape[1]
    cache["pos"] = jnp.asarray(total, jnp.int32)
    return logits, cache


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None) -> dict:
    """Empty decode cache (for decode-only dry-runs and serving)."""
    dtype = dtype or cfg.param_dtype
    if cfg.family in ("dense", "moe", "vlm"):
        shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads_eff, cfg.dh)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        di, N, H, W = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.conv_width
        conv_dim = di + 2 * N
        return {
            "conv": jnp.zeros((cfg.n_layers, batch_size, W - 1, conv_dim), dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch_size, H, di // H, N), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_every
        n_tail = cfg.n_layers - n_super * cfg.attn_every
        di, N, H, W = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.conv_width
        conv_dim = di + 2 * N
        kv_len = min(max_len, cfg.window) if cfg.window else max_len
        c = {
            "conv": jnp.zeros((n_super, cfg.attn_every, batch_size, W - 1, conv_dim), dtype),
            "ssm": jnp.zeros((n_super, cfg.attn_every, batch_size, H, di // H, N), dtype),
            "k": jnp.zeros((n_super, batch_size, kv_len, cfg.n_kv_heads_eff, cfg.dh), dtype),
            "v": jnp.zeros((n_super, batch_size, kv_len, cfg.n_kv_heads_eff, cfg.dh), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
        if n_tail:
            c["conv_tail"] = jnp.zeros((n_tail, batch_size, W - 1, conv_dim), dtype)
            c["ssm_tail"] = jnp.zeros((n_tail, batch_size, H, di // H, N), dtype)
        return c
    raise ValueError(cfg.family)


def pad_cache(cache: dict, max_len: int) -> dict:
    """Grow a prefill cache's seq axis to ``max_len`` for decoding."""
    out = dict(cache)
    for key in ("k", "v"):
        if key in cache:
            c = cache[key]
            pad = max_len - c.shape[2]
            if pad > 0:
                cfgpad = [(0, 0)] * c.ndim
                cfgpad[2] = (0, pad)
                out[key] = jnp.pad(c, cfgpad)
    return out


def decode_step(params, cache: dict, tokens: jnp.ndarray, cfg: ModelConfig):
    """One decode step. tokens: (B, 1). Returns (logits (B,V), new cache)."""
    x = L.embed(params["embed"], tokens, cfg.param_dtype)
    pos = cache["pos"]

    if cfg.family in ("dense", "moe", "vlm"):
        window_arr, theta_arr = _layer_kind_arrays(cfg)

        def body(x, xs):
            p, w, t, kc, vc = xs
            cfg_l = _with_theta(cfg, t)
            h = L.rmsnorm(x, p["ln1"])
            attn, kc, vc = L.decode_attention(p, h, kc, vc, pos, cfg_l, window=w)
            x = x + attn
            h = L.rmsnorm(x, p["ln2"])
            if cfg.family == "moe":
                y, _ = L.moe_block(p, h, cfg)
            else:
                y = L.gated_mlp(p, h, cfg.cim)
            return x + y, (kc, vc)

        x, (k, v) = _scan(
            body, x, (params["layers"], window_arr, theta_arr, cache["k"], cache["v"]), cfg
        )
        new_cache = {"k": k, "v": v, "pos": pos + 1}

    elif cfg.family == "ssm":

        def body(x, xs):
            p, conv, h = xs
            hin = L.rmsnorm(x, p["ln"])
            y, conv, h = SSM.mamba_decode_step(p, hin, conv, h, cfg)
            return x + y, (conv, h)

        x, (conv, h) = _scan(body, x, (params["layers"], cache["conv"], cache["ssm"]), cfg)
        new_cache = {"conv": conv, "ssm": h, "pos": pos + 1}

    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, cache, x, cfg)
        new_cache["pos"] = pos + 1
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(x, params["final_ln"])
    head = params["head"] if "head" in params else params["embed"].T
    logits = L.logits_out(head, x, cfg.cim)[:, 0, : cfg.vocab]
    return logits, new_cache


def _hybrid_decode(params, cache, x, cfg: ModelConfig):
    shared = params["shared_attn"]
    pos = cache["pos"]
    kv_len = cache["k"].shape[2]
    ring = bool(cfg.window) and kv_len == min(cfg.window, kv_len)

    def super_body(x, xs):
        p_stack, gate, conv, h, kc, vc = xs

        def inner(x, ys):
            p, cv, hh = ys
            hin = L.rmsnorm(x, p["ln"])
            y, cv, hh = SSM.mamba_decode_step(p, hin, cv, hh, cfg)
            return x + y, (cv, hh)

        x, (conv, h) = jax.lax.scan(inner, x, (p_stack, conv, h))
        hin = L.rmsnorm(x, shared["ln1"])
        attn, kc, vc = L.decode_attention(shared, hin, kc, vc, pos, cfg,
                                          window=0, use_rope=True, ring=ring)
        x = x + gate.astype(x.dtype) * attn
        hin = L.rmsnorm(x, shared["ln2"])
        x = x + gate.astype(x.dtype) * L.gated_mlp(shared, hin, cfg.cim)
        return x, (conv, h, kc, vc)

    x, (conv, h, k, v) = _scan(
        super_body, x,
        (params["layers_body"], params["attn_gate"], cache["conv"], cache["ssm"],
         cache["k"], cache["v"]), cfg,
    )
    new_cache = {"conv": conv, "ssm": h, "k": k, "v": v}

    if "layers_tail" in params:

        def tail(x, ys):
            p, cv, hh = ys
            hin = L.rmsnorm(x, p["ln"])
            y, cv, hh = SSM.mamba_decode_step(p, hin, cv, hh, cfg)
            return x + y, (cv, hh)

        x, (cv, hh) = _scan(
            tail, x, (params["layers_tail"], cache["conv_tail"], cache["ssm_tail"]), cfg
        )
        new_cache["conv_tail"] = cv
        new_cache["ssm_tail"] = hh
    return x, new_cache
