"""Whisper-style encoder-decoder backbone.

Per the assignment spec the conv/mel frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, enc_seq, d_model). The rest is a
faithful whisper transformer: LayerNorm (with bias), learned decoder
positions, sinusoidal-free encoder (positions baked into stub frames),
MHA (kv == heads), GELU MLP, tied output head.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig

MAX_DEC_POS = 8192  # learned decoder positions (>= longest assigned shape? no
# - decode_32k exceeds this; positions clamp, noted as a backbone-shape
# exercise rather than a claim whisper generates 32k tokens)


def _ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def _attn_init(key, cfg: ModelConfig, dtype):
    d, dh = cfg.d_model, cfg.dh
    nh, nkv = cfg.n_heads_eff, cfg.n_kv_heads_eff
    ks = jax.random.split(key, 4)
    s = 1.0 / d**0.5
    return {
        "wq": jax.random.normal(ks[0], (d, nh * dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, nkv * dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, nkv * dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (nh * dh, d), dtype)
        * (1.0 / (nh * dh) ** 0.5),
    }


def _mlp_init(key, cfg, dtype):
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    return {
        "w_up": jax.random.normal(k1, (d, cfg.d_ff), dtype) * (1.0 / d**0.5),
        "w_down": jax.random.normal(k2, (cfg.d_ff, d), dtype) * (1.0 / cfg.d_ff**0.5),
    }


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": _ln_init(cfg.d_model), "attn": _attn_init(k1, cfg, dtype),
            "ln2": _ln_init(cfg.d_model), "mlp": _mlp_init(k2, cfg, dtype)}


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": _ln_init(cfg.d_model), "self": _attn_init(k1, cfg, dtype),
        "lnx": _ln_init(cfg.d_model), "cross": _attn_init(k2, cfg, dtype),
        "ln2": _ln_init(cfg.d_model), "mlp": _mlp_init(k3, cfg, dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cfg.param_dtype
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": jax.random.normal(ks[2], (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "pos_dec": jax.random.normal(ks[3], (MAX_DEC_POS, cfg.d_model), dtype) * 0.01,
        "enc_layers": jax.vmap(functools.partial(_enc_layer_init, cfg=cfg, dtype=dtype))(enc_keys),
        "dec_layers": jax.vmap(functools.partial(_dec_layer_init, cfg=cfg, dtype=dtype))(dec_keys),
        "enc_ln": _ln_init(cfg.d_model),
        "dec_ln": _ln_init(cfg.d_model),
    }


def _ln(x, p):
    return L.layernorm(x, p["g"], p["b"])


def encode(params, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, T, D) stubbed embeddings -> encoder hidden (B, T, D)."""
    x = frames.astype(cfg.param_dtype)

    def body(x, p):
        h = _ln(x, p["ln1"])
        x = x + L.bidir_attention(p["attn"], h, cfg)
        h = _ln(x, p["ln2"])
        x = x + L.gelu_mlp(p["mlp"], h, cfg.cim)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=True if cfg.scan_unroll else 1)
    return _ln(x, params["enc_ln"])


def _dec_embed(params, tokens, pos0, cfg):
    x = L.embed(params["embed"], tokens, cfg.param_dtype)
    s = tokens.shape[1]
    pidx = jnp.clip(pos0 + jnp.arange(s), 0, MAX_DEC_POS - 1)
    return x + params["pos_dec"][pidx][None].astype(x.dtype)


def decode_full(params, tokens: jnp.ndarray, enc: jnp.ndarray, cfg) -> jnp.ndarray:
    """Teacher-forced decoder over the full sequence (train)."""
    x = _dec_embed(params, tokens, 0, cfg)

    def body(x, p):
        h = _ln(x, p["ln1"])
        attn, _ = L.self_attention(p["self"], h, cfg, use_rope=False)
        x = x + attn
        h = _ln(x, p["lnx"])
        b, t, _ = enc.shape
        kx = L.cim_matmul(enc, p["cross"]["wk"].astype(enc.dtype), cfg.cim)
        vx = L.cim_matmul(enc, p["cross"]["wv"].astype(enc.dtype), cfg.cim)
        kx = kx.reshape(b, t, cfg.n_kv_heads_eff, cfg.dh)
        vx = vx.reshape(b, t, cfg.n_kv_heads_eff, cfg.dh)
        x = x + L.cross_attention(p["cross"], h, (kx, vx), cfg)
        h = _ln(x, p["ln2"])
        x = x + L.gelu_mlp(p["mlp"], h, cfg.cim)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"],
                        unroll=True if cfg.scan_unroll else 1)
    return _ln(x, params["dec_ln"])


def train_loss(params, batch, cfg: ModelConfig) -> jnp.ndarray:
    enc = encode(params, batch["frames"], cfg)
    hidden = decode_full(params, batch["tokens"], enc, cfg)
    logits = L.logits_out(params["embed"].T, hidden[:, :-1, :], cfg.cim)
    return L.cross_entropy(logits, batch["tokens"][:, 1:])


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or cfg.param_dtype
    Lc = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads_eff, cfg.dh)
    Xc = (cfg.n_layers, batch_size, cfg.enc_seq, cfg.n_kv_heads_eff, cfg.dh)
    return {"k": jnp.zeros(Lc, dtype), "v": jnp.zeros(Lc, dtype),
            "xk": jnp.zeros(Xc, dtype), "xv": jnp.zeros(Xc, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg: ModelConfig):
    """Encode + teacher-forced prefill of the decoder prompt; fills both the
    self-attn cache and the precomputed cross K/V."""
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _dec_embed(params, tokens, 0, cfg)

    def body(x, p):
        h = _ln(x, p["ln1"])
        attn, (k, v) = L.self_attention(p["self"], h, cfg, use_rope=False)
        x = x + attn
        h = _ln(x, p["lnx"])
        t = enc.shape[1]
        kx = L.cim_matmul(enc, p["cross"]["wk"].astype(enc.dtype), cfg.cim)
        vx = L.cim_matmul(enc, p["cross"]["wv"].astype(enc.dtype), cfg.cim)
        kx = kx.reshape(b, t, cfg.n_kv_heads_eff, cfg.dh)
        vx = vx.reshape(b, t, cfg.n_kv_heads_eff, cfg.dh)
        x = x + L.cross_attention(p["cross"], h, (kx, vx), cfg)
        h = _ln(x, p["ln2"])
        x = x + L.gelu_mlp(p["mlp"], h, cfg.cim)
        return x, (k, v, kx, vx)

    x, (k, v, kx, vx) = jax.lax.scan(body, x, params["dec_layers"],
                                     unroll=True if cfg.scan_unroll else 1)
    x = _ln(x, params["dec_ln"])
    logits = L.logits_out(params["embed"].T, x[:, -1:, :], cfg.cim)[:, 0, :]
    return logits, {"k": k, "v": v, "xk": kx, "xv": vx,
                    "pos": jnp.asarray(s, jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One decoder token. tokens: (B,1)."""
    pos = cache["pos"]
    x = _dec_embed(params, tokens, pos, cfg)

    def body(x, xs):
        p, kc, vc, kx, vx = xs
        h = _ln(x, p["ln1"])
        attn, kc, vc = L.decode_attention(p["self"], h, kc, vc, pos, cfg,
                                          use_rope=False)
        x = x + attn
        h = _ln(x, p["lnx"])
        x = x + L.cross_attention(p["cross"], h, (kx.astype(x.dtype), vx.astype(x.dtype)), cfg)
        h = _ln(x, p["ln2"])
        x = x + L.gelu_mlp(p["mlp"], h, cfg.cim)
        return x, (kc, vc)

    x, (k, v) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        unroll=True if cfg.scan_unroll else 1,
    )
    x = _ln(x, params["dec_ln"])
    logits = L.logits_out(params["embed"].T, x, cfg.cim)[:, 0, :]
    return logits, {"k": k, "v": v, "xk": cache["xk"], "xv": cache["xv"], "pos": pos + 1}
