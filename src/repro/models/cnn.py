"""VGG16 / ResNet18 (CIFAR variants) built from CIMConv2D - the paper's own
test networks (§V.B). Small variants exist for CPU-budget training in the
benchmarks; layer shapes of the full nets match the paper exactly.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import cim_layer as CL
from ..core.cim_layer import CIMConfig


VGG16_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M", 512, 512, 512, "M",
             512, 512, 512, "M"]
VGG_SMALL_CFG = [32, "M", 64, "M", 128, "M"]


def vgg_init(key, cfg: CIMConfig, plan: Sequence = VGG16_CFG, in_ch: int = 3,
             n_classes: int = 10, dtype=jnp.float32):
    params, states = [], []
    c = in_ch
    for i, v in enumerate(plan):
        if v == "M":
            params.append(None)
            states.append(None)
            continue
        key, sub = jax.random.split(key)
        p, s = CL.conv_init(sub, 3, 3, c, v, cfg, dtype)
        params.append(p)
        states.append(s)
        c = v
    key, sub = jax.random.split(key)
    head = {"w": jax.random.normal(sub, (c, n_classes), dtype) * (1.0 / c**0.5),
            "b": jnp.zeros((n_classes,), dtype)}
    return {"convs": params, "head": head}, {"convs": states}


def vgg_apply(params, state, x, cfg: CIMConfig, plan: Sequence = VGG16_CFG,
              train: bool = False):
    new_states = []
    i = 0
    for v, p, s in zip(plan, params["convs"], state["convs"]):
        if v == "M":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                      (1, 2, 2, 1), "VALID")
            new_states.append(None)
            continue
        x, s2 = CL.conv_apply(p, s, x, cfg, train=train)
        x = jax.nn.relu(x)
        # eq.5 assumes inputs in [0,1]; post-ReLU clip matches the paper's
        # "clip function ... instead of normalization"
        x = jnp.clip(x, 0.0, 1.0) if cfg.mode == "qat" else x
        new_states.append(s2)
        i += 1
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    logits = x @ params["head"]["w"] + params["head"]["b"]
    return logits, {"convs": new_states}


# ---------------------------------------------------------------------------
# ResNet18 (CIFAR stem)
# ---------------------------------------------------------------------------

RESNET18_STAGES = [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)]
RESNET_SMALL_STAGES = [(32, 1, 1), (64, 1, 2)]


def resnet_init(key, cfg: CIMConfig, stages=RESNET18_STAGES, in_ch: int = 3,
                n_classes: int = 10, dtype=jnp.float32):
    key, sub = jax.random.split(key)
    stem_p, stem_s = CL.conv_init(sub, 3, 3, in_ch, stages[0][0], cfg, dtype)
    blocks_p, blocks_s = [], []
    c = stages[0][0]
    for width, nblocks, stride in stages:
        for b in range(nblocks):
            s0 = stride if b == 0 else 1
            key, k1, k2, k3 = jax.random.split(key, 4)
            p1, s1 = CL.conv_init(k1, 3, 3, c, width, cfg, dtype)
            p2, s2 = CL.conv_init(k2, 3, 3, width, width, cfg, dtype)
            blk = {"conv1": p1, "conv2": p2, "stride": s0}
            st = {"conv1": s1, "conv2": s2}
            if s0 != 1 or c != width:
                pd, sd = CL.conv_init(k3, 1, 1, c, width, cfg, dtype)
                blk["down"] = pd
                st["down"] = sd
            blocks_p.append(blk)
            blocks_s.append(st)
            c = width
    key, sub = jax.random.split(key)
    head = {"w": jax.random.normal(sub, (c, n_classes), dtype) * (1.0 / c**0.5),
            "b": jnp.zeros((n_classes,), dtype)}
    return ({"stem": stem_p, "blocks": blocks_p, "head": head},
            {"stem": stem_s, "blocks": blocks_s})


def resnet_apply(params, state, x, cfg: CIMConfig, train: bool = False):
    def act(x):
        x = jax.nn.relu(x)
        return jnp.clip(x, 0.0, 1.0) if cfg.mode == "qat" else x

    x, stem_s = CL.conv_apply(params["stem"], state["stem"], x, cfg, train=train)
    x = act(x)
    new_blocks = []
    for blk, st in zip(params["blocks"], state["blocks"]):
        stride = blk["stride"]
        h, s1 = CL.conv_apply(blk["conv1"], st["conv1"], x, cfg, stride=stride,
                              train=train)
        h = act(h)
        h, s2 = CL.conv_apply(blk["conv2"], st["conv2"], h, cfg, train=train)
        ns = {"conv1": s1, "conv2": s2}
        if "down" in blk:
            x, sd = CL.conv_apply(blk["down"], st["down"], x, cfg, stride=stride,
                                  train=train)
            ns["down"] = sd
        x = act(x + h)
        new_blocks.append(ns)
    x = jnp.mean(x, axis=(1, 2))
    logits = x @ params["head"]["w"] + params["head"]["b"]
    return logits, {"stem": stem_s, "blocks": new_blocks}


# ---------------------------------------------------------------------------
# Compression-pipeline helpers (used by benchmarks/examples)
# ---------------------------------------------------------------------------


def iter_conv_params(params):
    """Yield every conv param dict in a CNN param tree."""
    if "convs" in params:
        for p in params["convs"]:
            if p is not None:
                yield p
    else:
        yield params["stem"]
        for blk in params["blocks"]:
            yield blk["conv1"]
            yield blk["conv2"]
            if "down" in blk:
                yield blk["down"]


def regularization(params, cfg: CIMConfig):
    total = jnp.zeros((), jnp.float32)
    for p in iter_conv_params(params):
        total = total + CL.conv_regularizer(p, cfg)
    return total


def prune_all(params, cfg: CIMConfig):
    """Recompute masks on every conv (in place on a copied tree)."""
    import copy

    out = copy.deepcopy(jax.tree.map(lambda x: x, params))
    if "convs" in out:
        out["convs"] = [
            CL.conv_prune(p, cfg) if p is not None else None for p in out["convs"]
        ]
    else:
        out["stem"] = CL.conv_prune(out["stem"], cfg)
        for blk in out["blocks"]:
            blk["conv1"] = CL.conv_prune(blk["conv1"], cfg)
            blk["conv2"] = CL.conv_prune(blk["conv2"], cfg)
            if "down" in blk:
                blk["down"] = CL.conv_prune(blk["down"], cfg)
    return out
