"""Architecture registry: family -> model functions, name -> ModelConfig."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict

from . import encdec, transformer
from .config import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = [
    "llava-next-34b",
    "mamba2-780m",
    "zamba2-1.2b",
    "whisper-tiny",
    "stablelm-12b",
    "yi-6b",
    "gemma3-27b",
    "granite-8b",
    "phi3.5-moe-42b-a6.6b",
    "grok-1-314b",
]

_MODULE_FOR_ID = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ModelFns:
    init_params: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def model_fns(cfg: ModelConfig) -> ModelFns:
    mod = encdec if cfg.family == "encdec" else transformer
    return ModelFns(
        init_params=mod.init_params,
        train_loss=mod.train_loss,
        prefill=mod.prefill,
        decode_step=mod.decode_step,
        init_cache=mod.init_cache,
    )


def get_config(arch: str, **overrides) -> ModelConfig:
    """Load configs/<arch>.py and apply overrides (e.g. smoke-size)."""
    modname = _MODULE_FOR_ID.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{modname}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    modname = _MODULE_FOR_ID.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{modname}")
    cfg: ModelConfig = mod.SMOKE
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def shape_config(name: str) -> ShapeConfig:
    return SHAPES[name]


def supported_cells(arch: str):
    """The assigned (arch x shape) cells, honoring the documented skips:
    long_500k only for sub-quadratic-decode archs; whisper skips long_500k."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid") or cfg.local_global_ratio > 0:
        cells.append("long_500k")
    return cells
