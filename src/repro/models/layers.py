"""Shared neural building blocks (pure JAX, pjit/GSPMD-friendly).

Every weight matmul routes through ``cim_matmul`` so the MARS technique
(eq.5 activation quant + eqs.6-8 weight quant, group-lasso structure) is a
first-class, config-gated feature of every architecture - not a bolt-on.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import deploy
from ..core import quant as Q
from ..core.cim_layer import CIMConfig

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# MARS-quantized matmul (the paper's technique on the LM fast path)
# ---------------------------------------------------------------------------


def maybe_quant_a(x: jnp.ndarray, cim: CIMConfig) -> jnp.ndarray:
    if cim.mode == "qat" and cim.quant.enabled:
        return Q.quantize_activation(x.astype(jnp.float32), cim.quant.a_bits,
                                     cim.quant.a_signed).astype(x.dtype)
    return x


def maybe_quant_w(w: jnp.ndarray, cim: CIMConfig) -> jnp.ndarray:
    if cim.mode == "qat" and cim.quant.enabled:
        wq = Q.tanh_normalize(w.astype(jnp.float32), cim.quant.group_size)
        return Q.quantize_weight_symmetric(wq, cim.quant.w_bits).astype(w.dtype)
    return w


def cim_matmul(x: jnp.ndarray, w, cim: CIMConfig) -> jnp.ndarray:
    """x @ w with MARS QAT when enabled. w: (d_in, d_out) or (E, d_in, d_out).

    ``w`` may also be a :class:`repro.core.deploy.DeployedWeight` - then the
    projection runs on the int8 BSR Pallas kernel (eq.5 activation quant +
    zero-block skip), making the compressed form the compute representation
    wherever this model code executes (prefill, decode, batch serving) - or
    a :class:`repro.core.deploy.StackedLayerView` (one layer of a uniform
    envelope, selected by a traced scan index), which runs the layer-indexed
    form of the same kernel so a ``lax.scan`` over layers is one compiled
    dispatch per step.
    """
    if isinstance(w, deploy.DeployedWeight):
        return deploy.deployed_matmul(x, w, a_bits=cim.quant.a_bits)
    if isinstance(w, deploy.StackedLayerView):
        return deploy.stacked_matmul(x, w.sw, w.layer, a_bits=cim.quant.a_bits)
    return maybe_quant_a(x, cim) @ maybe_quant_w(w, cim)


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + g.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * g + b).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, sliding-window, cross, KV-cache decode)
# ---------------------------------------------------------------------------


def _expand_kv(k: jnp.ndarray, n_heads: int, n_true: int = 0) -> jnp.ndarray:
    """(B, S, KV, dh) -> (B, S, H, dh): repeat each kv head by the TRUE
    H/KV ratio, then zero-pad up to ``n_heads`` (TP head padding - the pad
    q-heads are zero-weighted so their kv content is irrelevant)."""
    b, s, kv, dh = k.shape
    n_true = n_true or n_heads
    if kv != n_true:
        k = jnp.repeat(k, n_true // kv, axis=2)
    if n_heads > n_true:
        k = jnp.pad(k, [(0, 0), (0, 0), (0, n_heads - n_true), (0, 0)])
    return k


def attention_scores(q, k, v, mask) -> jnp.ndarray:
    """q: (B,Sq,H,dh) k,v: (B,Sk,H,dh) mask: broadcastable (B,1,Sq,Sk)."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def causal_mask(sq: int, sk: int, window=0, offset: int = 0):
    """(1,1,Sq,Sk) causal (+sliding window) mask. ``window`` may be a traced
    per-layer scalar (gemma3 local/global pattern under scan); <=0 = full.
    ``offset`` = absolute position of query 0 minus key 0."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    w = jnp.asarray(window)
    m = m & ((w <= 0) | (kj > qi - w))
    return m[None, None]


def qkv_project(p: dict, x: jnp.ndarray, cfg, cim: CIMConfig):
    b, s, _ = x.shape
    nh = getattr(cfg, "n_heads_eff", cfg.n_heads)
    nkv = getattr(cfg, "n_kv_heads_eff", cfg.n_kv_heads)
    q = cim_matmul(x, p["wq"].astype(x.dtype), cim).reshape(b, s, nh, cfg.dh)
    k = cim_matmul(x, p["wk"].astype(x.dtype), cim).reshape(b, s, nkv, cfg.dh)
    v = cim_matmul(x, p["wv"].astype(x.dtype), cim).reshape(b, s, nkv, cfg.dh)
    return q, k, v


def chunked_attention(q, k, v, n_heads: int, chunk: int, window=0,
                      offset: int = 0, n_true: int = 0,
                      unroll: bool = False) -> jnp.ndarray:
    """Online-softmax (flash-style) attention over KV chunks.

    Never materializes the (Sq, Sk) score matrix - the beyond-paper memory
    optimization of EXPERIMENTS.md §Perf. q: (B,Sq,H,dh); k, v: (B,Sk,KV,dh)
    un-expanded (GQA expansion happens per chunk). Causal with optional
    sliding window; ``offset`` = absolute position of q row 0 minus k row 0.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    pad = (-sk) % chunk
    if pad:
        cfgp = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k = jnp.pad(k, cfgp)
        v = jnp.pad(v, cfgp)
    nc = k.shape[1] // chunk
    kc = k.reshape(b, nc, chunk, *k.shape[2:]).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, chunk, *v.shape[2:]).transpose(1, 0, 2, 3, 4)
    qi = (jnp.arange(sq) + offset)[:, None]  # (Sq, 1)
    w = jnp.asarray(window)
    scale = 1.0 / jnp.sqrt(dh)
    q32 = q.astype(jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kj0, kcj, vcj = inp
        ke = _expand_kv(kcj, n_heads, n_true).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, ke) * scale  # (B,H,Sq,C)
        kj = kj0 + jnp.arange(chunk)[None, :]
        mask = (kj <= qi) & ((w <= 0) | (kj > qi - w)) & (kj < sk)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)  # (B,H,Sq)
        l = l * alpha + jnp.sum(p, axis=-1)
        ve = _expand_kv(vcj, n_heads, n_true).astype(jnp.float32)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p, ve)
        acc = acc * alpha.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l, acc), None

    init = (
        jnp.full((b, n_heads, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, n_heads, sq), jnp.float32),
        jnp.zeros((b, sq, n_heads, dh), jnp.float32),
    )
    starts = jnp.arange(nc) * chunk
    (m, l, acc), _ = jax.lax.scan(body, init, (starts, kc, vc),
                                  unroll=True if unroll else 1)
    out = acc / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
    return out.astype(q.dtype)


def self_attention(p: dict, x: jnp.ndarray, cfg, window: int = 0,
                   positions: Optional[jnp.ndarray] = None,
                   use_rope: bool = True) -> Tuple[jnp.ndarray, Tuple]:
    """Full-sequence self-attention (train / prefill). Returns (y, (k, v))."""
    b, s, d = x.shape
    nh = getattr(cfg, "n_heads_eff", cfg.n_heads)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = qkv_project(p, x, cfg, cfg.cim)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    chunk = getattr(cfg, "attn_chunk", 0)
    # chunking pays when S^2 scores dominate; at short S the extra f32
    # accumulator traffic + remat-over-scan recompute outweighs it
    # (measured: grok train_4k memory 3.0s -> 6.9s with chunking at S=4096)
    if chunk and s >= max(4 * chunk, 8192):
        o = chunked_attention(q, k, v, nh, chunk, window=window,
                              n_true=cfg.n_heads,
                              unroll=getattr(cfg, "scan_unroll", False))
    else:
        mask = causal_mask(s, s, window)
        o = attention_scores(q, _expand_kv(k, nh, cfg.n_heads),
                             _expand_kv(v, nh, cfg.n_heads), mask)
    y = cim_matmul(o.reshape(b, s, nh * cfg.dh), p["wo"].astype(x.dtype), cfg.cim)
    return y, (k, v)


def bidir_attention(p: dict, x: jnp.ndarray, cfg, use_rope: bool = False):
    """Encoder self-attention (no mask)."""
    b, s, d = x.shape
    nh = getattr(cfg, "n_heads_eff", cfg.n_heads)
    q, k, v = qkv_project(p, x, cfg, cfg.cim)
    if use_rope:
        pos = jnp.arange(s)[None, :]
        q, k = rope(q, pos, cfg.rope_theta), rope(k, pos, cfg.rope_theta)
    mask = jnp.ones((1, 1, s, s), dtype=bool)
    o = attention_scores(q, _expand_kv(k, nh, cfg.n_heads),
                         _expand_kv(v, nh, cfg.n_heads), mask)
    return cim_matmul(o.reshape(b, s, -1), p["wo"].astype(x.dtype), cfg.cim)


def cross_attention(p: dict, x: jnp.ndarray, enc_kv: Tuple, cfg) -> jnp.ndarray:
    """Decoder cross-attention against precomputed encoder K/V."""
    b, s, d = x.shape
    nh = getattr(cfg, "n_heads_eff", cfg.n_heads)
    q = cim_matmul(x, p["wq"].astype(x.dtype), cfg.cim).reshape(b, s, nh, cfg.dh)
    k, v = enc_kv
    mask = jnp.ones((1, 1, s, k.shape[1]), dtype=bool)
    o = attention_scores(q, _expand_kv(k, nh, cfg.n_heads),
                         _expand_kv(v, nh, cfg.n_heads), mask)
    return cim_matmul(o.reshape(b, s, -1), p["wo"].astype(x.dtype), cfg.cim)


def decode_attention(p: dict, x1: jnp.ndarray, kcache: jnp.ndarray,
                     vcache: jnp.ndarray, pos: jnp.ndarray, cfg,
                     window: int = 0, use_rope: bool = True, ring: bool = False):
    """One-token decode. x1: (B,1,D); caches (B,Smax,KV,dh); pos: scalar
    absolute position. ``ring=True`` treats the cache as a ring buffer of
    the sliding window (write at pos % Smax, attend all valid slots).
    Returns (y, new_kcache, new_vcache)."""
    b, _, d = x1.shape
    smax = kcache.shape[1]
    q, k, v = qkv_project(p, x1, cfg, cfg.cim)
    if use_rope:
        pp = jnp.full((1, 1), pos)
        q, k = rope(q, pp, cfg.rope_theta), rope(k, pp, cfg.rope_theta)
    wpos = pos % smax if ring else pos
    kcache = jax.lax.dynamic_update_slice(kcache, k.astype(kcache.dtype), (0, wpos, 0, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, v.astype(vcache.dtype), (0, wpos, 0, 0))
    kj = jnp.arange(smax)[None, None, None, :]
    if ring:
        mask = kj < jnp.minimum(pos + 1, smax)
    else:
        mask = kj <= pos
        w = jnp.asarray(window)
        mask = mask & ((w <= 0) | (kj > pos - w))
    nh = getattr(cfg, "n_heads_eff", cfg.n_heads)
    o = attention_scores(
        q, _expand_kv(kcache.astype(x1.dtype), nh, cfg.n_heads),
        _expand_kv(vcache.astype(x1.dtype), nh, cfg.n_heads), mask
    )
    y = cim_matmul(o.reshape(b, 1, -1), p["wo"].astype(x1.dtype), cfg.cim)
    return y, kcache, vcache


def decode_attention_multi(p: dict, xt: jnp.ndarray, kview: jnp.ndarray,
                           vview: jnp.ndarray, pos: jnp.ndarray, cfg,
                           window: int = 0, use_rope: bool = True):
    """Multi-token decode with PER-ROW positions over a gathered KV view.

    The continuous-batching engine serves slots at different depths in one
    step: row b's ``T`` query tokens sit at absolute positions
    ``pos[b] .. pos[b]+T-1`` (T=1 is the ordinary decode step; T>1 is the
    speculative verify pass, a prefill-style causal pass over the draft
    run). ``kview``/``vview`` (B, Sv, KV, dh) are the paged KV blocks
    gathered contiguously for this step (logical positions 0..Sv-1); the
    query tokens' own K/V are written into the view before attending, and
    positions beyond each query's own position hold stale or scratch data
    masked out causally, so the view length only has to cover the deepest
    active row. Returns (y, k_new, v_new) where k_new/v_new (B, T, KV, dh)
    are the query tokens' cache entries for the pool write-back - the view
    itself is a throwaway gather (the caller commits only the entries it
    accepts, which is how speculative rejection rolls back)."""
    b, t, _ = xt.shape
    q, k, v = qkv_project(p, xt, cfg, cfg.cim)
    pp = pos[:, None] + jnp.arange(t)[None, :]  # (B, T) absolute positions
    if use_rope:
        q, k = rope(q, pp, cfg.rope_theta), rope(k, pp, cfg.rope_theta)
    rows = jnp.arange(b)[:, None]
    kview = kview.at[rows, pp].set(k.astype(kview.dtype))
    vview = vview.at[rows, pp].set(v.astype(vview.dtype))
    kj = jnp.arange(kview.shape[1])[None, None, None, :]
    pe = pp[:, None, :, None]  # (B, 1, T, 1) per-query positions
    mask = kj <= pe
    w = jnp.asarray(window)
    mask = mask & ((w <= 0) | (kj > pe - w))
    nh = getattr(cfg, "n_heads_eff", cfg.n_heads)
    o = attention_scores(
        q, _expand_kv(kview.astype(xt.dtype), nh, cfg.n_heads),
        _expand_kv(vview.astype(xt.dtype), nh, cfg.n_heads), mask
    )
    y = cim_matmul(o.reshape(b, t, -1), p["wo"].astype(xt.dtype), cfg.cim)
    return y, k, v


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def gated_mlp(p: dict, x: jnp.ndarray, cim: CIMConfig, act=jax.nn.silu) -> jnp.ndarray:
    h = act(cim_matmul(x, p["w_gate"].astype(x.dtype), cim)) * cim_matmul(
        x, p["w_up"].astype(x.dtype), cim
    )
    return cim_matmul(h, p["w_down"].astype(x.dtype), cim)


def gelu_mlp(p: dict, x: jnp.ndarray, cim: CIMConfig) -> jnp.ndarray:
    h = jax.nn.gelu(cim_matmul(x, p["w_up"].astype(x.dtype), cim))
    return cim_matmul(h, p["w_down"].astype(x.dtype), cim)


def moe_block(p: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE with grouped capacity dispatch (Switch/GSPMD style).

    x: (B, S, D). Experts (E, D, FF) are expert-parallel; the one-hot
    dispatch einsums lower to all-to-alls under GSPMD. Token groups bound
    the dispatch tensor size; capacity is per group. Returns (y, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    gs = min(getattr(cfg, "moe_group_size", 512), s)
    ng = s // gs
    cap = max(k, int(cfg.capacity_factor * gs * k / e))

    xg = x.reshape(b, ng, gs, d)
    router_logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates = jax.nn.softmax(router_logits, axis=-1)  # (b, ng, gs, e)
    gate_k, idx_k = jax.lax.top_k(gates, k)  # (b, ng, gs, k)
    gate_k = gate_k / (jnp.sum(gate_k, axis=-1, keepdims=True) + 1e-9)

    # slot position of each (token, choice) within its expert's capacity
    onehot = jax.nn.one_hot(idx_k, e, dtype=jnp.float32)  # (b,ng,gs,k,e)
    pos_in_expert = jnp.cumsum(onehot.reshape(b, ng, gs * k, e), axis=2) - 1.0
    pos_in_expert = pos_in_expert.reshape(b, ng, gs, k, e)
    slot = jnp.sum(pos_in_expert * onehot, axis=-1)  # (b,ng,gs,k)
    keep = slot < cap
    gate_k = gate_k * keep

    slot_oh = jax.nn.one_hot(slot.astype(jnp.int32), cap, dtype=jnp.float32)
    # dispatch: (b, ng, gs, e, cap)
    dispatch = jnp.einsum("bnske,bnskc->bnsec", onehot * keep[..., None], slot_oh)
    combine = jnp.einsum("bnsk,bnske,bnskc->bnsec", gate_k, onehot, slot_oh)

    if getattr(cfg, "moe_hints", False):
        # keep the one-hot routing tensors batch-sharded; the expert
        # all-to-all happens at the xin/out einsums, not during routing
        # construction (otherwise GSPMD replicates these multi-GiB tensors
        # on every device - "involuntary full rematerialization")
        from jax.sharding import PartitionSpec as _PS
        hint = lambda t: jax.lax.with_sharding_constraint(
            t, _PS("data", None, None, None, None))
        dispatch = hint(dispatch)
        combine = hint(combine)

    # expert_split: each expert's FFN halves into `split` sub-experts so the
    # expert axis matches the mesh (grok: 8 experts -> 16 sub-experts).
    # down(concat(h_a, h_b)) == down_a(h_a) + down_b(h_b), so routing the
    # same tokens to both halves and summing via `combine` is exact.
    split = getattr(cfg, "expert_split", 1)
    if split > 1:
        dispatch = jnp.repeat(dispatch, split, axis=3)
        combine = jnp.repeat(combine, split, axis=3)

    xin = jnp.einsum("bnsec,bnsd->ebncd", dispatch.astype(x.dtype), xg)
    xin = maybe_quant_a(xin, cfg.cim)
    wg = maybe_quant_w(p["w_gate"].astype(x.dtype), cfg.cim)
    wu = maybe_quant_w(p["w_up"].astype(x.dtype), cfg.cim)
    wd = maybe_quant_w(p["w_down"].astype(x.dtype), cfg.cim)
    h = jax.nn.silu(jnp.einsum("ebncd,edf->ebncf", xin, wg))
    h = maybe_quant_a(h * jnp.einsum("ebncd,edf->ebncf", xin, wu), cfg.cim)
    out = jnp.einsum("ebncf,efd->ebncd", h, wd)
    y = jnp.einsum("bnsec,ebncd->bnsd", combine.astype(x.dtype), out)

    # Switch-style load-balancing auxiliary loss
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=3), axis=(0, 1, 2))  # (e,)
    frac_router = jnp.mean(gates, axis=(0, 1, 2))  # (e,)
    aux = e * jnp.sum(frac_tokens * frac_router)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed(emb: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(emb, tokens, axis=0).astype(dtype)


def logits_out(head: jnp.ndarray, x: jnp.ndarray, cim: CIMConfig) -> jnp.ndarray:
    return cim_matmul(x, head.astype(x.dtype), cim)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over unmasked positions. logits (B,S,V), labels (B,S)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / (jnp.sum(mask) + 1e-9)
    return jnp.mean(nll)
