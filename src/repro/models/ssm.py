"""Mamba2 SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked SSD for train/prefill (quadratic within chunks, linear across) and
an O(1)-state recurrent step for decode. All projections route through
``cim_matmul`` so the MARS compression applies to the SSM family too
(DESIGN.md §Arch-applicability: the recurrence itself has no weight matmul
and therefore no CIM sparsity - only the projections do).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import cim_matmul, rmsnorm


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i], -inf for j>i.
    a: (..., l) -> (..., l, l)."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # sum over (j, i]
    mask = jnp.arange(l)[:, None] >= jnp.arange(l)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
                chunk: int, h0: jnp.ndarray | None = None,
                intra_dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan. x: (B,S,H,P); a = dt*A: (B,S,H) (negative); b, c: (B,S,N)
    (single group, shared across heads). Returns (y: (B,S,H,P), h_final:
    (B,H,P,N))."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    pad = (-S) % chunk
    if pad:
        # zero-pad: a=0 -> decay exp(0)=1 and x=0 contributes nothing, so
        # padded steps pass the state through unchanged (exact)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    s_out = S
    S = S + pad
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, P)
    ac = a.reshape(B, nc, chunk, H).transpose(0, 1, 3, 2)  # (B,nc,H,l)
    bc = b.reshape(B, nc, chunk, N)
    cc = c.reshape(B, nc, chunk, N)

    a_cum = jnp.cumsum(ac, axis=-1)  # (B,nc,H,l)

    # 1) intra-chunk (the "attention-like" diagonal block). The L tensor is
    # the big one (B,nc,H,l,l); intra_dtype=bf16 halves its bytes (§Perf).
    L = jnp.exp(_segsum(ac)).astype(intra_dtype)
    y_diag = jnp.einsum("bcin,bcjn,bchij,bcjhp->bcihp",
                        cc.astype(intra_dtype), bc.astype(intra_dtype), L,
                        xc.astype(intra_dtype))

    # 2) per-chunk final states
    decay = jnp.exp(a_cum[..., -1:] - a_cum)  # (B,nc,H,l)
    states = jnp.einsum("bcln,bchl,bclhp->bchpn", bc, decay, xc)

    # 3) inter-chunk recurrence over chunk boundaries
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B,nc,H)

    def step(h, inp):
        st, dk = inp  # (B,H,P,N), (B,H)
        h_new = h * dk[..., None, None] + st
        return h_new, h  # emit the state *entering* the chunk

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_last, h_in = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # 4) contribution of the entering state to each position in the chunk
    state_decay = jnp.exp(a_cum)  # (B,nc,H,l)
    y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", cc, h_in, state_decay)

    y = (y_diag + y_off).reshape(B, S, H, P)[:, :s_out]
    # internal math promotes to f32 (decays are exp/cumsum); the block's
    # residual stream stays in the model dtype
    return y.astype(x.dtype), h_last.astype(x.dtype)


def ssd_step(h: jnp.ndarray, x1: jnp.ndarray, a1: jnp.ndarray, b1: jnp.ndarray,
             c1: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step. h: (B,H,P,N); x1: (B,H,P); a1: (B,H); b1, c1:
    (B,N). Returns (y1: (B,H,P), h_new)."""
    dtype0 = h.dtype
    da = jnp.exp(a1)[..., None, None]
    h = (h.astype(jnp.float32) * da
         + jnp.einsum("bhp,bn->bhpn", x1, b1).astype(jnp.float32)).astype(dtype0)
    y = jnp.einsum("bhpn,bn->bhp", h, c1)
    return y, h


# ---------------------------------------------------------------------------
# The full Mamba2 block (in_proj -> conv -> SSD -> gate -> out_proj)
# ---------------------------------------------------------------------------


def mamba_init(key, cfg, dtype) -> dict:
    """Per-layer parameter shapes (callers stack over L)."""
    d, di = cfg.d_model, cfg.d_inner
    H, N, W = cfg.n_ssm_heads, cfg.ssm_state, cfg.conv_width
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 6)
    s = 1.0 / (d ** 0.5)
    common = {
        "a_log": jnp.zeros((H,), jnp.float32),  # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm_g": jnp.zeros((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * (1.0 / di ** 0.5),
    }
    if getattr(cfg, "ssm_split_proj", False):
        # shard-aligned layout (§Perf): segment boundaries of the fused
        # in_proj cut across TP shards, forcing full reshards; splitting
        # into z|x / b|c / dt weights (and per-segment depthwise convs)
        # is the same math with every slice local to its shard.
        return {
            "w_zx": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
            "w_bc": jax.random.normal(ks[3], (d, 2 * N), dtype) * s,
            "w_dt": jax.random.normal(ks[4], (d, H), dtype) * s,
            "conv_xw": jax.random.normal(ks[1], (W, di), dtype) * 0.1,
            "conv_xb": jnp.zeros((di,), dtype),
            "conv_bcw": jax.random.normal(ks[5], (W, 2 * N), dtype) * 0.1,
            "conv_bcb": jnp.zeros((2 * N,), dtype),
            **common,
        }
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di + 2 * N + H), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (W, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        **common,
    }


def _split_proj(zxbcdt, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. xbc: (B,S,C); w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad, w[:, None, :], (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=xbc.shape[-1],
    )
    return jax.nn.silu(out + b)


def mamba_block(p: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, Tuple]:
    """Full-sequence forward. Returns (y, (conv_tail, h_final)) for cache."""
    Bsz, S, _ = x.shape
    di, N, H, W = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.conv_width
    P = di // H
    if "w_zx" in p:  # shard-aligned split layout (§Perf)
        zx = cim_matmul(x, p["w_zx"].astype(x.dtype), cfg.cim)
        z, xin = zx[..., :di], zx[..., di:]
        bc = cim_matmul(x, p["w_bc"].astype(x.dtype), cfg.cim)
        dt = cim_matmul(x, p["w_dt"].astype(x.dtype), cfg.cim)
        conv_tail = jnp.concatenate([xin, bc], axis=-1)[:, -(W - 1):, :]
        xin = _causal_conv(xin, p["conv_xw"].astype(x.dtype),
                           p["conv_xb"].astype(x.dtype))
        bc = _causal_conv(bc, p["conv_bcw"].astype(x.dtype),
                          p["conv_bcb"].astype(x.dtype))
        xs = xin.reshape(Bsz, S, H, P)
        b, c = bc[..., :N], bc[..., N:]
    else:
        zxbcdt = cim_matmul(x, p["in_proj"].astype(x.dtype), cfg.cim)
        z, xbc, dt = _split_proj(zxbcdt, cfg)
        conv_tail = xbc[:, -(W - 1):, :]
        xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
        xs = xbc[..., :di].reshape(Bsz, S, H, P)
        b = xbc[..., di : di + N]
        c = xbc[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])[None, None, :] * dt  # (B,S,H), negative
    y, h_last = ssd_chunked((xs * dt[..., None]).astype(x.dtype),
                            a.astype(jnp.float32),
                            b.astype(x.dtype), c.astype(x.dtype),
                            min(cfg.ssm_chunk, S),
                            intra_dtype=(jnp.bfloat16 if cfg.ssd_lowp
                                         else jnp.float32))
    y = y + xs * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(Bsz, S, di)
    y = rmsnorm(y, p["norm_g"]) * jax.nn.silu(z)
    return cim_matmul(y, p["out_proj"].astype(x.dtype), cfg.cim), (conv_tail, h_last)


def mamba_decode_step(p: dict, x1: jnp.ndarray, conv_state: jnp.ndarray,
                      h: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x1: (B,1,D); conv_state: (B,W-1,conv_dim); h:
    (B,H,P,N). Returns (y1, conv_state, h)."""
    Bsz = x1.shape[0]
    di, N, H, W = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.conv_width
    P = di // H
    if "w_zx" in p:
        zx = cim_matmul(x1, p["w_zx"].astype(x1.dtype), cfg.cim)[:, 0, :]
        z, xin = zx[..., :di], zx[..., di:]
        bc = cim_matmul(x1, p["w_bc"].astype(x1.dtype), cfg.cim)[:, 0, :]
        dt = cim_matmul(x1, p["w_dt"].astype(x1.dtype), cfg.cim)[:, 0, :]
        xbc_new = jnp.concatenate([xin, bc], axis=-1)
        window = jnp.concatenate([conv_state, xbc_new[:, None, :]], axis=1)
        conv_state = window[:, 1:, :]
        conv_w = jnp.concatenate([p["conv_xw"], p["conv_bcw"]], axis=-1)
        conv_b = jnp.concatenate([p["conv_xb"], p["conv_bcb"]], axis=-1)
        conv = jnp.einsum("bwc,wc->bc", window, conv_w.astype(x1.dtype))
        xbc = jax.nn.silu(conv + conv_b.astype(x1.dtype))
        xs = xbc[..., :di].reshape(Bsz, H, P)
        b = xbc[..., di : di + N]
        c = xbc[..., di + N :]
    else:
        zxbcdt = cim_matmul(x1, p["in_proj"].astype(x1.dtype), cfg.cim)
        z, xbc, dt = _split_proj(zxbcdt[:, 0, :], cfg)
        window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,W,C)
        conv_state = window[:, 1:, :]
        conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x1.dtype))
        xbc = jax.nn.silu(conv + p["conv_b"].astype(x1.dtype))
        xs = xbc[..., :di].reshape(Bsz, H, P)
        b = xbc[..., di : di + N]
        c = xbc[..., di + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])[None, :] * dt
    y1, h = ssd_step(h, (xs * dt[..., None]).astype(x1.dtype), a, b.astype(x1.dtype),
                     c.astype(x1.dtype))
    y1 = (y1 + xs * p["d_skip"][None, :, None].astype(x1.dtype)).astype(x1.dtype)
    y1 = y1.reshape(Bsz, 1, di)
    y1 = rmsnorm(y1, p["norm_g"]) * jax.nn.silu(z[:, None, :])
    return cim_matmul(y1, p["out_proj"].astype(x1.dtype), cfg.cim), conv_state, h
