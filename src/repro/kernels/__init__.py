# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# ``timing`` is the fenced-dispatch observability hook: call kernels
# through ``timing.DispatchTimer.timed`` to record block_until_ready'd
# wall time per (name, shape, tile, backend). Disabled by default.
from . import timing  # noqa: F401
from .timing import DispatchRecord, DispatchTimer  # noqa: F401
