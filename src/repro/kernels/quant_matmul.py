"""Dense int8-weight matmul with per-output-channel scales.

The non-sparse CIM macro (the paper's "baseline" accelerator): weights
live as int8 levels, activations stream through, dequantization happens
once per output tile after K-accumulation (scale factors out of the K
sum because MARS scales are per output group - eq. 8).

  x: (M, K)  float
  w: (K, N)  int8 levels
  scale: (N,) f32      y = (x @ w) * scale
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, s_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] * s_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(x: jnp.ndarray, w: jnp.ndarray, scale: jnp.ndarray,
                 bm: int = 128, bn: int = 128, bk: int = 128,
                 interpret: bool = True) -> jnp.ndarray:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and scale.shape == (n,)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pn:
        scale = jnp.pad(scale, (0, pn))
    mt, nt, kt = x.shape[0] // bm, w.shape[1] // bn, x.shape[1] // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=kt),
        grid=(mt, nt, kt),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]), jnp.float32),
        scratch_shapes=[pltpu_vmem((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, scale[None, :].astype(jnp.float32))
    return out[:m, :n]


def pltpu_vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
