"""Fused fake-quantization kernel (eq. 5 activations / eq. 8 weights).

QAT spends a large fraction of its elementwise budget on clamp+round+scale;
fusing it into one VMEM-tiled pass keeps the data in registers instead of
three HBM round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref, *, bits: int, signed: bool):
    x = x_ref[...].astype(jnp.float32)
    if signed:
        qmax = 2.0 ** (bits - 1) - 1.0
        y = jnp.round(jnp.clip(x, -1.0, 1.0) * qmax) / (2.0 ** (bits - 1))
    else:
        levels = 2.0**bits - 1.0
        y = jnp.round(jnp.clip(x, 0.0, 1.0) * levels) / (2.0**bits)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "signed", "bm", "bn", "interpret"))
def fake_quant(x: jnp.ndarray, bits: int, signed: bool = False,
               bm: int = 256, bn: int = 256, interpret: bool = True) -> jnp.ndarray:
    """Tiled fake-quant; arbitrary leading shape, last dim tiled."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    m, n = flat.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        flat = jnp.pad(flat, ((0, pm), (0, pn)))
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, signed=signed),
        grid=(flat.shape[0] // bm, flat.shape[1] // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=interpret,
    )(flat)
    return out[:m, :n].reshape(shape)
