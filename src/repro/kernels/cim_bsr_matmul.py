"""Block-sparse int8 matmul - the MARS zero-group-set skip, TPU-native.

This is the paper's central hardware mechanism (§III.B) re-expressed for
the TPU memory hierarchy:

  SRAM-CIM macro                      TPU kernel
  ------------------------------      -----------------------------------
  nonzero group-sets packed in        nonzero (bk x bn) weight blocks
  the 64 Kb macro (Fig. 5b)           packed densely in HBM
  16-bit index codes in Index SRAM    row_idx (SMEM, scalar-prefetched)
  SAS generates IFM addresses         BlockSpec index_map steers the x DMA
  zero group-sets never computed      padding slots masked from the MXU
  ping-pong FM SRAMs                  Pallas double-buffered VMEM pipeline

Weights are stored as int8 levels (eq. 8 output x 2^{b-1}) with one f32
scale per block; dequantization rides the VPU before the MXU matmul, so -
exactly as in MARS - no high-precision weight path exists at rest.

Layout (column-major ELL, from core.mapping.pack_bsr):
  x:       (M, K)                activations
  blocks:  (go, nnz_max, bk, bn) int8 packed nonzero blocks
  scales:  (go, nnz_max)         f32 per-block scale
  row_idx: (go, nnz_max)         int32 k-block index per slot (pad -> 0)
  nnz:     (go,)                 int32 true slot counts
  out:     (M, N=go*bn)

Grid = (M/bm, go, nnz_max); the slot axis is innermost so each output tile
stays resident in VMEM across its accumulation.

``bsr_matmul_sharded`` is the multi-macro form: the ``go`` block-column
axis is split over a ``macro`` mesh axis (one shard per device, the way one
MARS layer spans several SRAM macros), each device runs the SAME kernel on
only its resident columns, and a single tiled all-gather at the projection
boundary reassembles the (M, N) output - no cross-device weight traffic.

``bsr_matmul_stacked`` is the uniform-envelope form: L layers of one
projection, all packed to the SAME (go, nnz_max, bk, bn) geometry, stacked
along a leading layer axis. The layer id rides the scalar-prefetch channel
(next to row_idx/nnz), so the BlockSpec index maps steer every DMA into the
selected layer's slice of the stacked arrays - ONE compiled kernel serves
all L layers, and a ``lax.scan`` over the layer index never re-traces or
re-dispatches per layer. Envelope padding slots carry zero blocks AND zero
scales, so even a slot the per-layer ``nnz`` guard does not skip
contributes exactly 0 - stacking can never change numerics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


DEFAULT_BM = 128

MACRO_AXIS = "macro"  # mesh axis name for the serving macro cluster


def _kernel(row_idx_ref, nnz_ref, x_ref, blocks_ref, scales_ref, out_ref,
            *, acc_dtype):
    i, j, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(s < nnz_ref[j])
    def _accum():
        w = blocks_ref[0, 0].astype(acc_dtype) * scales_ref[0, 0]
        out_ref[...] += jnp.dot(
            x_ref[...].astype(acc_dtype), w, preferred_element_type=acc_dtype
        )


@functools.partial(
    jax.jit, static_argnames=("bm", "interpret", "acc_dtype")
)
def bsr_matmul(x: jnp.ndarray, blocks: jnp.ndarray, scales: jnp.ndarray,
               row_idx: jnp.ndarray, nnz: jnp.ndarray, bm: int = DEFAULT_BM,
               interpret: bool = True, acc_dtype=jnp.float32) -> jnp.ndarray:
    """y = x @ W for BSR-packed W. Returns (M, go*bn) in acc_dtype."""
    m, k = x.shape
    go, nnz_max, bk, bn = blocks.shape
    assert k % bk == 0, (k, bk)
    assert row_idx.shape == (go, nnz_max)
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    mt = x.shape[0] // bm

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mt, go, nnz_max),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s, ri, nz: (i, ri[j, s])),
            pl.BlockSpec((1, 1, bk, bn), lambda i, j, s, ri, nz: (j, s, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, s, ri, nz: (j, s)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s, ri, nz: (i, j)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((x.shape[0], go * bn), acc_dtype),
        interpret=interpret,
    )(row_idx, nnz, x, blocks, scales.astype(acc_dtype))
    return out[:m]


def _kernel_stacked(layer_ref, row_idx_ref, nnz_ref, x_ref, blocks_ref,
                    scales_ref, out_ref, *, acc_dtype):
    i, j, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    l = layer_ref[0]

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # the guard uses the SELECTED LAYER's true slot count; slots past it are
    # envelope padding (zero block, zero scale) and are skipped, and a
    # truncated layer (nnz > stored slots) accumulates only inert zeros
    @pl.when(s < nnz_ref[l, j])
    def _accum():
        w = blocks_ref[0, 0, 0].astype(acc_dtype) * scales_ref[0, 0, 0]
        out_ref[...] += jnp.dot(
            x_ref[...].astype(acc_dtype), w, preferred_element_type=acc_dtype
        )


@functools.partial(
    jax.jit, static_argnames=("bm", "interpret", "acc_dtype")
)
def bsr_matmul_stacked(x: jnp.ndarray, blocks: jnp.ndarray,
                       scales: jnp.ndarray, row_idx: jnp.ndarray,
                       nnz: jnp.ndarray, layer: jnp.ndarray,
                       bm: int = DEFAULT_BM, interpret: bool = True,
                       acc_dtype=jnp.float32) -> jnp.ndarray:
    """y = x @ W[layer] for a layer-stacked BSR packing.

    blocks: (L, go, nnz_max, bk, bn); scales/row_idx: (L, go, nnz_max);
    nnz: (L, go); layer: scalar (or (1,)) int32 selecting the layer. The
    layer id is a traced value - the compiled kernel is layer-agnostic and
    the grid never grows with L, so a scan over layers is one dispatch per
    step, not one per (layer, projection).
    """
    m, k = x.shape
    _, go, nnz_max, bk, bn = blocks.shape
    assert k % bk == 0, (k, bk)
    assert row_idx.shape == blocks.shape[:3]
    layer = jnp.asarray(layer, jnp.int32).reshape(1)
    pad_m = (-m) % bm
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    mt = x.shape[0] // bm

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(mt, go, nnz_max),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s, l, ri, nz: (i, ri[l[0], j, s])),
            pl.BlockSpec((1, 1, 1, bk, bn),
                         lambda i, j, s, l, ri, nz: (l[0], j, s, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, j, s, l, ri, nz: (l[0], j, s)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s, l, ri, nz: (i, j)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel_stacked, acc_dtype=acc_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((x.shape[0], go * bn), acc_dtype),
        interpret=interpret,
    )(layer, row_idx, nnz, x, blocks, scales.astype(acc_dtype))
    return out[:m]


def bsr_matmul_stacked_sharded(x: jnp.ndarray, blocks: jnp.ndarray,
                               scales: jnp.ndarray, row_idx: jnp.ndarray,
                               nnz: jnp.ndarray, layer: jnp.ndarray, *,
                               mesh: Mesh, axis: str = MACRO_AXIS,
                               bm: int = DEFAULT_BM, interpret: bool = True,
                               acc_dtype=jnp.float32) -> jnp.ndarray:
    """Tensor-parallel ``bsr_matmul_stacked``: the ``go`` axis (dim 1 of the
    stacked arrays) is sharded over ``axis``; the layer axis and ``x`` are
    replicated. Same contract as ``bsr_matmul_sharded``: output columns are
    in DEVICE order, callers un-permute with their per-layer ``col_inv``."""
    layer = jnp.asarray(layer, jnp.int32).reshape(1)

    def _local(xl, b, s, ri, nz, l):
        y = bsr_matmul_stacked(xl, b, s, ri, nz, l, bm=bm,
                               interpret=interpret, acc_dtype=acc_dtype)
        return jax.lax.all_gather(y, axis, axis=1, tiled=True)

    f = shard_map(
        _local, mesh=mesh,
        in_specs=(P(), P(None, axis, None, None, None), P(None, axis, None),
                  P(None, axis, None), P(None, axis), P()),
        out_specs=P(), check_vma=False)
    return f(x, blocks, scales, row_idx, nnz, layer)


def bsr_matmul_sharded(x: jnp.ndarray, blocks: jnp.ndarray,
                       scales: jnp.ndarray, row_idx: jnp.ndarray,
                       nnz: jnp.ndarray, *, mesh: Mesh,
                       axis: str = MACRO_AXIS, bm: int = DEFAULT_BM,
                       interpret: bool = True,
                       acc_dtype=jnp.float32) -> jnp.ndarray:
    """Tensor-parallel ``bsr_matmul`` over the ``axis`` mesh dimension.

    The block-column axis (``go``) of blocks/scales/row_idx/nnz is sharded
    over the mesh; ``x`` is replicated (every device holds the full K, so
    ``row_idx`` needs no translation). Each device accumulates only its
    resident columns' slots - the per-device ``nnz`` is its own macro
    occupancy - and one tiled all-gather on the N axis is the only
    collective. Output is the replicated (M, go*bn), columns in DEVICE
    order: callers that column-permuted the packing (LPT balancing) must
    un-permute with their ``col_inv``.
    """
    def _local(xl, b, s, ri, nz):
        y = bsr_matmul(xl, b, s, ri, nz, bm=bm, interpret=interpret,
                       acc_dtype=acc_dtype)
        return jax.lax.all_gather(y, axis, axis=1, tiled=True)

    f = shard_map(
        _local, mesh=mesh,
        in_specs=(P(), P(axis, None, None, None), P(axis, None),
                  P(axis, None), P(axis)),
        out_specs=P(), check_vma=False)
    return f(x, blocks, scales, row_idx, nnz)
