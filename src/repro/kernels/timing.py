"""Fenced kernel-dispatch timing hook.

jax dispatch is asynchronous: a wall-clock around ``bsr_matmul_stacked``
measures enqueue, not device work. This hook is the honest form - call the
kernel through :meth:`DispatchTimer.timed` and the elapsed time spans
dispatch PLUS ``jax.block_until_ready`` on every output, labeled with
``(name, shape, tile, backend)`` so per-(shape, tile, backend) costs are
separable in the report (the data the measured-latency tile autotuner,
ROADMAP item 4, consumes).

The hook lives OUTSIDE jit: timing inside a traced function is meaningless
(and would bake host callbacks into the compiled step), so callers fence at
the dispatch boundary - the serve loop does it per decode step, the gap
comparator (``repro.obs.gap.kernel_gap``) per standalone kernel call. A
disabled timer (the default ``TIMER``) forwards the call untouched: no
fence, no clock, no allocation - tracing off must not serialize the
pipeline.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    """One fenced kernel call."""

    name: str
    shape: Optional[tuple]  # activation/problem shape, caller-defined
    tile: Optional[tuple]  # (bk, bn) packing tile, None for dense dispatch
    backend: str
    seconds: float

    @property
    def key(self) -> tuple:
        return (self.name, self.shape, self.tile, self.backend)


def _dim_label(dims: Optional[tuple]) -> str:
    """``(16, 16)`` -> ``"16x16"``; None -> ``"none"`` (metric label form)."""
    if dims is None:
        return "none"
    return "x".join(str(int(d)) for d in dims)


class DispatchTimer:
    """Thread-safe fenced wall-time recorder for kernel dispatches.

    When constructed with a recording ``repro.obs.metrics`` registry, every
    record is also observed into the ``kernel_dispatch_s`` histogram labeled
    (name, shape, tile, backend) - the ServeReport metrics snapshot then
    carries per-dispatch p50/p99 without a side table."""

    def __init__(self, enabled: bool = True, metrics=None):
        self.enabled = enabled
        self.metrics = metrics
        self._lock = threading.Lock()
        self.records: List[DispatchRecord] = []

    def record(self, name: str, seconds: float, shape=None, tile=None,
               backend: Optional[str] = None) -> None:
        rec = DispatchRecord(
            name, tuple(shape) if shape is not None else None,
            tuple(tile) if tile is not None else None,
            backend if backend is not None else jax.default_backend(),
            float(seconds))
        with self._lock:
            self.records.append(rec)
        if self.metrics is not None and getattr(self.metrics, "recording", False):
            # label key is ``kernel`` (not ``name``): the registry's
            # instrument name is the positional ``name`` argument
            self.metrics.histogram(
                "kernel_dispatch_s", kernel=rec.name,
                shape=_dim_label(rec.shape), tile=_dim_label(rec.tile),
                backend=rec.backend).observe(rec.seconds)

    def timed(self, name: str, shape, tile, fn, *args, **kw):
        """Call ``fn(*args, **kw)``; when enabled, fence every output with
        ``block_until_ready`` and record the wall time under
        ``(name, shape, tile, backend)``. Disabled: a plain call."""
        if not self.enabled:
            return fn(*args, **kw)
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        out = jax.block_until_ready(out)
        self.record(name, time.perf_counter() - t0, shape=shape, tile=tile)
        return out

    def clear(self) -> None:
        with self._lock:
            self.records.clear()

    def summary(self) -> List[dict]:
        """Per-(name, shape, tile, backend) aggregate rows, JSON-ready."""
        with self._lock:
            recs = list(self.records)
        groups: Dict[tuple, List[float]] = {}
        for r in recs:
            groups.setdefault(r.key, []).append(r.seconds)
        rows = []
        for (name, shape, tile, backend), secs in sorted(
                groups.items(), key=lambda kv: repr(kv[0])):
            secs = sorted(secs)
            rows.append({
                "name": name,
                "shape": list(shape) if shape is not None else None,
                "tile": list(tile) if tile is not None else None,
                "backend": backend,
                "calls": len(secs),
                "total_s": round(sum(secs), 6),
                "min_ms": round(secs[0] * 1e3, 4),
                "p50_ms": round(secs[len(secs) // 2] * 1e3, 4),
                "max_ms": round(secs[-1] * 1e3, 4),
            })
        return rows


# module-level default, DISABLED: importing this hook never slows a caller
# that does not opt in
TIMER = DispatchTimer(enabled=False)
