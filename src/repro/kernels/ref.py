"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def bsr_dense(blocks, scales, row_idx, nnz, k_dim: int) -> jnp.ndarray:
    """Reconstruct the dense (K, N) weight from the packed BSR arrays."""
    go, nnz_max, bk, bn = blocks.shape
    w = np.zeros((k_dim, go * bn), dtype=np.float32)
    blocks = np.asarray(blocks, dtype=np.float32)
    scales = np.asarray(scales, dtype=np.float32)
    row_idx = np.asarray(row_idx)
    nnz = np.asarray(nnz)
    for j in range(go):
        for s in range(int(nnz[j])):
            i = int(row_idx[j, s])
            w[i * bk : (i + 1) * bk, j * bn : (j + 1) * bn] = blocks[j, s] * scales[j, s]
    return jnp.asarray(w)


def bsr_matmul_ref(x, blocks, scales, row_idx, nnz) -> jnp.ndarray:
    w = bsr_dense(blocks, scales, row_idx, nnz, x.shape[1])
    return x.astype(jnp.float32) @ w


def quant_matmul_ref(x, w_int8, scale) -> jnp.ndarray:
    return (x.astype(jnp.float32) @ w_int8.astype(jnp.float32)) * scale[None, :]


def fake_quant_ref(x, bits: int, signed: bool = False) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    if signed:
        qmax = 2.0 ** (bits - 1) - 1.0
        y = jnp.round(jnp.clip(x32, -1.0, 1.0) * qmax) / (2.0 ** (bits - 1))
    else:
        levels = 2.0**bits - 1.0
        y = jnp.round(jnp.clip(x32, 0.0, 1.0) * levels) / (2.0**bits)
    return y.astype(x.dtype)


def ssd_intra_ref(a, b, c, x):
    """Oracle for ssd_intra_chunk. a: (C,H,l); b,c: (C,l,N); x: (C,l,H,P)."""
    import numpy as np

    a = np.asarray(a, np.float64)
    C, H, l = a.shape
    cum = np.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    causal = np.tril(np.ones((l, l), bool))
    L = np.where(causal, np.exp(diff), 0.0)  # (C,H,l,l)
    s = np.einsum("cin,cjn->cij", np.asarray(c, np.float64),
                  np.asarray(b, np.float64))
    y = np.einsum("chij,cij,cjhp->cihp", L, s, np.asarray(x, np.float64))
    return jnp.asarray(y, x.dtype)
