"""Fused SSD intra-chunk kernel (Mamba2's quadratic block, VMEM-resident).

EXPERIMENTS.md §Perf cell 2 identified the SSD intra-chunk computation as
mamba2's dominant memory term: in pure JAX the (B, nc, H, l, l) decay/score
product materializes in HBM three times (s, s*L, backward). This kernel is
the Pallas fix: for one (batch*chunk, head) grid cell the whole chain

    s   = C @ B^T                  (l, l)
    L   = exp(segsum(a))           (l, l)  causal decay
    y   = (s * L) @ (x * dt)       (l, P)

stays in VMEM - HBM traffic drops from O(l^2) to O(l*(N+P)) per tile,
the same insight as flash attention (and as MARS's ping-pong FM SRAMs:
intermediates live in the near-compute memory, never the big one).

Shapes per grid cell (c = flattened batch*chunk index, h = head):
  a:  (l,)  post-discretization decay logits (dt * A, negative)
  b:  (l, N), c_in: (l, N)  shared across heads (single group)
  x:  (l, P)  head slice of (x * dt)
  y:  (l, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, c_ref, x_ref, y_ref):
    l = a_ref.shape[-1]
    a = a_ref[0, 0].astype(jnp.float32)  # (l,)
    cum = jnp.cumsum(a)
    diff = cum[:, None] - cum[None, :]  # segsum: sum a[(j, i]]
    causal = jnp.arange(l)[:, None] >= jnp.arange(l)[None, :]
    ldecay = jnp.where(causal, jnp.exp(diff), 0.0)  # (l, l)
    s = jnp.dot(c_ref[0].astype(jnp.float32),
                b_ref[0].astype(jnp.float32).T,
                preferred_element_type=jnp.float32)  # (l, l)
    y = jnp.dot(s * ldecay, x_ref[0, 0].astype(jnp.float32),
                preferred_element_type=jnp.float32)  # (l, P)
    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
                    x: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Batched intra-chunk SSD.

    a: (C, H, l); b, c: (C, l, N); x: (C, l, H, P)  ->  y: (C, l, H, P)
    where C = batch*num_chunks flattened. Grid = (C, H): one chunk-head
    tile per step; b/c re-read per head (they are small: l x N).
    """
    C, H, l = a.shape
    N = b.shape[-1]
    P = x.shape[-1]
    xt = x.transpose(0, 2, 1, 3)  # (C, H, l, P)

    y = pl.pallas_call(
        _kernel,
        grid=(C, H),
        in_specs=[
            pl.BlockSpec((1, 1, l), lambda i, h: (i, h, 0)),
            pl.BlockSpec((1, l, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, l, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, 1, l, P), lambda i, h: (i, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, P), lambda i, h: (i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, H, l, P), x.dtype),
        interpret=interpret,
    )(a.transpose(0, 1, 2), b, c, xt)
    return y.transpose(0, 2, 1, 3)  # (C, l, H, P)
