"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels target TPU v5e and are validated in interpret mode per the spec).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mapping import BsrWeight, pack_bsr
from ..core.quant import weight_int_levels
from . import cim_bsr_matmul, fake_quant as _fq, quant_matmul as _qm, ssd_intra as _ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


# ---------------------------------------------------------------------------
# Deployment packing: quantized dense weight -> device arrays for the kernel
# ---------------------------------------------------------------------------


def pack_for_kernel(w_q: np.ndarray, bits: int, bk: int = 128, bn: int = 128
                    ) -> dict:
    """Take eq.8 output (float levels/2^{b-1}) and produce the kernel's
    int8-blocks + scales + index arrays. Zero blocks are dropped (the CIM
    skip). Returns a dict of jnp arrays."""
    scale = 1.0 / (2.0 ** (bits - 1))
    levels = np.asarray(np.round(np.asarray(w_q, np.float64) / scale), np.int8)
    bsr = pack_bsr(levels, bk, bn)
    go, nnz_max = bsr.row_idx.shape
    scales = np.full((go, nnz_max), scale, np.float32)
    return {
        "blocks": jnp.asarray(bsr.blocks),
        "scales": jnp.asarray(scales),
        "row_idx": jnp.asarray(bsr.row_idx),
        "nnz": jnp.asarray(bsr.nnz),
        "density": bsr.density,
    }


def bsr_matmul(x, packed: dict, bm: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return cim_bsr_matmul.bsr_matmul(
        x, packed["blocks"], packed["scales"], packed["row_idx"], packed["nnz"],
        bm=bm, interpret=interpret,
    )


def bsr_matmul_sharded(x, packed: dict, mesh, bm: int = 128,
                       interpret: bool | None = None,
                       axis: str = cim_bsr_matmul.MACRO_AXIS):
    """Macro-cluster tensor-parallel bsr_matmul over a column-sharded
    packed dict (see ``core.deploy.shard_weight``). Output columns are in
    device order - the caller un-permutes with ``packed['col_inv']``."""
    if interpret is None:
        interpret = default_interpret()
    return cim_bsr_matmul.bsr_matmul_sharded(
        x, packed["blocks"], packed["scales"], packed["row_idx"], packed["nnz"],
        mesh=mesh, axis=axis, bm=bm, interpret=interpret,
    )


def bsr_matmul_stacked(x, blocks, scales, row_idx, nnz, layer,
                       bm: int = 128, interpret: bool | None = None):
    """Layer-indexed matmul over a uniform-envelope layer stack (see
    ``core.deploy.stack_deployed``). ``layer`` is a traced int32 scalar -
    one compiled kernel serves every layer of the stack."""
    if interpret is None:
        interpret = default_interpret()
    return cim_bsr_matmul.bsr_matmul_stacked(
        x, blocks, scales, row_idx, nnz, layer, bm=bm, interpret=interpret,
    )


def bsr_matmul_stacked_sharded(x, blocks, scales, row_idx, nnz, layer, mesh,
                               bm: int = 128, interpret: bool | None = None,
                               axis: str = cim_bsr_matmul.MACRO_AXIS):
    """Macro-cluster tensor-parallel ``bsr_matmul_stacked``. Output columns
    are in device order - the caller un-permutes with the stack's per-layer
    ``col_inv`` row."""
    if interpret is None:
        interpret = default_interpret()
    return cim_bsr_matmul.bsr_matmul_stacked_sharded(
        x, blocks, scales, row_idx, nnz, layer, mesh=mesh, axis=axis, bm=bm,
        interpret=interpret,
    )


def quant_matmul(x, w_int8, scale, interpret: bool | None = None, **kw):
    if interpret is None:
        interpret = default_interpret()
    return _qm.quant_matmul(x, w_int8, scale, interpret=interpret, **kw)


def fake_quant(x, bits: int, signed: bool = False, interpret: bool | None = None, **kw):
    if interpret is None:
        interpret = default_interpret()
    return _fq.fake_quant(x, bits, signed=signed, interpret=interpret, **kw)


def ssd_intra(a, b, c, x, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _ssd.ssd_intra_chunk(a, b, c, x, interpret=interpret)
