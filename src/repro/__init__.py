"""repro: MARS (multi-macro SRAM-CIM accelerator + co-designed compression)
reproduced as a production-grade JAX training/serving framework."""

__version__ = "0.1.0"
