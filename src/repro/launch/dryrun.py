import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

__doc__ = """Multi-pod dry-run + roofline extraction (deliverables e, g).

For every (architecture x input shape) cell and mesh:
  1. full scanned-program ``jit(step).lower(**specs).compile()`` - proves
     the distribution config is coherent (sharding, collectives, memory);
  2. reduced-depth UNROLLED compiles at one and two pattern-periods for
     exact per-layer FLOPs/bytes/collective-bytes (XLA cost analysis counts
     a while-loop body once, so scanned programs under-report; DESIGN.md §6);
  3. roofline terms vs TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
     ~50 GB/s/link ICI.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out results.json
"""


import argparse
import dataclasses
import json
import re
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import registry, transformer
from ..models.config import ModelConfig, SHAPES, ShapeConfig
from ..train import optimizer as optim
from ..train.trainer import TrainConfig, make_train_step
from . import shardings as SH
from .mesh import make_production_mesh

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

# wire-volume factor per collective kind (ring algorithms, asymptotic)
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_OP_RE = re.compile(
    r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device result bytes x wire factor, summed per collective kind.

    Parses optimized HLO lines like
      %all-reduce.3 = bf16[16,4096]{1,0} all-reduce(...)
    including tuple results and layout suffixes; async ``-start`` counted
    once, ``-done`` skipped."""
    out = {k: 0.0 for k in _WIRE_FACTOR}
    counts = {k: 0 for k in _WIRE_FACTOR}
    for line in hlo_text.splitlines():
        if "-done" in line or "=" not in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        eq = line.index("=")
        lhs = line[eq + 1 : m.start()]
        total = 0
        for sm in _SHAPE_RE.finditer(lhs):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] += total * _WIRE_FACTOR[kind]
        counts[kind] += 1
    out["_counts"] = counts
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    S = shape.seq_len
    sd = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        batch = {"tokens": sd((B, 1), jnp.int32)}
    else:
        s_text = S - cfg.n_patches if cfg.family == "vlm" else S
        batch = {"tokens": sd((B, s_text), jnp.int32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sd((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            batch["frames"] = sd((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


def _params_shape(cfg: ModelConfig):
    fns = registry.model_fns(cfg)
    return jax.eval_shape(lambda: fns.init_params(cfg, jax.random.PRNGKey(0)))


def active_param_count(cfg: ModelConfig) -> float:
    """N (active) for MODEL_FLOPS = 6*N*D / 2*N*D. Embedding tables excluded;
    MoE expert weights scaled by top_k/n_experts."""
    shapes = _params_shape(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = 0.0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1] if keys else ""
        if name in ("embed", "pos_dec") or leaf.ndim < 2:
            continue
        n = float(np.prod(leaf.shape))
        if cfg.family == "moe" and name in ("w_gate", "w_up", "w_down"):
            n *= cfg.top_k / cfg.n_experts
        total += n
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------
# Step construction with shardings
# ---------------------------------------------------------------------------


def _train_setup(cfg: ModelConfig, shape: ShapeConfig, mesh, opt=False,
                 grad_accum: int = 1):
    tcfg = TrainConfig(opt=optim.OptConfig(kind="adamw", clip_norm=1.0),
                       grad_accum=grad_accum)
    fns = registry.model_fns(cfg)
    pshape = _params_shape(cfg)
    pspecs = SH.param_specs(cfg, mesh.shape["model"], opt=opt)
    ospecs = {"m": SH.zero1_specs(pspecs, pshape, mesh),
              "v": SH.zero1_specs(pspecs, pshape, mesh)}
    state_shape = {
        "params": pshape,
        "opt": jax.eval_shape(lambda: optim.init_state(tcfg.opt, pshape)),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_specs = {"params": pspecs, "opt": ospecs, "step": P()}
    batch = input_specs(cfg, shape)
    bspecs = SH.batch_specs(cfg, mesh, shape)
    step = make_train_step(cfg, tcfg)
    in_sh = (SH.to_named(state_specs, mesh), SH.to_named(bspecs, mesh))
    metrics_specs = {"lr": P(), "grad_norm": P(), "loss": P()}
    out_sh = (SH.to_named(state_specs, mesh), SH.to_named(metrics_specs, mesh))
    args = (state_shape, batch)
    return step, args, in_sh, out_sh


def _prefill_setup(cfg: ModelConfig, shape: ShapeConfig, mesh, opt=False):
    fns = registry.model_fns(cfg)
    batch = input_specs(cfg, shape)
    bspecs = SH.batch_specs(cfg, mesh, shape)
    B = shape.global_batch
    dp = SH.data_axes(mesh)
    vx = "model" if cfg.vocab_eff % mesh.shape["model"] == 0 and cfg.vocab_eff == cfg.vocab else None
    logits_spec = P(dp if B > 1 else None, vx)
    cache_shape = jax.eval_shape(
        lambda p, b: fns.prefill(p, b, cfg), _params_shape(cfg), batch
    )[1]
    cspecs = SH.cache_specs(cfg, mesh, B, opt=opt)
    cspecs = {k: cspecs[k] for k in cache_shape}  # prefill cache key subset

    def step(params, batch):
        return fns.prefill(params, batch, cfg)

    in_sh = (SH.to_named(SH.param_specs(cfg, mesh.shape["model"], opt=opt), mesh),
             SH.to_named(bspecs, mesh))
    out_sh = (NamedSharding(mesh, logits_spec), SH.to_named(cspecs, mesh))
    args = (_params_shape(cfg), batch)
    return step, args, in_sh, out_sh


def _decode_setup(cfg: ModelConfig, shape: ShapeConfig, mesh, opt=False):
    fns = registry.model_fns(cfg)
    B = shape.global_batch
    dp = SH.data_axes(mesh)
    cache_shape = jax.eval_shape(
        lambda: fns.init_cache(cfg, B, max_len=shape.seq_len)
    )
    cspecs = SH.cache_specs(cfg, mesh, B, opt=opt)
    cspecs = {k: cspecs[k] for k in cache_shape}
    batch = input_specs(cfg, shape)
    tok_spec = P(dp, None) if B > 1 else P(None, None)
    vx = "model" if cfg.vocab_eff % mesh.shape["model"] == 0 and cfg.vocab_eff == cfg.vocab else None
    logits_spec = P(dp if B > 1 else None, vx)

    def step(params, cache, tokens):
        return fns.decode_step(params, cache, tokens, cfg)

    in_sh = (SH.to_named(SH.param_specs(cfg, mesh.shape["model"], opt=opt), mesh),
             SH.to_named(cspecs, mesh), NamedSharding(mesh, tok_spec))
    out_sh = (NamedSharding(mesh, logits_spec), SH.to_named(cspecs, mesh))
    args = (_params_shape(cfg), cache_shape, batch["tokens"])
    return step, args, in_sh, out_sh


OPT_OVERRIDES = dict(attn_chunk=1024, head_pad=16, moe_group_size=128,
                     capacity_factor=1.0, ssm_chunk=128, ssd_lowp=True,
                     ssm_split_proj=True, vocab_pad_multiple=256)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, opt: bool = False,
               grad_accum: int = 1):
    if shape.kind == "train":
        return _train_setup(cfg, shape, mesh, opt=opt, grad_accum=grad_accum)
    if shape.kind == "prefill":
        return _prefill_setup(cfg, shape, mesh, opt=opt)
    return _decode_setup(cfg, shape, mesh, opt=opt)


def compile_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 donate: bool = True, opt: bool = False, grad_accum: int = 1):
    step, args, in_sh, out_sh = build_cell(cfg, shape, mesh, opt=opt,
                                           grad_accum=grad_accum)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


# ---------------------------------------------------------------------------
# Cost extrapolation (scan-aware; DESIGN.md §6)
# ---------------------------------------------------------------------------


def _pattern_period(cfg: ModelConfig) -> int:
    if cfg.local_global_ratio > 0:
        return cfg.local_global_ratio + 1
    if cfg.family == "hybrid":
        return cfg.attn_every
    return 1


def _reduced(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    kw = dict(n_layers=n_layers, scan_unroll=True)
    if cfg.family == "encdec":
        kw["enc_layers"] = max(1, cfg.enc_layers * n_layers // cfg.n_layers)
    return dataclasses.replace(cfg, **kw)


def cost_terms(cfg: ModelConfig, shape: ShapeConfig, mesh, opt: bool = False) -> dict:
    """FLOPs / bytes / collective bytes per device, extrapolated to depth L."""
    p = _pattern_period(cfg)
    cfg_a, cfg_b = _reduced(cfg, p), _reduced(cfg, 2 * p)

    def measure(c):
        _, comp = compile_cell(c, shape, mesh, opt=opt)
        ca = comp.cost_analysis()
        coll = collective_bytes(comp.as_text())
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": sum(v for k, v in coll.items() if k != "_counts"),
            "coll_by_kind": {k: v for k, v in coll.items() if k != "_counts"},
            "coll_counts": coll["_counts"],
        }

    a = measure(cfg_a)
    b = measure(cfg_b)
    scale = (cfg.n_layers - p) / p

    def extra(ka, kb):
        # per-period deltas can be slightly negative on tiny decode graphs
        # (constant folding differs between depths); clamp at zero
        return ka + max(kb - ka, 0.0) * scale

    return {
        "flops": extra(a["flops"], b["flops"]),
        "bytes": extra(a["bytes"], b["bytes"]),
        "coll": extra(a["coll"], b["coll"]),
        "coll_by_kind": {
            k: extra(a["coll_by_kind"][k], b["coll_by_kind"][k])
            for k in a["coll_by_kind"]
        },
        "coll_counts_1period": a["coll_counts"],
        "period": p,
    }


def roofline(cfg: ModelConfig, shape: ShapeConfig, mesh, chips: int,
             opt: bool = False) -> dict:
    costs = cost_terms(cfg, shape, mesh, opt=opt)
    t_compute = costs["flops"] / PEAK_FLOPS  # per-device flops / chip peak
    t_memory = costs["bytes"] / HBM_BW
    t_coll = costs["coll"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = costs["flops"] * chips
    return {
        **costs,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        # fraction of roofline-minimum time spent on the useful math
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / max(
            max(terms.values()), 1e-12
        ),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, cim: bool = False,
             with_roofline: bool = True, opt: bool = False) -> dict:
    cfg = registry.get_config(arch)
    if cim:
        cfg = dataclasses.replace(cfg, cim_mode="qat", w_bits=8, a_bits=8,
                                  lambda_g=1e-5)
    if opt:
        cfg = dataclasses.replace(cfg, **OPT_OVERRIDES)
        if cfg.family in ("dense", "vlm"):
            # Megatron-SP residual: confirmed win for dense/vlm TP; REFUTED
            # for MoE (conflicts with dispatch grouping: grok coll 3.7->171s)
            cfg = dataclasses.replace(cfg, seq_shard_residual=True)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    jax.set_mesh(mesh)  # with_sharding_constraint needs a context mesh
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered, compiled = compile_cell(cfg, shape, mesh, opt=opt)
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "cim": cim, "opt": opt,
        "compile_s": round(t_compile, 1),
        "argument_bytes_per_dev": int(ma.argument_size_in_bytes),
        "output_bytes_per_dev": int(ma.output_size_in_bytes),
        "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
        "peak_bytes_per_dev": int(ma.argument_size_in_bytes
                                  + ma.temp_size_in_bytes),
    }
    if with_roofline:
        rec.update(roofline(cfg, shape, mesh, chips, opt=opt))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--cim", action="store_true",
                    help="enable the MARS QAT path in the compiled graph")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimization set (EXPERIMENTS.md §Perf)")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if args.arch == "all" else [args.arch]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    results, failures = [], []
    for arch in archs:
        shapes = (registry.supported_cells(arch) if args.shape == "all"
                  else [args.shape])
        for shape_name in shapes:
            for mp in pods:
                tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape_name, mp, cim=args.cim,
                                   opt=args.opt,
                                   with_roofline=not args.no_roofline and not mp)
                    results.append(rec)
                    extra = ""
                    if "t_compute_s" in rec:
                        extra = (f" compute={rec['t_compute_s']*1e3:.2f}ms"
                                 f" memory={rec['t_memory_s']*1e3:.2f}ms"
                                 f" coll={rec['t_collective_s']*1e3:.2f}ms"
                                 f" bound={rec['bottleneck']}"
                                 f" roofline={rec['roofline_fraction']:.2f}")
                    print(f"PASS {tag} compile={rec['compile_s']}s "
                          f"temp={rec['temp_bytes_per_dev']/2**30:.2f}GiB{extra}",
                          flush=True)
                except Exception as e:  # noqa: BLE001 - report, keep sweeping
                    failures.append({"cell": tag, "error": str(e)[:500]})
                    print(f"FAIL {tag}: {str(e)[:200]}", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells passed, {len(failures)} failed")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
