"""PartitionSpec builders for every architecture family x shape kind.

Sharding policy (DESIGN.md §4):
  * batch over the data axes ("pod"+"data" multi-pod, "data" single-pod)
  * TP over "model": QKV/up column-parallel, O/down row-parallel,
    vocab-parallel embedding + head
  * EP: MoE (sub-)experts over "data" + TP within experts over "model"
    (all-to-all dispatch on the data axis)
  * SP: long_500k (batch=1) shards sequence / KV-cache length over "data"
  * ZeRO-1: optimizer moments additionally sharded over an axis the param
    spec leaves free (zero1_specs)
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.cim_bsr_matmul import MACRO_AXIS
from ..models.config import ModelConfig, ShapeConfig


def data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Serving: the macro-cluster mesh (tensor-parallel compressed decode)
# ---------------------------------------------------------------------------


def macro_mesh(n: Optional[int] = None) -> Mesh:
    """1-D serving mesh whose ``macro`` axis plays the MARS macro cluster:
    every DeployedWeight's block columns are split over it. ``n`` defaults
    to every visible device."""
    devs = jax.devices()
    n = len(devs) if n is None else int(n)
    if not 1 <= n <= len(devs):
        raise ValueError(f"macro mesh of {n} devices, host has {len(devs)}")
    return Mesh(np.asarray(devs[:n]), (MACRO_AXIS,))


def deployed_weight_specs() -> dict:
    """PartitionSpecs for one BSR-packed projection dict - delegates to
    ``core.deploy.deployed_weight_specs``, the single source of truth
    ``shard_weight`` applies."""
    from ..core.deploy import deployed_weight_specs as _specs
    return _specs(MACRO_AXIS)


def serve_kv_view_spec(cfg: ModelConfig, mesh: Mesh) -> P:
    """Spec for the gathered paged-KV views (L, B, Sv, KV, dh): heads over
    the macro axis when divisible, else replicated (correctness first).
    Delegates to ``serve.batching.kv_view_spec``, which PagedKVCache
    consumes."""
    from ..serve.batching import kv_view_spec
    spec = kv_view_spec(cfg, mesh)
    return spec if spec is not None else P()


def _attn_layer_specs(cfg: ModelConfig, stacked: bool, model_n: int = 16,
                      opt: bool = False) -> dict:
    pre = (None,) if stacked else ()
    # opt mode: when KV heads don't divide the TP axis, replicate K/V weights
    # (Megatron GQA-style) - kills the pathological head resharding
    kv_spec = (P(*pre, None, None)
               if opt and cfg.n_kv_heads % model_n != 0
               else P(*pre, None, "model"))
    sp = {
        "ln1": P(*pre), "ln2": P(*pre),
        "wq": P(*pre, None, "model"),
        "wk": kv_spec,
        "wv": kv_spec,
        "wo": P(*pre, "model", None),
    }
    if cfg.family == "moe":
        sp["router"] = P(*pre)
        sp["w_gate"] = P(*pre, "data", None, "model")
        sp["w_up"] = P(*pre, "data", None, "model")
        sp["w_down"] = P(*pre, "data", "model", None)
    else:
        sp["w_gate"] = P(*pre, None, "model")
        sp["w_up"] = P(*pre, None, "model")
        sp["w_down"] = P(*pre, "model", None)
    return sp


def _mamba_layer_specs(cfg: ModelConfig, pre: Tuple, opt: bool = False) -> dict:
    if opt:
        # opt mode = split layout (cfg.ssm_split_proj): z|x TP-sharded with
        # shard-aligned boundaries; the tiny b|c / dt weights replicated so
        # the SSD einsums see replicated B,C and run collective-free.
        return {
            "ln": P(*pre),
            "w_zx": P(*pre, None, "model"),
            "w_bc": P(*pre),
            "w_dt": P(*pre),
            "conv_xw": P(*pre, None, "model"),
            "conv_xb": P(*pre, "model"),
            "conv_bcw": P(*pre), "conv_bcb": P(*pre),
            "a_log": P(*pre), "dt_bias": P(*pre), "d_skip": P(*pre),
            "norm_g": P(*pre, "model"),
            "out_proj": P(*pre, "model", None),
        }
    return {
        "ln": P(*pre),
        "in_proj": P(*pre, None, "model"),
        "conv_w": P(*pre, None, "model"),
        "conv_b": P(*pre, "model"),
        "a_log": P(*pre), "dt_bias": P(*pre), "d_skip": P(*pre),
        "norm_g": P(*pre, "model"),
        "out_proj": P(*pre, "model", None),
    }


def _embed_specs(cfg: ModelConfig, model_n: int):
    """Vocab-parallel embedding when the (possibly padded) vocab divides
    the TP axis; whisper's 51865 and mamba2's 50280 need vocab_pad_multiple
    (opt mode) or fall back to d-sharding + logits all-reduce (baseline)."""
    if cfg.vocab_eff % model_n == 0:
        return P("model", None), P(None, "model")
    return P(None, "model"), P("model", None)


def param_specs(cfg: ModelConfig, model_n: int = 16, opt: bool = False) -> dict:
    """PartitionSpec pytree mirroring registry init_params exactly."""
    emb_spec, head_spec = _embed_specs(cfg, model_n)
    if cfg.family == "encdec":
        kv = (P(None, None, None) if opt and cfg.n_kv_heads % model_n != 0
              else P(None, None, "model"))
        attn = {"wq": P(None, None, "model"), "wk": kv,
                "wv": kv, "wo": P(None, "model", None)}
        ln = {"g": P(None), "b": P(None)}
        mlp = {"w_up": P(None, None, "model"), "w_down": P(None, "model", None)}
        return {
            "embed": emb_spec,
            "pos_dec": P(),
            "enc_layers": {"ln1": ln, "attn": attn, "ln2": ln, "mlp": mlp},
            "dec_layers": {"ln1": ln, "self": attn, "lnx": ln, "cross": attn,
                           "ln2": ln, "mlp": mlp},
            "enc_ln": {"g": P(), "b": P()},
            "dec_ln": {"g": P(), "b": P()},
        }

    sp: dict = {"embed": emb_spec, "final_ln": P()}
    if not cfg.tie_embeddings:
        sp["head"] = head_spec

    if cfg.family in ("dense", "moe", "vlm"):
        sp["layers"] = _attn_layer_specs(cfg, stacked=True, model_n=model_n, opt=opt)
    elif cfg.family == "ssm":
        sp["layers"] = _mamba_layer_specs(cfg, (None,), opt=opt)
    elif cfg.family == "hybrid":
        sp["layers_body"] = _mamba_layer_specs(cfg, (None, None), opt=opt)
        n_tail = cfg.n_layers - (cfg.n_layers // cfg.attn_every) * cfg.attn_every
        if n_tail:
            sp["layers_tail"] = _mamba_layer_specs(cfg, (None,), opt=opt)
        shared = _attn_layer_specs(cfg, stacked=False, model_n=model_n, opt=opt)
        sp["shared_attn"] = shared
        sp["attn_gate"] = P()
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        sp["mm_proj"] = P(None, "model")
    return sp


def _kv_cache_spec(cfg: ModelConfig, mesh: Mesh, batch: int, lead: int = 1,
                   opt: bool = False):
    """Spec for (lead..., B, S, KV, dh) caches."""
    dp = data_axes(mesh)
    model_n = mesh.shape["model"]
    pre = (None,) * lead
    if opt:
        # sequence-sharded cache: the decode DUS update stays local to one
        # shard and per-token attention reduces over S with tiny collectives
        if batch == 1:
            return P(*pre, None, ("data", "model") if "pod" not in
                     mesh.axis_names else ("pod", "data", "model"), None, None)
        return P(*pre, dp, "model", None, None)
    if batch == 1:
        # SP: shard the cache length; heads over model if divisible
        if cfg.n_kv_heads and cfg.n_kv_heads % model_n == 0:
            return P(*pre, None, dp, "model", None)
        return P(*pre, None, dp, None, None)
    if cfg.n_kv_heads and cfg.n_kv_heads % model_n == 0:
        return P(*pre, dp, None, "model", None)
    if cfg.dh % model_n == 0:
        return P(*pre, dp, None, None, "model")
    return P(*pre, dp, None, None, None)


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, opt: bool = False) -> dict:
    dp = data_axes(mesh)
    model_n = mesh.shape["model"]
    if cfg.family in ("dense", "moe", "vlm"):
        kv = _kv_cache_spec(cfg, mesh, batch, opt=opt)
        return {"k": kv, "v": kv, "pos": P()}
    if cfg.family == "ssm":
        h_ax = "model" if cfg.n_ssm_heads % model_n == 0 else None
        bp = dp if batch > 1 else None
        return {
            "conv": P(None, bp, None, "model"),
            "ssm": P(None, bp, h_ax, None, None),
            "pos": P(),
        }
    if cfg.family == "hybrid":
        bp = dp if batch > 1 else None
        h_ax = "model" if cfg.n_ssm_heads % model_n == 0 else None
        kv = _kv_cache_spec(cfg, mesh, batch, opt=opt)
        sp = {
            "conv": P(None, None, bp, None, "model"),
            "ssm": P(None, None, bp, h_ax, None, None),
            "k": kv, "v": kv, "pos": P(),
        }
        n_tail = cfg.n_layers - (cfg.n_layers // cfg.attn_every) * cfg.attn_every
        if n_tail:
            sp["conv_tail"] = P(None, bp, None, "model")
            sp["ssm_tail"] = P(None, bp, h_ax, None, None)
        return sp
    if cfg.family == "encdec":
        kv = _kv_cache_spec(cfg, mesh, batch, opt=opt)
        return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": P()}
    raise ValueError(cfg.family)


def batch_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig) -> dict:
    dp = data_axes(mesh)
    if shape.kind == "decode":
        tok = P(dp, None) if shape.global_batch > 1 else P(None, None)
    elif shape.global_batch == 1:
        tok = P(None, dp)  # SP over sequence
    else:
        tok = P(dp, None)
    sp = {"tokens": tok}
    if cfg.family == "vlm":
        sp["patch_embeds"] = P(dp if shape.global_batch > 1 else None, None, None)
    if cfg.family == "encdec":
        sp["frames"] = P(dp if shape.global_batch > 1 else None, None, None)
    return sp


def zero1_specs(pspecs, params_shape, mesh: Mesh):
    """Optimizer-moment specs: param spec + shard the largest free axis over
    the data axes (ZeRO-1). Falls back to the param spec when nothing fits."""
    dp = data_axes(mesh)

    def one(spec: P, shape):
        dims = list(spec) + [None] * (len(shape.shape) - len(spec))
        used = set()
        for d in dims:
            for a in (d if isinstance(d, tuple) else (d,)):
                if a is not None:
                    used.add(a)
        free = tuple(a for a in dp if a not in used)  # MoE uses "data" on E
        if not free:
            return spec
        n_free = 1
        for a in free:
            n_free *= mesh.shape[a]
        best, best_size = None, 0
        for i, (s, d) in enumerate(zip(shape.shape, dims)):
            if d is None and s % n_free == 0 and s > best_size:
                best, best_size = i, s
        if best is None:
            return spec
        dims[best] = free if len(free) > 1 else free[0]
        return P(*dims)

    return jax.tree.map(one, pspecs, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
