"""Production mesh construction (assignment: MULTI-POD DRY-RUN step 1)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host actually has (CPU: 1 device) -> (1, 1) mesh so the
    same pjit code paths run locally."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
