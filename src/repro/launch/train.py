"""Training driver: pjit over whatever devices exist (the production mesh
shardings come from launch.shardings, so the same code paths run on 1 CPU
device or a 512-chip pod), fault-tolerant checkpoint/resume, SIGTERM-safe.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \\
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck [--resume] [--cim]
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..data import TokenPipeline
from ..models import registry
from ..train import checkpoint as ckpt
from ..train import optimizer as optim
from ..train.trainer import TrainConfig, init_train_state, make_train_step
from . import shardings as SH
from .mesh import make_local_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--opt", choices=["adamw", "sgdm"], default="adamw")
    ap.add_argument("--cim", action="store_true",
                    help="enable MARS QAT + group lasso (the paper's technique)")
    ap.add_argument("--w-bits", type=int, default=8)
    ap.add_argument("--a-bits", type=int, default=8)
    ap.add_argument("--lambda-g", type=float, default=1e-5)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    over = dict(dtype=args.dtype)
    if args.cim:
        over.update(cim_mode="qat", w_bits=args.w_bits, a_bits=args.a_bits,
                    lambda_g=args.lambda_g, cim_alpha=16, cim_n=16)
    cfg = (registry.get_smoke_config(args.arch, **over) if args.smoke
           else registry.get_config(args.arch, **over))
    tcfg = TrainConfig(
        opt=optim.OptConfig(kind=args.opt, lr=args.lr, warmup_steps=10,
                            total_steps=args.steps),
        grad_accum=args.grad_accum,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )

    mesh = make_local_mesh()
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
                         seed=args.seed)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(args.seed))
    start_step = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, manifest = ckpt.restore(args.ckpt_dir, state)
        pipe.restore(manifest["extra"]["pipe"])
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    # On this host's mesh the shardings are trivial; the production-mesh
    # sharding path (param_specs/zero1_specs) is exercised by launch.dryrun
    # and applies identically when real pods are attached.
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))

    stop = {"flag": False}
    signal.signal(signal.SIGTERM, lambda *_: stop.update(flag=True))

    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i == start_step:
            dt = time.time() - t0
            print(f"step {i+1} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({dt:.1f}s)", flush=True)
        if (i + 1) % tcfg.ckpt_every == 0 or stop["flag"] or i + 1 == args.steps:
            ckpt.save(tcfg.ckpt_dir, i + 1, state,
                      extra={"pipe": pipe.state(), "arch": args.arch},
                      keep=tcfg.ckpt_keep)
        if stop["flag"]:
            print("SIGTERM received: checkpointed and exiting cleanly")
            sys.exit(0)
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
