from . import mesh, shardings  # noqa: F401  (dryrun imports jax-device state; import explicitly)
