"""Serving driver: continuous-batching (default) or legacy static engine.

  # continuous batching over a synthetic mixed-length trace
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke

  # same trace, weights BSR-compressed with a searched schedule tile
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --target-sparsity 0.5

  # compiled runtime: one jitted lax.scan decode step over the uniform
  # envelope (bit-identical tokens to the default loop runtime)
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --runtime scan

  # offline artifact: first run packs + saves, later runs boot from disk
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --artifact /tmp/yi6b-artifact

  # tensor-parallel compressed decode over a 4-device macro cluster
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --mesh macro=4 --tile 16x16

  # self-speculative decode: a 0.9-sparsity draft packing of the SAME
  # weights proposes 4 tokens per target verify (greedy tokens stay
  # bit-identical to target-only decode; --spec auto picks k and the
  # draft sparsity from the simulated reload+compute cost)
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --spec k=4,draft_sparsity=0.9

  # instrumented serve: Perfetto-loadable trace + metrics snapshot of the
  # measured (post-warmup) run; add --profile DIR for an XLA-level
  # jax.profiler trace
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --runtime scan --trace-out /tmp/serve-trace.json \\
      --metrics-out /tmp/serve-metrics.json

  # legacy static-batch Engine (any registry family)
  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \\
      --engine legacy --batch 4 --prompt-len 16 --new-tokens 32

  # multi-tenant gateway: several tenants (artifacts or fresh inits)
  # behind ONE shared KV pool, priced admission, optional mid-run
  # hot-swaps; see the README's "Multi-tenant gateway" for tenants.json
  PYTHONPATH=src python -m repro.launch.serve --smoke \\
      --gateway tenants.json --gateway-parity-check
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import registry
from ..obs import MetricsRegistry, Tracer
from ..serve import (BatchConfig, BatchServer, Engine, Request, ServeConfig,
                     SpecConfig, deployed, stacked)
from ..serve import spec as spec_mod


def _legacy(args, cfg, params, fns=None):
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)) * 0.02,
            cfg.param_dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)) * 0.02,
            cfg.param_dtype)

    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          temperature=args.temperature,
                                          seed=args.seed), fns=fns)
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    tps = args.batch * out.shape[1] / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    for row in out[: min(4, args.batch)]:
        print("  ", row.tolist())


def synthetic_trace(cfg, n_requests: int, max_prompt: int, max_new: int,
                    seed: int = 0, long_every: int = 4):
    """Mixed-length trace: every ``long_every``-th request decodes the full
    ``max_new`` tokens, the rest draw short lengths - the skew that makes
    static batching idle its lanes."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(2, max(3, max_prompt)))
        n_new = max_new if i % long_every == 0 else int(
            rng.integers(1, max(2, max_new // 6)))
        reqs.append(Request(f"r{i}", rng.integers(0, cfg.vocab, plen), n_new))
    return reqs


def prefix_skew_trace(cfg, n_requests: int, shared_len: int, suffix_max: int,
                      max_new: int, seed: int = 0,
                      shared_frac: float = 0.9):
    """Prefix-skewed trace (the production shape: most requests share one
    system prompt). ``shared_frac`` of the requests open with the SAME
    ``shared_len``-token prefix followed by a short unique suffix; the rest
    are fully unique prompts of comparable length. Which requests share is
    DETERMINISTIC (position mod 10), so the served hit-rate is a stable
    property of the trace, not of the seed."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab, shared_len).astype(np.int32)
    cut = int(round(shared_frac * 10))
    reqs = []
    for i in range(n_requests):
        if i % 10 < cut:
            sfx = rng.integers(0, cfg.vocab,
                               int(rng.integers(1, max(2, suffix_max + 1))))
            p = np.concatenate([system, sfx.astype(np.int32)])
        else:
            p = rng.integers(0, cfg.vocab,
                             shared_len + max(1, suffix_max // 2)
                             ).astype(np.int32)
        reqs.append(Request(f"r{i}", p, max_new))
    return reqs


def _parse_mesh(spec):
    """'macro=N' -> a macro_mesh(N); None -> single-device serving."""
    if not spec:
        return None
    from .shardings import macro_mesh
    axis, _, n = spec.partition("=")
    if axis != "macro" or not n.isdigit():
        raise SystemExit(f"--mesh expects macro=N, got {spec!r}")
    return macro_mesh(int(n))


def _parse_tile(spec):
    if not spec:
        return None
    bk, _, bn = spec.lower().partition("x")
    if not (bk.isdigit() and bn.isdigit() and int(bk) > 0 and int(bn) > 0):
        raise SystemExit(f"--tile expects BKxBN (e.g. 16x16), got {spec!r}")
    return (int(bk), int(bn))


def _load_calibration(args):
    """Boot the measured acceptance prior from the artifact manifest (the
    same persistence slot as the autotune cache). Returns an empty
    SpecCalibration when there is none - search_spec then falls back to
    the uncalibrated priors."""
    from ..sched.search import SpecCalibration
    stored = None
    if args.artifact:
        try:
            stored = deployed.load_artifact_extra(
                args.artifact).get("spec_calibration")
        except (OSError, ValueError):
            stored = None
    if stored is None:
        return SpecCalibration()
    try:
        cal = SpecCalibration.from_json(stored)
        print(f"spec: loaded acceptance calibration "
              f"({len(cal.rows)} measured row(s)) from the artifact "
              "manifest")
        return cal
    except ValueError as e:
        print(f"spec: stored calibration unusable ({e}) - using "
              "uncalibrated priors")
        return SpecCalibration()


def _parse_spec(arg, cfg, target_sparsity, calibration=None):
    """'k=4,draft_sparsity=0.9' or 'draft=layerskip,keep=0.5,k=4' ->
    SpecConfig; 'auto' picks (family, k, knob) from the simulated cost and
    the calibrated acceptance prior - and returns None (serve the scan
    engine) when even the best candidate models a LOSS; '' -> None (no
    speculation)."""
    if not arg:
        return None
    if arg == "auto":
        from ..sched import search_spec
        res = search_spec(cfg, target_sparsity=target_sparsity,
                          calibration=calibration, arch=cfg.name)
        d = res.decision
        print("spec auto:", json.dumps(d))
        if d["accept_source"] != "calibrated":
            print(f"spec auto: acceptance {d['accept']} is a MODELED prior "
                  "(sched.search uncalibrated fallback), not a measurement "
                  "- serve one spec run with --artifact and the measured "
                  "rate is persisted for the next pick")
        if d["verdict"] == "declined":
            print(f"spec auto: declined: scan wins (best candidate "
                  f"{d['family']} k={d['k']} models "
                  f"{d['predicted_speedup']}x vs target-only decode) - "
                  "serving the scan engine")
            return None
        print(f"spec auto: serving {d['family']} k={d['k']} "
              f"({'keep' if d['family'] == 'layerskip' else 'draft_sparsity'}"
              f"={d['knob']}, predicted {d['predicted_speedup']}x, "
              f"accept={d['accept']} [{d['accept_source']}])")
        if d["family"] == "layerskip":
            return SpecConfig(k=int(d["k"]), draft="layerskip",
                              keep=float(d["knob"]))
        return SpecConfig(k=int(d["k"]), draft_sparsity=float(d["knob"]))
    usage = (f"--spec expects 'auto' or comma-joined k=INT, "
             f"draft=reprune|layerskip, draft_sparsity=FLOAT, keep=FLOAT, "
             f"adaptive_k=0|1, got {arg!r}")
    kw = {}
    for part in arg.split(","):
        key, _, val = part.partition("=")
        key = key.strip()
        try:
            if key == "k":
                kw["k"] = int(val)
            elif key == "draft_sparsity":
                kw["draft_sparsity"] = float(val)
            elif key == "draft":
                kw["draft"] = val.strip()
            elif key == "keep":
                kw["keep"] = float(val)
            elif key == "adaptive_k":
                kw["adaptive_k"] = bool(int(val))
            else:
                raise SystemExit(usage)
        except ValueError:
            raise SystemExit(usage) from None
    try:
        return SpecConfig(**kw)
    except ValueError as e:
        raise SystemExit(f"--spec: {e}") from None


def _run_autotune(args, cfg):
    """Measured-latency tile pick for a fresh pack: shortlist the top-N
    simulated tiles, time each through the real stacked BSR kernels, return
    (AutotuneResult, AutotuneCache) - the cache is persisted into the
    artifact manifest so later boots reuse the measurement."""
    from ..sched import autotune as AT

    cache = AT.AutotuneCache()
    res = AT.autotune(cfg, top_n=args.autotune,
                      target_sparsity=args.target_sparsity, cache=cache)
    bk, bn = res.best_tile
    sbk, sbn = res.simulated_tile
    print(f"autotune: measured tile {bk}x{bn} over {len(res.table)} "
          f"candidate(s) on {res.backend} (simulated pick {sbk}x{sbn})")
    for row in res.table:
        print(f"autotune:   tile {row['tile'][0]}x{row['tile'][1]} "
              f"total {row['total_s'] * 1e3:.2f} ms "
              f"(prefill {row['prefill_s'] * 1e3:.2f}, "
              f"decode {row['decode_s'] * 1e3:.2f}; "
              f"sim {row['sim_fps']} fps)")
    return res, cache


def _report_artifact_autotune(cfg, meta):
    """Boot-path cache report: the stored packing is served either way
    (artifacts are immutable); this only says whether the stored autotune
    measurement covers THIS (arch, shapes, backend)."""
    from ..sched import autotune as AT

    stored = meta.get("autotune")
    if not stored:
        print("autotune: artifact carries no autotune cache - serving "
              "stored packing unchanged (re-pack with --autotune to tune)")
        return
    try:
        cache = AT.AutotuneCache.from_json(stored)
    except ValueError as e:
        print(f"autotune: stored cache unusable ({e}) - serving stored "
              "packing unchanged")
        return
    hit = cache.get(AT.autotune_key(cfg))
    if hit is None:
        print("autotune: cache MISS for this (arch, shapes, backend) - the "
              "stored packing was tuned elsewhere; serving as stored "
              "(point --artifact at a fresh directory to re-tune here)")
    else:
        bt = hit["best_tile"]
        print(f"autotune: cache hit ({bt[0]}x{bt[1]}, measured on "
              f"{hit.get('backend')}) - boot reuses the measurement, "
              "no re-timing")


def _serving_params(args, cfg, params, spec_cfg=None):
    """Build (or boot) the serving weights: the artifact flow runs the
    full search+quantize+prune+pack pipeline ONCE and later boots skip
    straight to weights-on-device. Returns (target, draft-or-None,
    spec_cfg); with ``spec_cfg`` the draft tier rides the same artifact
    (two-tier) - an existing single-tier artifact is upgraded in place
    (draft re-packed from the STORED target packing, then re-saved with
    its original manifest extra merged, not rebuilt from current flags).
    A stored draft tier is served AS STORED: if its packed sparsity
    differs from the requested one, the returned spec_cfg adopts the
    stored value so telemetry reports the packing actually served."""
    sp = draft = None
    if args.artifact:
        try:
            sp, draft, meta = deployed.load_artifact_tiers(args.artifact)
        except FileNotFoundError:
            sp = None
        if sp is not None:
            if meta.get("arch") not in (None, cfg.name):
                raise SystemExit(
                    f"--artifact {args.artifact} holds arch "
                    f"{meta.get('arch')!r}, not {cfg.name!r} - point it at a "
                    "fresh directory to re-pack")
            if bool(meta.get("compressed", args.compressed)) != args.compressed:
                print(f"note: artifact was saved with compressed="
                      f"{meta.get('compressed')} - serving it as stored "
                      "(packing flags only apply when building)")
            print(f"artifact: loaded {args.artifact} "
                  f"(arch={meta.get('arch')}, no re-packing)")
            if args.autotune > 0:
                _report_artifact_autotune(cfg, meta)
            if spec_cfg is None:
                return sp, None, None
            if spec_cfg.draft == "layerskip":
                # the layerskip family drafts with a sublayer subset of the
                # TARGET envelope - no second packing to load or build
                return sp, None, spec_cfg
            if draft is not None:
                stored_ds = meta.get("draft_sparsity")
                if (stored_ds is not None
                        and stored_ds != spec_cfg.draft_sparsity):
                    print(f"note: artifact's draft tier was packed at "
                          f"sparsity {stored_ds}, not the requested "
                          f"{spec_cfg.draft_sparsity} - serving it as "
                          "stored (point --artifact at a fresh directory "
                          "to re-pack)")
                    spec_cfg = SpecConfig(k=spec_cfg.k,
                                          draft_sparsity=float(stored_ds))
                return sp, draft, spec_cfg
            draft = spec_mod.draft_serving(
                cfg, sp, spec_cfg.draft_sparsity,
                tile=_parse_tile(args.tile))
            out = deployed.save_artifact(
                args.artifact, sp, cfg, draft=draft,
                extra={**meta,
                       "draft_sparsity": spec_cfg.draft_sparsity})
            print(f"artifact: upgraded to two-tier (draft packed at "
                  f"sparsity {spec_cfg.draft_sparsity}) at {out}")
            return sp, draft, spec_cfg
    at_result = at_cache = None
    tile = _parse_tile(args.tile)
    if args.compressed and args.autotune > 0 and tile is None:
        at_result, at_cache = _run_autotune(args, cfg)
        tile = at_result.best_tile
    sp = (deployed.compress(cfg, params, target_sparsity=args.target_sparsity,
                            schedule=(None if tile else
                                      deployed.default_schedule(cfg)),
                            tile=tile, uniform=at_result is not None)
          if args.compressed else deployed.from_params(cfg, params))
    if spec_cfg is not None and spec_cfg.draft == "reprune":
        draft = spec_mod.draft_serving(cfg, sp, spec_cfg.draft_sparsity,
                                       tile=tile)
    if args.artifact:
        extra = {"compressed": args.compressed}
        if draft is not None:
            extra["draft_sparsity"] = spec_cfg.draft_sparsity
        if at_result is not None:
            extra["autotune"] = at_cache.to_json()
            extra["autotune_tile"] = list(at_result.best_tile)
        out = deployed.save_artifact(args.artifact, sp, cfg, draft=draft,
                                     extra=extra)
        print(f"artifact: packed + saved to {out}")
    return sp, draft, spec_cfg


def _batch(args, cfg, params):
    mesh = _parse_mesh(args.mesh)
    calibration = _load_calibration(args) if args.spec else None
    spec_cfg = _parse_spec(args.spec, cfg, args.target_sparsity,
                           calibration=calibration)
    sp, draft, spec_cfg = _serving_params(args, cfg, params, spec_cfg)
    if args.compressed:
        print("compression:", json.dumps(sp.report()))
    if spec_cfg is not None and spec_cfg.draft == "layerskip":
        print(f"spec: layerskip draft over the target envelope, "
              f"keep={spec_cfg.keep}, k={spec_cfg.k} (no second packing)")
    elif spec_cfg is not None:
        print(f"spec: draft tier packed at sparsity "
              f"{spec_cfg.draft_sparsity} "
              f"({json.dumps(draft.report())}), k={spec_cfg.k}")
    if mesh is not None:
        sp = deployed.shard(sp, mesh)
        if draft is not None:
            draft = deployed.shard(draft, mesh)
        n_sharded = sum(1 for dw in sp.deployed().values()
                        if dw.mesh is not None)
        print(f"macro mesh: {mesh.shape} - {n_sharded} projections "
              "column-sharded (rest replicated)")
    bcfg = BatchConfig(n_slots=args.slots, block_size=args.block_size,
                       n_blocks=args.kv_blocks,
                       prefix_cache=not args.no_prefix_cache)
    engine = "spec" if spec_cfg is not None else args.runtime
    print(f"runtime: {engine}"
          + {"scan": " (single jitted lax.scan decode step)",
             "loop": " (python loop over per-layer weights)",
             "spec": " (draft-k-verify speculative decode, greedy-exact)"
             }[engine])
    tracer = Tracer() if args.trace_out else None
    metrics = (MetricsRegistry()
               if args.metrics_out or args.trace_out else None)
    srv = BatchServer(cfg, sp, ServeConfig(temperature=args.temperature,
                                           seed=args.seed), bcfg,
                      continuous=(args.engine == "batch"), mesh=mesh,
                      engine=engine, draft=draft, spec=spec_cfg,
                      tracer=tracer, metrics=metrics)
    if spec_cfg is not None and spec_cfg.draft == "layerskip":
        a_on, m_on = srv.spec_masks
        print(f"spec: layerskip masks attn={list(a_on)} mlp={list(m_on)} "
              f"(executes {spec_mod.kept_fraction(a_on, m_on):.2f} of the "
              "sublayer units; nnz-ranked importance)")
    if args.shared_prefix > 0:
        # align the shared span up to a block multiple: the trie matches in
        # whole blocks, so an unaligned span would leave a partial block
        # unshared every time
        shared_len = -(-args.shared_prefix // args.block_size) \
            * args.block_size
        trace = lambda: prefix_skew_trace(
            cfg, args.requests, shared_len, max(2, args.block_size // 2),
            args.new_tokens, seed=args.seed)
    else:
        trace = lambda: synthetic_trace(cfg, args.requests, args.prompt_len,
                                        args.new_tokens, seed=args.seed)
    srv.run(trace())  # compile
    # the warmup run's spans/samples are compile noise: drop them (the
    # tracer keeps its epoch + track names so the measured run's clocks
    # stay consistent)
    if tracer is not None:
        tracer.clear()
    if metrics is not None:
        metrics.clear()
    srv.timer.clear()
    prof = (jax.profiler.trace(args.profile) if args.profile
            else contextlib.nullcontext())
    with prof:
        rep = srv.run(trace())
    out = rep.to_json()
    if spec_cfg is not None and args.parity_check:
        # greedy-exactness audit: target-only scan decode over the same
        # trace must emit bit-identical tokens
        ref = BatchServer(cfg, sp, ServeConfig(seed=args.seed), bcfg,
                          continuous=(args.engine == "batch"), mesh=mesh,
                          engine="scan").run(trace())
        out["tokens_match_target"] = bool(all(
            np.array_equal(rep.outputs[r.rid], ref.outputs[r.rid])
            for r in trace()))
    if args.prefix_parity_check and rep.prefix is not None:
        # sharing-exactness audit: the same engine with the prefix cache
        # OFF must emit bit-identical tokens over the same trace
        ref = BatchServer(cfg, sp, ServeConfig(temperature=args.temperature,
                                               seed=args.seed),
                          dataclasses.replace(bcfg, prefix_cache=False),
                          continuous=(args.engine == "batch"), mesh=mesh,
                          engine=engine, draft=draft,
                          spec=spec_cfg).run(trace())
        out["tokens_match_unshared"] = bool(all(
            np.array_equal(rep.outputs[r.rid], ref.outputs[r.rid])
            for r in trace()))
    print(json.dumps(out, indent=1))
    if (spec_cfg is not None and rep.spec is not None
            and rep.spec.get("proposed", 0) > 0):
        # close the calibration loop: fold the MEASURED acceptance into the
        # prior and persist it into the artifact manifest (next --spec auto
        # picks from data, not the uncalibrated prior)
        gap = (1.0 - spec_cfg.keep if spec_cfg.draft == "layerskip"
               else spec_cfg.draft_sparsity - args.target_sparsity)
        calibration.add(cfg.name, spec_cfg.draft, gap,
                        rep.spec["acceptance_rate"],
                        weight=float(rep.spec["proposed"]))
        if args.artifact:
            deployed.update_artifact_extra(
                args.artifact, {"spec_calibration": calibration.to_json()})
            print(f"spec: measured acceptance "
                  f"{rep.spec['acceptance_rate']} folded into the "
                  f"calibration ({len(calibration.rows)} row(s)) and "
                  "persisted to the artifact manifest")
        pf = rep.prefix
        print(f"prefix cache: {pf['hits']}/{pf['lookups']} hits "
              f"(hit_rate={pf['hit_rate']}, reused {pf['hit_tokens']} "
              "tokens)")
    for rid in list(rep.outputs)[:3]:
        print(f"  {rid}:", rep.outputs[rid].tolist())
    if tracer is not None:
        tracer.save(args.trace_out)
        print(f"trace: {len(tracer.to_chrome()['traceEvents'])} events -> "
              f"{args.trace_out} (open in Perfetto / chrome://tracing)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(rep.metrics or {}, f, indent=1)
        print(f"metrics: snapshot -> {args.metrics_out}")
    if args.profile:
        print(f"profile: jax.profiler trace -> {args.profile}")


def _tenant_cfg_sp(entry, args):
    """One tenants.json entry -> (cfg, ServingParams). An ``artifact``
    boots the stored packing (validated against the entry's arch); else a
    fresh init from ``seed`` is served dense or uniformly compressed."""
    arch = entry.get("arch") or args.arch
    if not arch:
        raise SystemExit(
            f"tenant {entry.get('name')!r}: no 'arch' in tenants.json and "
            "no --arch fallback")
    cfg = (registry.get_smoke_config(arch, dtype=args.dtype) if args.smoke
           else registry.get_config(arch, dtype=args.dtype))
    tile = _parse_tile(entry.get("tile", ""))
    if entry.get("artifact"):
        sp, _, _ = deployed.load_artifact_tiers(
            entry["artifact"], arch=cfg.name, tile=tile)
        print(f"gateway: tenant {entry['name']} loaded artifact "
              f"{entry['artifact']} (arch={cfg.name})")
        return cfg, sp
    params = registry.model_fns(cfg).init_params(
        cfg, jax.random.PRNGKey(int(entry.get("seed", 0))))
    if entry.get("compressed"):
        sp = deployed.compress(
            cfg, params,
            target_sparsity=float(entry.get("target_sparsity", 0.5)),
            tile=tile if tile else (16, 16), uniform=True)
    else:
        sp = deployed.from_params(cfg, params)
    return cfg, sp


def _gateway(args):
    """Multi-tenant serving: tenants.json -> Gateway run (+ optional
    per-tenant parity audit against dedicated single-tenant servers)."""
    from ..gateway import (AdmissionController, Gateway, GatewayConfig,
                           SwapEvent, TenantRuntime, TenantSLO)
    from ..sched.pricing import Pricer

    with open(args.gateway) as f:
        spec = json.load(f)
    entries = spec.get("tenants")
    if not entries:
        raise SystemExit(f"{args.gateway}: no 'tenants' list")
    tenants, swaps, traces = [], [], {}
    for i, entry in enumerate(entries):
        name = entry.get("name")
        if not name:
            raise SystemExit(f"{args.gateway}: tenants[{i}] has no 'name'")
        cfg, sp = _tenant_cfg_sp(entry, args)
        tenants.append(TenantRuntime(
            name, cfg, sp, priority=int(entry.get("priority", 0)),
            slo=TenantSLO.from_json(entry.get("slo")),
            sparsity=float(entry.get("sparsity", 0.0)),
            artifact=entry.get("artifact", "")))
        n_req = int(entry.get("requests", args.requests))
        reqs = synthetic_trace(cfg, n_req, args.prompt_len, args.new_tokens,
                               seed=args.seed + i)
        deadline_s = entry.get("deadline_s")
        traces[name] = [dataclasses.replace(
            r, rid=f"{name}-{r.rid}", tenant=name,
            priority=int(entry.get("priority", 0)),
            deadline=(r.arrival + float(deadline_s)
                      if deadline_s is not None else None))
            for r in reqs]
        hs = entry.get("hot_swap")
        if hs:
            if hs.get("artifact"):
                sp2, _, _ = deployed.load_artifact_tiers(
                    hs["artifact"], arch=cfg.name)
            else:
                sp2 = _tenant_cfg_sp(
                    {**entry, "seed": hs.get("reseed", 1),
                     "artifact": "", "tile": hs.get("tile",
                                                    entry.get("tile", ""))},
                    args)[1]
            swaps.append(SwapEvent(at_step=int(hs.get("at_step", 1)),
                                   tenant=name, sp=sp2))
    gspec = spec.get("gateway", {})
    gcfg = GatewayConfig(
        n_slots=int(gspec.get("n_slots", args.slots)),
        block_size=int(gspec.get("block_size", args.block_size)),
        n_blocks=int(gspec.get("n_blocks", args.kv_blocks)),
        prefill_chunk=int(gspec.get("prefill_chunk", args.prefill_chunk)),
        prefill_device=gspec.get("prefill_device", args.prefill_device),
        max_backlog_s=float(gspec.get("max_backlog_s", args.max_backlog_s)),
        max_pending=(int(gspec["max_pending"]) if "max_pending" in gspec
                     else args.max_pending))
    controller = AdmissionController(pricer=Pricer(),
                                     max_backlog_s=gcfg.max_backlog_s)
    gw = Gateway(tenants, gcfg, ServeConfig(seed=args.seed),
                 controller=controller)
    all_reqs = [r for reqs in traces.values() for r in reqs]
    print(f"gateway: {len(tenants)} tenant(s) "
          f"({', '.join(t.name for t in tenants)}), {len(all_reqs)} "
          f"request(s), one shared pool of {gcfg.n_blocks} blocks")
    rep = gw.run(all_reqs, swaps=swaps)
    for ev in rep.shed:
        print(f"gateway: shed rid={ev['rid']} tenant={ev['tenant']} "
              f"priority={ev['priority']} reason={ev['reason']}")
    out = rep.to_json()
    if args.gateway_parity_check:
        # per-tenant bit-exactness audit: each tenant's gateway tokens vs
        # a dedicated single-tenant BatchServer over the same requests
        swapped = {ev.tenant for ev in swaps}
        for t in tenants:
            if t.name in swapped:
                # pre-swap tokens came from weights the tenant no longer
                # holds - a post-hoc re-serve cannot reproduce them
                print(f"gateway: tenant={t.name} "
                      "tokens_match_dedicated=skipped(hot-swap)")
                continue
            served = rep.per_tenant[t.name].outputs
            bcfg = BatchConfig(n_slots=gcfg.n_slots,
                               block_size=gcfg.block_size,
                               n_blocks=gcfg.n_blocks)
            ded = BatchServer(t.cfg, t.sp, ServeConfig(seed=args.seed),
                              bcfg, engine="scan").run(
                [Request(r.rid, r.prompt, r.max_new_tokens)
                 for r in traces[t.name] if r.rid in served])
            match = bool(all(np.array_equal(served[rid], o)
                             for rid, o in ded.outputs.items()))
            out.setdefault("parity", {})[t.name] = match
            print(f"gateway: tenant={t.name} "
                  f"tokens_match_dedicated={match}")
    print(json.dumps(out, indent=1))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="registry architecture (required unless --gateway "
                    "names per-tenant arches)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=["batch", "static", "legacy"],
                    default="batch",
                    help="batch = continuous batching (default); static = "
                    "same server, whole-batch admission; legacy = Engine")
    ap.add_argument("--compressed", action="store_true",
                    help="serve deploy_weight-packed (BSR) projections")
    ap.add_argument("--runtime", choices=["loop", "scan"], default="loop",
                    help="decode runtime: loop = python loop over per-layer "
                    "weights; scan = one jitted lax.scan over the stacked "
                    "uniform envelope (bit-identical tokens)")
    ap.add_argument("--spec", default="",
                    help="speculative decode: comma-joined k=INT, "
                    "draft=reprune|layerskip, draft_sparsity=FLOAT (reprune "
                    "knob: packs a second higher-sparsity tier), keep=FLOAT "
                    "(layerskip knob: draft runs the nnz-ranked top keep "
                    "fraction of the TARGET envelope's sublayers - no "
                    "second packing), adaptive_k=0|1. 'auto' picks (family, "
                    "k, knob) from simulated cost + the calibrated "
                    "acceptance prior, or declines and serves the scan "
                    "engine when speculation models a loss")
    ap.add_argument("--parity-check", action="store_true",
                    help="with --spec: also run target-only scan decode "
                    "over the trace and report tokens_match_target (the "
                    "greedy bit-exactness contract)")
    ap.add_argument("--artifact", default="",
                    help="serving-artifact directory: boot from it when it "
                    "exists (no re-packing), else pack once and save there")
    ap.add_argument("--mesh", default="",
                    help="macro=N: shard compressed projections column-wise "
                    "and KV heads over an N-device macro cluster")
    ap.add_argument("--tile", default="",
                    help="BKxBN packing tile override (e.g. 16x16); default "
                    "is the searched schedule's tile")
    ap.add_argument("--autotune", type=int, default=0, metavar="TOPN",
                    help="with --compressed: time the top-TOPN simulated "
                    "tiles through the real stacked BSR kernels (fenced) "
                    "and pack with the measured winner; the measurement is "
                    "cached in the artifact manifest keyed by (arch, "
                    "shapes, backend). 0 = trust the simulator (default). "
                    "Ignored when --tile pins the tile explicitly")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the measured "
                    "run (phase spans, request lifecycle tracks, occupancy "
                    "counters) - open in Perfetto / chrome://tracing")
    ap.add_argument("--metrics-out", default="",
                    help="write the measured run's metrics snapshot "
                    "(counters/gauges/phase histograms + fenced kernel "
                    "dispatch table) as JSON")
    ap.add_argument("--profile", default="",
                    help="directory for a jax.profiler trace of the "
                    "measured run (XLA-level, TensorBoard-loadable)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="serve a prefix-skewed trace instead of the mixed-"
                    "length one: 90%% of requests share an N-token system "
                    "prompt (N is aligned up to a block multiple) plus a "
                    "short unique suffix - the radix-tree prefix cache "
                    "should hit on nearly all of them")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable radix-tree prefix KV reuse (default on; "
                    "tokens are bit-identical either way)")
    ap.add_argument("--prefix-parity-check", action="store_true",
                    help="also serve the trace with the prefix cache OFF "
                    "and report tokens_match_unshared (the sharing "
                    "bit-exactness contract)")
    ap.add_argument("--gateway", default="", metavar="TENANTS_JSON",
                    help="multi-tenant gateway mode: serve the tenants "
                    "described in TENANTS_JSON behind one shared KV pool "
                    "with simulator-priced admission (see the README's "
                    "'Multi-tenant gateway' for the schema)")
    ap.add_argument("--gateway-parity-check", action="store_true",
                    help="with --gateway: re-serve each tenant's requests "
                    "on a dedicated single-tenant server and report "
                    "tokens_match_dedicated (the isolation bit-exactness "
                    "contract)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="gateway: advance pending prefills at most N "
                    "tokens per step, interleaved with decode rounds "
                    "(0 = whole prompt at admission)")
    ap.add_argument("--prefill-device", type=int, default=None,
                    help="gateway: pin chunked-prefill dispatches to this "
                    "device index (prefill/decode disaggregation)")
    ap.add_argument("--max-backlog-s", type=float, default=float("inf"),
                    help="gateway: shed (lowest-priority-first) once the "
                    "simulator-predicted backlog exceeds this many seconds")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="gateway: bound the request queue; overflow sheds "
                    "the lowest-priority pending request (counted, never "
                    "silent)")
    ap.add_argument("--target-sparsity", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--kv-blocks", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.gateway:
        _gateway(args)
        return
    if not args.arch:
        ap.error("--arch is required (unless --gateway names per-tenant "
                 "arches)")

    cfg = (registry.get_smoke_config(args.arch, dtype=args.dtype) if args.smoke
           else registry.get_config(args.arch, dtype=args.dtype))
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(args.seed))

    use_legacy = args.engine == "legacy"
    if not use_legacy and cfg.family not in ("dense", "moe"):
        print(f"note: no batch-server path for the {cfg.family} family yet; "
              "falling back to the legacy Engine")
        use_legacy = True

    if use_legacy:
        if args.compressed:
            sp, _, _ = _serving_params(args, cfg, params)
            print("compression:", json.dumps(sp.report()))
            if args.runtime == "scan":
                _legacy(args, cfg, stacked.stack(sp),
                        fns=stacked.model_fns(cfg))
            else:
                _legacy(args, cfg, sp, fns=deployed.model_fns(cfg))
        else:
            # uncompressed legacy serving already runs the registry's
            # scan-over-layers forward - both --runtime values coincide
            _legacy(args, cfg, params)
    else:
        _batch(args, cfg, params)


if __name__ == "__main__":
    main()
