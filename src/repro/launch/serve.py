"""Serving driver: batched generation with the Engine.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import registry
from ..serve import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch, dtype=args.dtype) if args.smoke
           else registry.get_config(args.arch, dtype=args.dtype))
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)) * 0.02,
            cfg.param_dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)) * 0.02,
            cfg.param_dtype)

    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          temperature=args.temperature,
                                          seed=args.seed))
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    tps = args.batch * out.shape[1] / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    for row in out[: min(4, args.batch)]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
