"""Serving driver: continuous-batching (default) or legacy static engine.

  # continuous batching over a synthetic mixed-length trace
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke

  # same trace, weights BSR-compressed with a searched schedule tile
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --target-sparsity 0.5

  # compiled runtime: one jitted lax.scan decode step over the uniform
  # envelope (bit-identical tokens to the default loop runtime)
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --runtime scan

  # offline artifact: first run packs + saves, later runs boot from disk
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --artifact /tmp/yi6b-artifact

  # tensor-parallel compressed decode over a 4-device macro cluster
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
      --compressed --mesh macro=4 --tile 16x16

  # legacy static-batch Engine (any registry family)
  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \\
      --engine legacy --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..models import registry
from ..serve import (BatchConfig, BatchServer, Engine, Request, ServeConfig,
                     deployed, stacked)


def _legacy(args, cfg, params, fns=None):
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)) * 0.02,
            cfg.param_dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)) * 0.02,
            cfg.param_dtype)

    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          temperature=args.temperature,
                                          seed=args.seed), fns=fns)
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    tps = args.batch * out.shape[1] / dt
    print(f"generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    for row in out[: min(4, args.batch)]:
        print("  ", row.tolist())


def synthetic_trace(cfg, n_requests: int, max_prompt: int, max_new: int,
                    seed: int = 0, long_every: int = 4):
    """Mixed-length trace: every ``long_every``-th request decodes the full
    ``max_new`` tokens, the rest draw short lengths - the skew that makes
    static batching idle its lanes."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(2, max(3, max_prompt)))
        n_new = max_new if i % long_every == 0 else int(
            rng.integers(1, max(2, max_new // 6)))
        reqs.append(Request(f"r{i}", rng.integers(0, cfg.vocab, plen), n_new))
    return reqs


def _parse_mesh(spec):
    """'macro=N' -> a macro_mesh(N); None -> single-device serving."""
    if not spec:
        return None
    from .shardings import macro_mesh
    axis, _, n = spec.partition("=")
    if axis != "macro" or not n.isdigit():
        raise SystemExit(f"--mesh expects macro=N, got {spec!r}")
    return macro_mesh(int(n))


def _parse_tile(spec):
    if not spec:
        return None
    bk, _, bn = spec.lower().partition("x")
    if not (bk.isdigit() and bn.isdigit() and int(bk) > 0 and int(bn) > 0):
        raise SystemExit(f"--tile expects BKxBN (e.g. 16x16), got {spec!r}")
    return (int(bk), int(bn))


def _serving_params(args, cfg, params):
    """Build (or boot) the ServingParams: the artifact flow runs the full
    search+quantize+prune+pack pipeline ONCE and later boots skip straight
    to weights-on-device."""
    if args.artifact:
        try:
            sp, meta = deployed.load_artifact(args.artifact)
        except FileNotFoundError:
            sp = None
        if sp is not None:
            if meta.get("arch") not in (None, cfg.name):
                raise SystemExit(
                    f"--artifact {args.artifact} holds arch "
                    f"{meta.get('arch')!r}, not {cfg.name!r} - point it at a "
                    "fresh directory to re-pack")
            if bool(meta.get("compressed", args.compressed)) != args.compressed:
                print(f"note: artifact was saved with compressed="
                      f"{meta.get('compressed')} - serving it as stored "
                      "(packing flags only apply when building)")
            print(f"artifact: loaded {args.artifact} "
                  f"(arch={meta.get('arch')}, no re-packing)")
            return sp
    sp = (deployed.compress(cfg, params, target_sparsity=args.target_sparsity,
                            schedule=(None if args.tile else
                                      deployed.default_schedule(cfg)),
                            tile=_parse_tile(args.tile))
          if args.compressed else deployed.from_params(cfg, params))
    if args.artifact:
        out = deployed.save_artifact(args.artifact, sp, cfg,
                                     extra={"compressed": args.compressed})
        print(f"artifact: packed + saved to {out}")
    return sp


def _batch(args, cfg, params):
    mesh = _parse_mesh(args.mesh)
    sp = _serving_params(args, cfg, params)
    if args.compressed:
        print("compression:", json.dumps(sp.report()))
    if mesh is not None:
        sp = deployed.shard(sp, mesh)
        n_sharded = sum(1 for dw in sp.deployed().values()
                        if dw.mesh is not None)
        print(f"macro mesh: {mesh.shape} - {n_sharded} projections "
              "column-sharded (rest replicated)")
    bcfg = BatchConfig(n_slots=args.slots, block_size=args.block_size,
                       n_blocks=args.kv_blocks)
    print(f"runtime: {args.runtime}"
          + (" (single jitted lax.scan decode step)"
             if args.runtime == "scan" else
             " (python loop over per-layer weights)"))
    srv = BatchServer(cfg, sp, ServeConfig(temperature=args.temperature,
                                           seed=args.seed), bcfg,
                      continuous=(args.engine == "batch"), mesh=mesh,
                      engine=args.runtime)
    trace = lambda: synthetic_trace(cfg, args.requests, args.prompt_len,
                                    args.new_tokens, seed=args.seed)
    srv.run(trace())  # compile
    rep = srv.run(trace())
    print(json.dumps(rep.to_json(), indent=1))
    for rid in list(rep.outputs)[:3]:
        print(f"  {rid}:", rep.outputs[rid].tolist())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=["batch", "static", "legacy"],
                    default="batch",
                    help="batch = continuous batching (default); static = "
                    "same server, whole-batch admission; legacy = Engine")
    ap.add_argument("--compressed", action="store_true",
                    help="serve deploy_weight-packed (BSR) projections")
    ap.add_argument("--runtime", choices=["loop", "scan"], default="loop",
                    help="decode runtime: loop = python loop over per-layer "
                    "weights; scan = one jitted lax.scan over the stacked "
                    "uniform envelope (bit-identical tokens)")
    ap.add_argument("--artifact", default="",
                    help="serving-artifact directory: boot from it when it "
                    "exists (no re-packing), else pack once and save there")
    ap.add_argument("--mesh", default="",
                    help="macro=N: shard compressed projections column-wise "
                    "and KV heads over an N-device macro cluster")
    ap.add_argument("--tile", default="",
                    help="BKxBN packing tile override (e.g. 16x16); default "
                    "is the searched schedule's tile")
    ap.add_argument("--target-sparsity", type=float, default=0.5)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--kv-blocks", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch, dtype=args.dtype) if args.smoke
           else registry.get_config(args.arch, dtype=args.dtype))
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(args.seed))

    use_legacy = args.engine == "legacy"
    if not use_legacy and cfg.family not in ("dense", "moe"):
        print(f"note: no batch-server path for the {cfg.family} family yet; "
              "falling back to the legacy Engine")
        use_legacy = True

    if use_legacy:
        if args.compressed:
            sp = _serving_params(args, cfg, params)
            print("compression:", json.dumps(sp.report()))
            if args.runtime == "scan":
                _legacy(args, cfg, stacked.stack(sp),
                        fns=stacked.model_fns(cfg))
            else:
                _legacy(args, cfg, sp, fns=deployed.model_fns(cfg))
        else:
            # uncompressed legacy serving already runs the registry's
            # scan-over-layers forward - both --runtime values coincide
            _legacy(args, cfg, params)
    else:
        _batch(args, cfg, params)


if __name__ == "__main__":
    main()
