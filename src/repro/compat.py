"""Version-compatibility shims for the jax API surface the repo uses.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` (0.4.x, with a
``check_rep`` kwarg) to the top level (>= 0.6, with ``check_vma``). Import
``shard_map`` from here; it accepts the new-style ``check_vma`` kwarg on
both versions.
"""
from __future__ import annotations

try:  # jax >= 0.6
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})
