"""Mapping search: grid/greedy co-exploration of the compression tiling.

CIM-Tuner's observation, applied to MARS: the (group x alpha) tile shape is
simultaneously (a) the pruning granularity, (b) the macro storage quantum,
and (c) the TPU kernel's block shape - so changing it trades skip
opportunity (smaller tiles -> more all-zero tiles survive pruning) against
per-cycle parallelism and index overhead (smaller tiles -> more tiles, more
codes, more reload waves). The search simulates each candidate tiling on
the event-driven model and returns the best schedule; the paper's own
16x16 mapping is always in the candidate set, so the result is never worse
than the default.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from ..core.perf_model import (DEFAULT_HW, HardwareConfig,
                               speculative_summary)

from .graph import LayerGraph, lm_graph
from .simulate import SimResult, simulate


@dataclasses.dataclass(frozen=True)
class MappingCandidate:
    """One point in the mapping space."""

    group: int  # weight-group size (input direction) = kernel bk
    alpha: int  # kernels per group-set (output direction) = kernel bn
    pipeline: bool = True

    @property
    def tile(self) -> Tuple[int, int]:
        return (self.group, self.alpha)


@dataclasses.dataclass
class CandidateResult:
    candidate: MappingCandidate
    fps: float
    cycles: float
    core_utilization: float

    def row(self) -> dict:
        return {
            "group": self.candidate.group,
            "alpha": self.candidate.alpha,
            "pipeline": self.candidate.pipeline,
            "fps": round(self.fps, 2),
            "cycles": round(self.cycles, 1),
            "core_utilization": round(self.core_utilization, 4),
        }


@dataclasses.dataclass
class SearchResult:
    best: CandidateResult
    default: CandidateResult
    table: List[CandidateResult]

    @property
    def speedup_vs_default(self) -> float:
        return self.best.fps / max(self.default.fps, 1e-9)


def default_candidate(hw: HardwareConfig = DEFAULT_HW,
                      pipeline: bool = True) -> MappingCandidate:
    return MappingCandidate(hw.group, hw.alpha, pipeline)


def tile_divides_graph(graph: LayerGraph, group: int, alpha: int) -> bool:
    """True when (group, alpha) exactly tiles EVERY node's 2-D workload
    (kh*kw*cin x cout) - the uniform-envelope feasibility predicate: one
    tile that ``pack_bsr`` accepts unchanged for the whole network."""
    return all(
        (n.layer.kh * n.layer.kw * n.layer.cin) % group == 0
        and n.layer.cout % alpha == 0
        for n in graph.nodes.values())


def uniform_tile_candidates(graph: LayerGraph,
                            groups: Sequence[int],
                            alphas: Sequence[int],
                            pipeline: bool = True) -> List[MappingCandidate]:
    """The subset of the (groups x alphas) grid that is network-uniform
    feasible (divides every layer)."""
    return [MappingCandidate(g, a, pipeline)
            for g in groups for a in alphas
            if tile_divides_graph(graph, g, a)]


def search_mapping(graph: LayerGraph, hw: HardwareConfig = DEFAULT_HW,
                   w_bits: int = 8, a_bits: int = 4,
                   groups: Sequence[int] = (8, 16, 32),
                   alphas: Sequence[int] = (8, 16, 32),
                   pipeline: bool = True,
                   budget: Optional[int] = None,
                   uniform: bool = False) -> SearchResult:
    """Grid search over tile shapes; ``budget`` caps simulated candidates
    (the default mapping never counts against it).

    ``uniform=True`` is the CIM-Tuner-style network-wide mode: only tiles
    that exactly divide EVERY layer's (d_in, d_out) are considered, so the
    winning (group, alpha) is directly the one packing envelope the whole
    network deploys with (``stack_deployed`` requires it). The default
    mapping is kept only if itself feasible; with no feasible candidate at
    all the search fails loudly rather than silently clipping per layer.
    """
    cands = [default_candidate(hw, pipeline)]
    for g in groups:
        for a in alphas:
            c = MappingCandidate(g, a, pipeline)
            if c not in cands:
                cands.append(c)
    has_default = True
    if uniform:
        cands = [c for c in cands
                 if tile_divides_graph(graph, c.group, c.alpha)]
        if not cands:
            raise ValueError(
                "search_mapping(uniform=True): no candidate tile divides "
                "every layer - widen groups/alphas (powers of two that "
                "divide the model dims always qualify)")
        has_default = cands[0] == default_candidate(hw, pipeline)
    if budget is not None:
        # the default mapping (when it survived filtering) rides for free;
        # always simulate at least one candidate so a reference row exists
        cands = cands[: max(int(has_default) + max(budget, 0), 1)]

    table: List[CandidateResult] = []
    for c in cands:
        res = simulate(graph, hw, w_bits, a_bits, pipeline=c.pipeline,
                       group=c.group, alpha=c.alpha, keep_events=True)
        table.append(CandidateResult(c, res.fps, res.cycles,
                                     res.core_utilization))
    default = table[0]
    best = max(table, key=lambda r: r.fps)
    return SearchResult(best, default, table)


# ---------------------------------------------------------------------------
# Speculative two-tier search: pick (family, k, knob) from simulated cost
# and the CALIBRATED acceptance prior
# ---------------------------------------------------------------------------

CALIBRATION_SCHEMA = 1


@dataclasses.dataclass
class SpecCalibration:
    """Measured acceptance prior, keyed (arch, family, gap).

    Every served spec run measures an acceptance rate at one point of the
    draft-knob space; this cache accumulates those points and interpolates
    between them, replacing ``default_accept_model``'s linear guess with
    data. ``gap`` is the family's normalized how-much-the-draft-gives-up
    coordinate: ``draft_sparsity - target_sparsity`` for reprune,
    ``1 - keep`` for layerskip - one axis per family, so measurements at
    different absolute sparsities still pool.

    Persisted like the autotune cache: ``to_json`` into the serving-
    artifact manifest (``spec_calibration`` key) and alongside the bench
    history JSONL, ``from_json`` back with hard schema validation
    (malformed calibration fails loudly, never silently mis-prices)."""

    rows: List[dict] = dataclasses.field(default_factory=list)

    def add(self, arch: str, family: str, gap: float, accept: float,
            weight: float = 1.0) -> None:
        """Fold in one measured point. ``weight`` should scale with the
        evidence (e.g. the number of proposed tokens behind the rate)."""
        if not 0.0 <= accept <= 1.0:
            raise ValueError(f"calibration: accept {accept} not in [0, 1]")
        if weight <= 0.0:
            raise ValueError(f"calibration: weight {weight} must be > 0")
        self.rows.append({"arch": str(arch), "family": str(family),
                          "gap": float(gap), "accept": float(accept),
                          "weight": float(weight)})

    # how far (in gap units) a measurement's influence reaches before the
    # fit falls back toward the uncalibrated prior: one point at gap=0.5
    # must NOT promise its acceptance at gap=0.75 unseen
    TRUST_RADIUS = 0.1

    def accept_model(self, arch: str, family: str,
                     prior: Optional[Callable[[float], float]] = None
                     ) -> Optional[Callable[[float], float]]:
        """Fitted gap -> acceptance for one (arch, family), or None when no
        measurements exist. Inverse-distance-weighted over the measured
        points (times their evidence weight): exact re-queries reproduce
        the measurement, in-between gaps interpolate. ``prior`` (an
        uncalibrated gap -> accept fallback) bounds extrapolation: trust
        in the interpolation decays with the distance to the NEAREST
        measured point, so a query far from all data answers mostly from
        the prior instead of flat-extrapolating one measurement across
        the whole knob axis."""
        pts = [r for r in self.rows
               if r["arch"] == arch and r["family"] == family]
        if not pts:
            return None

        def model(gap: float) -> float:
            num = den = 0.0
            d_min = min(abs(gap - r["gap"]) for r in pts)
            for r in pts:
                w = r["weight"] / (1e-3 + abs(gap - r["gap"]))
                num += w * r["accept"]
                den += w
            fit = num / den
            if prior is not None:
                trust = self.TRUST_RADIUS / (self.TRUST_RADIUS + d_min)
                fit = trust * fit + (1.0 - trust) * prior(gap)
            return min(1.0, max(0.0, fit))

        return model

    def to_json(self) -> dict:
        return {"schema": CALIBRATION_SCHEMA, "rows": list(self.rows)}

    @classmethod
    def from_json(cls, d: dict) -> "SpecCalibration":
        if not isinstance(d, dict) or d.get("schema") != CALIBRATION_SCHEMA:
            raise ValueError(
                f"spec calibration: unsupported schema {d.get('schema')!r} "
                f"(supported: {CALIBRATION_SCHEMA})")
        rows = d.get("rows")
        if not isinstance(rows, list):
            raise ValueError("spec calibration: rows is not a list")
        cal = cls()
        for i, r in enumerate(rows):
            try:
                cal.add(r["arch"], r["family"], r["gap"], r["accept"],
                        r.get("weight", 1.0))
            except (KeyError, TypeError, ValueError) as e:
                raise ValueError(f"spec calibration: row {i}: {e}")
        return cal


@dataclasses.dataclass
class SpecSearchResult:
    """Winner + full table of the (family, knob, k) grid. Each row is a
    ``perf_model.speculative_summary`` dict extended with the simulated
    per-step draft cost, family and knob."""

    best: dict
    table: List[dict]

    @property
    def decision(self) -> dict:
        """The auto-policy verdict: serve speculation with the winning
        (family, k, knob), or DECLINE - fall back to the scan engine -
        when even the best modeled candidate loses to target-only decode.
        No configuration may silently ship a speculation loss."""
        b = self.best
        d = {"verdict": ("spec" if b["speedup_vs_target"] > 1.0
                         else "declined"),
             "family": b["family"], "k": b["k"],
             "knob": (b["draft_sparsity"] if b["family"] == "reprune"
                      else b["keep"]),
             "predicted_speedup": b["speedup_vs_target"],
             "accept": b["accept"], "accept_source": b["accept_source"]}
        if d["verdict"] == "declined":
            d["reason"] = "scan wins"
        return d


def default_accept_model(draft_sparsity: float,
                         target_sparsity: float) -> float:
    """Crude reprune acceptance prior: agreement decays linearly with the
    extra sparsity the draft tier gives up over the target. This is the
    UNCALIBRATED fallback - measured :class:`SpecCalibration` rows replace
    it as soon as one spec run has been served."""
    return min(1.0, max(0.0, 1.0 - (draft_sparsity - target_sparsity)))


def default_accept_model_layerskip(keep: float) -> float:
    """Uncalibrated layerskip prior: agreement ~ the kept-sublayer
    fraction (keep=1 is the target itself). Same caveat as
    :func:`default_accept_model` - measurements override it."""
    return min(1.0, max(0.0, keep))


def search_spec(cfg, *, hw: HardwareConfig = DEFAULT_HW, w_bits: int = 8,
                a_bits: int = 8, target_sparsity: float = 0.6,
                draft_sparsities: Sequence[float] = (0.75, 0.85, 0.9, 0.95),
                ks: Sequence[int] = (2, 3, 4, 6, 8),
                keeps: Sequence[float] = (0.25, 0.5, 0.75),
                families: Sequence[str] = ("reprune", "layerskip"),
                group: int = 16, alpha: int = 16,
                accept_model: Optional[Callable[[float, float], float]] = None,
                calibration: Optional[SpecCalibration] = None,
                arch: Optional[str] = None) -> SpecSearchResult:
    """Pick the speculative (family, k, draft knob) from SIMULATED cost and
    the best available acceptance prior.

    Cost: the event-driven simulator prices a one-token draft step for
    every candidate - a re-pruned graph at each ``draft_sparsities`` for
    the reprune family; the kept-sublayer fraction of a target step for
    each ``keeps`` of the layerskip family (its draft IS the target
    envelope, so its per-step cost scales with the executed sublayers, and
    its rounds run k draft steps, not k+1 - no second KV cache to fill).
    For every k the (k+1)-token target verify pass is priced once.

    Acceptance: ``calibration`` (measured :class:`SpecCalibration` rows
    for ``arch``, default ``cfg.name``) beats the explicit
    ``accept_model`` callable (reprune-only, legacy signature), beats the
    uncalibrated linear priors. Each row records which source priced it
    (``accept_source``).

    The winner maximizes expected tokens/cycle; ``result.decision``
    declines speculation outright when even the winner models below
    target-only throughput."""
    arch = arch if arch is not None else getattr(cfg, "name", "unknown")
    c_target_step = simulate(lm_graph(cfg, seq_len=1,
                                      sparsity_gs=target_sparsity),
                             hw, w_bits, a_bits, group=group,
                             alpha=alpha).cycles
    verify_cost = {k: simulate(lm_graph(cfg, seq_len=k + 1,
                                        sparsity_gs=target_sparsity),
                               hw, w_bits, a_bits, group=group,
                               alpha=alpha).cycles
                   for k in ks}
    table: List[dict] = []

    def add_rows(family: str, knob: float, gap: float, c_draft: float,
                 draft_steps_of) -> None:
        # both families' uncalibrated priors are max(0, 1 - gap) in gap
        # space (reprune: 1 - (ds - ts); layerskip: keep = 1 - gap)
        gap_prior = lambda g: min(1.0, max(0.0, 1.0 - g))
        fitted = (calibration.accept_model(arch, family, prior=gap_prior)
                  if calibration is not None else None)
        if fitted is not None:
            accept, source = fitted(gap), "calibrated"
        elif family == "reprune" and accept_model is not None:
            accept, source = accept_model(knob, target_sparsity), "model"
        elif family == "reprune":
            accept, source = default_accept_model(knob, target_sparsity), \
                "prior"
        else:
            accept, source = default_accept_model_layerskip(knob), "prior"
        for k in ks:
            row = speculative_summary(c_draft, verify_cost[k], k, accept,
                                      draft_steps=draft_steps_of(k))
            row["family"] = family
            if family == "reprune":
                row["draft_sparsity"] = knob
            else:
                row["keep"] = knob
            row["gap"] = round(gap, 4)
            row["accept_source"] = source
            row["draft_step_cycles"] = round(c_draft, 1)
            # tokens/cycle speculative vs the target's 1 token / step
            row["speedup_vs_target"] = round(
                row["tokens_per_round"] * c_target_step
                / max(row["cycles_per_round"], 1e-9), 4)
            table.append(row)

    if "reprune" in families:
        for ds in draft_sparsities:
            c_draft = simulate(lm_graph(cfg, seq_len=1, sparsity_gs=ds),
                               hw, w_bits, a_bits, group=group,
                               alpha=alpha).cycles
            add_rows("reprune", ds, ds - target_sparsity, c_draft,
                     lambda k: k + 1)
    if "layerskip" in families:
        for keep in keeps:
            add_rows("layerskip", keep, 1.0 - keep, keep * c_target_step,
                     lambda k: k)
    if not table:
        raise ValueError(f"search_spec: no known family in {families!r}")
    best = max(table, key=lambda r: r["tokens_per_kcycle"])
    return SpecSearchResult(best, table)


def greedy_search(graph: LayerGraph, hw: HardwareConfig = DEFAULT_HW,
                  w_bits: int = 8, a_bits: int = 4,
                  steps: Sequence[int] = (8, 16, 32, 64),
                  pipeline: bool = True) -> SearchResult:
    """Coordinate-descent alternative to the full grid: optimize ``group``
    with alpha fixed at the default, then ``alpha`` at the winning group.
    Simulates O(2k) candidates instead of O(k^2)."""
    table: List[CandidateResult] = []

    def ev(c: MappingCandidate) -> CandidateResult:
        for t in table:
            if t.candidate == c:
                return t
        res = simulate(graph, hw, w_bits, a_bits, pipeline=c.pipeline,
                       group=c.group, alpha=c.alpha)
        r = CandidateResult(c, res.fps, res.cycles, res.core_utilization)
        table.append(r)
        return r

    default = ev(default_candidate(hw, pipeline))
    best = default
    for g in steps:
        best = max(best, ev(MappingCandidate(g, hw.alpha, pipeline)),
                   key=lambda r: r.fps)
    for a in steps:
        best = max(best, ev(MappingCandidate(best.candidate.group, a,
                                             pipeline)),
                   key=lambda r: r.fps)
    return SearchResult(best, default, table)
