"""Mapping search: grid/greedy co-exploration of the compression tiling.

CIM-Tuner's observation, applied to MARS: the (group x alpha) tile shape is
simultaneously (a) the pruning granularity, (b) the macro storage quantum,
and (c) the TPU kernel's block shape - so changing it trades skip
opportunity (smaller tiles -> more all-zero tiles survive pruning) against
per-cycle parallelism and index overhead (smaller tiles -> more tiles, more
codes, more reload waves). The search simulates each candidate tiling on
the event-driven model and returns the best schedule; the paper's own
16x16 mapping is always in the candidate set, so the result is never worse
than the default.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from ..core.perf_model import (DEFAULT_HW, HardwareConfig,
                               speculative_summary)

from .graph import LayerGraph, lm_graph
from .simulate import SimResult, simulate


@dataclasses.dataclass(frozen=True)
class MappingCandidate:
    """One point in the mapping space."""

    group: int  # weight-group size (input direction) = kernel bk
    alpha: int  # kernels per group-set (output direction) = kernel bn
    pipeline: bool = True

    @property
    def tile(self) -> Tuple[int, int]:
        return (self.group, self.alpha)


@dataclasses.dataclass
class CandidateResult:
    candidate: MappingCandidate
    fps: float
    cycles: float
    core_utilization: float

    def row(self) -> dict:
        return {
            "group": self.candidate.group,
            "alpha": self.candidate.alpha,
            "pipeline": self.candidate.pipeline,
            "fps": round(self.fps, 2),
            "cycles": round(self.cycles, 1),
            "core_utilization": round(self.core_utilization, 4),
        }


@dataclasses.dataclass
class SearchResult:
    best: CandidateResult
    default: CandidateResult
    table: List[CandidateResult]

    @property
    def speedup_vs_default(self) -> float:
        return self.best.fps / max(self.default.fps, 1e-9)


def default_candidate(hw: HardwareConfig = DEFAULT_HW,
                      pipeline: bool = True) -> MappingCandidate:
    return MappingCandidate(hw.group, hw.alpha, pipeline)


def tile_divides_graph(graph: LayerGraph, group: int, alpha: int) -> bool:
    """True when (group, alpha) exactly tiles EVERY node's 2-D workload
    (kh*kw*cin x cout) - the uniform-envelope feasibility predicate: one
    tile that ``pack_bsr`` accepts unchanged for the whole network."""
    return all(
        (n.layer.kh * n.layer.kw * n.layer.cin) % group == 0
        and n.layer.cout % alpha == 0
        for n in graph.nodes.values())


def uniform_tile_candidates(graph: LayerGraph,
                            groups: Sequence[int],
                            alphas: Sequence[int],
                            pipeline: bool = True) -> List[MappingCandidate]:
    """The subset of the (groups x alphas) grid that is network-uniform
    feasible (divides every layer)."""
    return [MappingCandidate(g, a, pipeline)
            for g in groups for a in alphas
            if tile_divides_graph(graph, g, a)]


def search_mapping(graph: LayerGraph, hw: HardwareConfig = DEFAULT_HW,
                   w_bits: int = 8, a_bits: int = 4,
                   groups: Sequence[int] = (8, 16, 32),
                   alphas: Sequence[int] = (8, 16, 32),
                   pipeline: bool = True,
                   budget: Optional[int] = None,
                   uniform: bool = False) -> SearchResult:
    """Grid search over tile shapes; ``budget`` caps simulated candidates
    (the default mapping never counts against it).

    ``uniform=True`` is the CIM-Tuner-style network-wide mode: only tiles
    that exactly divide EVERY layer's (d_in, d_out) are considered, so the
    winning (group, alpha) is directly the one packing envelope the whole
    network deploys with (``stack_deployed`` requires it). The default
    mapping is kept only if itself feasible; with no feasible candidate at
    all the search fails loudly rather than silently clipping per layer.
    """
    cands = [default_candidate(hw, pipeline)]
    for g in groups:
        for a in alphas:
            c = MappingCandidate(g, a, pipeline)
            if c not in cands:
                cands.append(c)
    has_default = True
    if uniform:
        cands = [c for c in cands
                 if tile_divides_graph(graph, c.group, c.alpha)]
        if not cands:
            raise ValueError(
                "search_mapping(uniform=True): no candidate tile divides "
                "every layer - widen groups/alphas (powers of two that "
                "divide the model dims always qualify)")
        has_default = cands[0] == default_candidate(hw, pipeline)
    if budget is not None:
        # the default mapping (when it survived filtering) rides for free;
        # always simulate at least one candidate so a reference row exists
        cands = cands[: max(int(has_default) + max(budget, 0), 1)]

    table: List[CandidateResult] = []
    for c in cands:
        res = simulate(graph, hw, w_bits, a_bits, pipeline=c.pipeline,
                       group=c.group, alpha=c.alpha, keep_events=True)
        table.append(CandidateResult(c, res.fps, res.cycles,
                                     res.core_utilization))
    default = table[0]
    best = max(table, key=lambda r: r.fps)
    return SearchResult(best, default, table)


# ---------------------------------------------------------------------------
# Speculative two-tier search: pick (draft_sparsity, k) from simulated cost
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpecSearchResult:
    """Winner + full table of the (draft_sparsity, k) grid. Each row is a
    ``perf_model.speculative_summary`` dict extended with the simulated
    per-step draft cost."""

    best: dict
    table: List[dict]


def default_accept_model(draft_sparsity: float,
                         target_sparsity: float) -> float:
    """Crude acceptance prior: agreement decays linearly with the extra
    sparsity the draft tier gives up over the target. This is a
    CALIBRATION KNOB, not physics - pass a measured model (e.g. fitted to
    ``BENCH_serve.json``'s spec row) for real deployments."""
    return min(1.0, max(0.0, 1.0 - (draft_sparsity - target_sparsity)))


def search_spec(cfg, *, hw: HardwareConfig = DEFAULT_HW, w_bits: int = 8,
                a_bits: int = 8, target_sparsity: float = 0.6,
                draft_sparsities: Sequence[float] = (0.75, 0.85, 0.9, 0.95),
                ks: Sequence[int] = (2, 3, 4, 6, 8),
                group: int = 16, alpha: int = 16,
                accept_model: Optional[Callable[[float, float], float]] = None
                ) -> SpecSearchResult:
    """Pick the speculative (draft_sparsity, k) from SIMULATED cost.

    For every candidate draft sparsity the event-driven simulator prices a
    one-token draft decode step (its reload + compute over the projection
    graph at that sparsity); for every k it prices the (k+1)-token target
    verify pass. ``perf_model.speculative_summary`` combines them with the
    acceptance prior into expected tokens/cycle; the best row wins. The
    target tier's own one-token cost is simulated too, so the winner's
    ``speedup_vs_target`` says whether speculation pays at all under the
    modeled acceptance.
    """
    accept_model = accept_model or default_accept_model
    c_target_step = simulate(lm_graph(cfg, seq_len=1,
                                      sparsity_gs=target_sparsity),
                             hw, w_bits, a_bits, group=group,
                             alpha=alpha).cycles
    verify_cost = {k: simulate(lm_graph(cfg, seq_len=k + 1,
                                        sparsity_gs=target_sparsity),
                               hw, w_bits, a_bits, group=group,
                               alpha=alpha).cycles
                   for k in ks}
    table: List[dict] = []
    for ds in draft_sparsities:
        c_draft = simulate(lm_graph(cfg, seq_len=1, sparsity_gs=ds),
                           hw, w_bits, a_bits, group=group,
                           alpha=alpha).cycles
        accept = accept_model(ds, target_sparsity)
        for k in ks:
            row = speculative_summary(c_draft, verify_cost[k], k, accept)
            row["draft_sparsity"] = ds
            row["draft_step_cycles"] = round(c_draft, 1)
            # tokens/cycle speculative vs the target's 1 token / step
            row["speedup_vs_target"] = round(
                row["tokens_per_round"] * c_target_step
                / max(row["cycles_per_round"], 1e-9), 4)
            table.append(row)
    best = max(table, key=lambda r: r["tokens_per_kcycle"])
    return SpecSearchResult(best, table)


def greedy_search(graph: LayerGraph, hw: HardwareConfig = DEFAULT_HW,
                  w_bits: int = 8, a_bits: int = 4,
                  steps: Sequence[int] = (8, 16, 32, 64),
                  pipeline: bool = True) -> SearchResult:
    """Coordinate-descent alternative to the full grid: optimize ``group``
    with alpha fixed at the default, then ``alpha`` at the winning group.
    Simulates O(2k) candidates instead of O(k^2)."""
    table: List[CandidateResult] = []

    def ev(c: MappingCandidate) -> CandidateResult:
        for t in table:
            if t.candidate == c:
                return t
        res = simulate(graph, hw, w_bits, a_bits, pipeline=c.pipeline,
                       group=c.group, alpha=c.alpha)
        r = CandidateResult(c, res.fps, res.cycles, res.core_utilization)
        table.append(r)
        return r

    default = ev(default_candidate(hw, pipeline))
    best = default
    for g in steps:
        best = max(best, ev(MappingCandidate(g, hw.alpha, pipeline)),
                   key=lambda r: r.fps)
    for a in steps:
        best = max(best, ev(MappingCandidate(best.candidate.group, a,
                                             pipeline)),
                   key=lambda r: r.fps)
    return SearchResult(best, default, table)
