"""Layer-graph extraction: model configs -> a DAG of CIM workload nodes.

Every schedulable unit (a conv layer or a CIM-mapped LM projection) becomes
a :class:`LayerNode` wrapping the ``perf_model.ConvLayer`` workload view
(a matmul over T tokens is a 1x1 conv with a 1 x T output plane). Edges are
data dependencies; the simulator consumes nodes in topological order and
uses edges to decide when a layer's activations exist.

Extractors:
  * ``graph_from_layers``  - linear chain from a perf-model layer table
    (used to cross-validate the simulator against ``summarize``).
  * ``vgg16_graph`` / ``resnet18_graph`` - the paper's CIFAR networks;
    ResNet18 is a real DAG (residual skips + 1x1 downsample convs).
  * ``lm_graph`` - CIM-mapped projections of a transformer ``ModelConfig``
    (QKV/O + MLP per block) as matmul nodes over a token batch.

Nodes may carry an actual 2-D weight (``kh*kw*cin x cout``); the allocator
then counts surviving group-sets exactly instead of using the layer's
``sparsity_gs`` profile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core import perf_model as PM
from ..core.perf_model import ConvLayer


@dataclasses.dataclass
class LayerNode:
    """One schedulable workload node in the layer DAG."""

    name: str
    layer: ConvLayer
    deps: Tuple[str, ...] = ()
    kind: str = "conv"  # conv | matmul
    weight: Optional[np.ndarray] = None  # optional (kh*kw*cin, cout) weight

    def kernel_group_counts(self, group: int, alpha: int,
                            dense: bool = False) -> np.ndarray:
        """Nonzero group-sets per kernel-group (output-group) column.

        The allocator balances these counts across cores. With a real
        weight attached the count is exact; otherwise the layer's
        ``sparsity_gs`` profile is spread evenly over the columns.
        """
        l = self.layer
        go = -(-l.cout // alpha)
        wg = l.kh * l.kw * -(-l.cin // group)
        if dense:
            return np.full(go, wg, dtype=np.int64)
        if self.weight is not None:
            return _exact_counts(self.weight, group, alpha)
        nnz = l.nnz_for(group, alpha)
        counts = np.full(go, nnz // go, dtype=np.int64)
        counts[: nnz % go] += 1
        return np.minimum(counts, wg)


def _exact_counts(w2d: np.ndarray, group: int, alpha: int) -> np.ndarray:
    d_in, d_out = w2d.shape
    gi, go = -(-d_in // group), -(-d_out // alpha)
    wp = np.zeros((gi * group, go * alpha), dtype=w2d.dtype)
    wp[:d_in, :d_out] = w2d
    tiles = wp.reshape(gi, group, go, alpha)
    alive = np.any(tiles != 0, axis=(1, 3))  # (gi, go)
    return alive.sum(axis=0).astype(np.int64)


@dataclasses.dataclass
class LayerGraph:
    nodes: Dict[str, LayerNode]

    def __post_init__(self) -> None:
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise ValueError(f"{n.name} depends on unknown node {d}")

    def topo_order(self) -> List[str]:
        """Kahn topological order (raises on cycles)."""
        indeg = {k: len(v.deps) for k, v in self.nodes.items()}
        succs: Dict[str, List[str]] = {k: [] for k in self.nodes}
        for k, v in self.nodes.items():
            for d in v.deps:
                succs[d].append(k)
        ready = [k for k, d in indeg.items() if d == 0]
        out: List[str] = []
        while ready:
            k = ready.pop(0)
            out.append(k)
            for s in succs[k]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(out) != len(self.nodes):
            raise ValueError("layer graph has a cycle")
        return out

    def layers(self) -> List[ConvLayer]:
        """Workload views in topological order (perf-model compatible)."""
        return [self.nodes[k].layer for k in self.topo_order()]

    @property
    def total_macs(self) -> int:
        return sum(n.layer.macs for n in self.nodes.values())


def graph_from_layers(layers: Sequence[ConvLayer],
                      names: Optional[Sequence[str]] = None) -> LayerGraph:
    """Linear chain over a perf-model layer table."""
    nodes: Dict[str, LayerNode] = {}
    prev: Tuple[str, ...] = ()
    for i, l in enumerate(layers):
        name = names[i] if names else f"L{i}_{l.kh}x{l.kw}x{l.cin}x{l.cout}"
        nodes[name] = LayerNode(name, l, deps=prev)
        prev = (name,)
    return LayerGraph(nodes)


def vgg16_graph(sparsity_per_layer: Optional[Sequence[float]] = None) -> LayerGraph:
    """VGG16-CIFAR chain with the paper's Table IV sparsity profile."""
    return graph_from_layers(PM.vgg16_cifar_layers(sparsity_per_layer))


def resnet18_graph(sparsity_per_layer: Optional[Sequence[float]] = None) -> LayerGraph:
    """ResNet18-CIFAR as a true DAG: stem, 8 residual blocks with skip
    edges, and the three 1x1 downsample convs the chain table omits.

    The residual add happens in the APW block, so a block's consumers
    simply depend on every producer of the stream (conv2 + the skip path).
    """
    chain = PM.resnet18_cifar_layers(sparsity_per_layer)
    stem, convs = chain[0], chain[1:]
    nodes: Dict[str, LayerNode] = {"stem": LayerNode("stem", stem)}
    prev: Tuple[str, ...] = ("stem",)  # producers of the residual stream
    ci = 0
    stages = [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)]
    cin = 64
    for si, (width, nblocks, stride) in enumerate(stages):
        for b in range(nblocks):
            c1, c2 = convs[ci], convs[ci + 1]
            n1, n2 = f"s{si}b{b}_conv1", f"s{si}b{b}_conv2"
            nodes[n1] = LayerNode(n1, c1, deps=prev)
            nodes[n2] = LayerNode(n2, c2, deps=(n1,))
            producers = [n2]
            if b == 0 and (stride != 1 or cin != width):
                nd = f"s{si}b{b}_down"
                down = ConvLayer(1, 1, cin, width, c2.out_h, c2.out_w,
                                 c2.sparsity_gs)
                nodes[nd] = LayerNode(nd, down, deps=prev)
                producers.append(nd)
            else:
                producers.extend(prev)  # identity skip feeds the add too
            prev = tuple(dict.fromkeys(producers))
            ci += 2
            cin = width
    return LayerGraph(nodes)


def lm_graph(cfg, seq_len: int = 512, sparsity_gs: float = 0.75,
             n_layers: Optional[int] = None) -> LayerGraph:
    """CIM-mapped projections of a transformer block stack.

    Each projection is a matmul node computing (seq_len, d_in) @ (d_in,
    d_out) - i.e. a 1x1 conv with a 1 x seq_len output plane. Attention
    math itself (softmax, RoPE) stays on the digital side and is not a CIM
    workload; QKV/O and the MLP projections are.
    """
    d = cfg.d_model
    hd = cfg.head_dim or d // cfg.n_heads
    dq, dkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    L = cfg.n_layers if n_layers is None else min(n_layers, cfg.n_layers)

    def mm(cin: int, cout: int) -> ConvLayer:
        return ConvLayer(1, 1, cin, cout, 1, seq_len, sparsity_gs)

    nodes: Dict[str, LayerNode] = {}
    prev: Tuple[str, ...] = ()
    for i in range(L):
        q, k, v = f"blk{i}_wq", f"blk{i}_wk", f"blk{i}_wv"
        o, up, gate, down = (f"blk{i}_wo", f"blk{i}_w_up",
                             f"blk{i}_w_gate", f"blk{i}_w_down")
        nodes[q] = LayerNode(q, mm(d, dq), deps=prev, kind="matmul")
        nodes[k] = LayerNode(k, mm(d, dkv), deps=prev, kind="matmul")
        nodes[v] = LayerNode(v, mm(d, dkv), deps=prev, kind="matmul")
        nodes[o] = LayerNode(o, mm(dq, d), deps=(q, k, v), kind="matmul")
        nodes[up] = LayerNode(up, mm(d, cfg.d_ff), deps=(o,), kind="matmul")
        nodes[gate] = LayerNode(gate, mm(d, cfg.d_ff), deps=(o,), kind="matmul")
        nodes[down] = LayerNode(down, mm(cfg.d_ff, d), deps=(up, gate),
                                kind="matmul")
        prev = (down,)
    return LayerGraph(nodes)


def attach_weights(graph: LayerGraph, weights: Dict[str, np.ndarray]) -> LayerGraph:
    """Attach real 2-D weights (kh*kw*cin, cout) to named nodes; the
    allocator then uses exact group-set survival counts."""
    for name, w in weights.items():
        node = graph.nodes[name]
        l = node.layer
        expect = (l.kh * l.kw * l.cin, l.cout)
        if tuple(w.shape) != expect:
            raise ValueError(f"{name}: weight {w.shape} != expected {expect}")
        node.weight = np.asarray(w)
    return graph
