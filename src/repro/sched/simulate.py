"""Event-driven simulation of the MARS fabric (replaces the closed-form
``max(compute, fm) + reload`` approximation of ``core.perf_model``).

Discrete-event engine at reload-wave granularity. Modeled resources, per
core:

  * one weight-SRAM -> macro write port (RELOAD events);
  * two 64 Kb macro buffers, ping-ponged: while one macro computes a wave
    the write port refills the other, so steady-state reload is hidden
    behind compute and only the first wave's fill is exposed (the
    closed-form model charges the full reload serially - the main place
    the two disagree, by design);
  * one MAC path issuing one group-set per CIM cycle (COMPUTE events);
    the shunter grants the core one FM-SRAM access per cycle, so a wave
    occupies the core for max(compute, fm) cycles - IFM fetches ride
    under the MACs unless the layer is fetch-bound (w4a4);
  * a per-layer APW event (adder/partial-sum write-back + controller),
    ``ctrl_overhead`` cycles per output pixel, emitted once every core
    has finished the layer's waves.

Inter-layer behavior follows the DAG: a layer's COMPUTE cannot start
before every dependency's APW has retired (activations exist), but with
``pipeline=True`` its RELOAD may - weights are static, so each core
prefetches the next layer's first wave into whichever macro buffer is
free while the current layer still computes. ``pipeline=False`` holds
reloads until dependencies retire, which is the closest event-level
analogue of the closed-form model and is what the cross-validation test
compares against ``perf_model.summarize``.

Known simplification: concurrent layers (ResNet down paths, LM QKV) share
the four cores by interleaving waves, not by a cycle-level arbiter; the
FIFO order the scheduler emits is what the hardware's static schedule
would pin anyway.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.perf_model import DEFAULT_HW, ConvLayer, HardwareConfig

from . import allocate as A
from .graph import LayerGraph, LayerNode, graph_from_layers

RELOAD, COMPUTE, APW = "reload", "compute", "apw"


@dataclasses.dataclass(frozen=True)
class SimEvent:
    """One completed occupancy interval on a resource (the event log)."""

    t_start: float
    t_end: float
    kind: str  # reload | compute | apw
    layer: str
    core: int  # -1 for network-level APW
    wave: int


@dataclasses.dataclass
class LayerTiming:
    name: str
    t_start: float  # first reload start
    t_compute: float  # first compute start
    t_end: float  # APW retire
    compute_cycles: float
    reload_cycles: float
    fm_cycles: float
    stall_cycles: float  # compute idle between ready and retire


@dataclasses.dataclass
class SimResult:
    cycles: float  # makespan (CIM cycles)
    fps: float
    layers: List[LayerTiming]
    events: List[SimEvent]
    hw: HardwareConfig
    w_bits: int
    a_bits: int
    compute_busy_total: float = 0.0  # MAC-path cycles summed over cores

    @property
    def core_utilization(self) -> float:
        return self.compute_busy_total / max(self.hw.cores * self.cycles, 1e-9)

    def summary(self) -> dict:
        return {
            "cycles": round(self.cycles, 1),
            "fps": round(self.fps, 2),
            "core_utilization": round(self.core_utilization, 4),
            "n_layers": len(self.layers),
            "n_events": len(self.events),
        }


@dataclasses.dataclass
class _Wave:
    layer: str
    wave: int
    groupsets: int
    compute: float  # cycles once issued
    fm: float
    reload: float
    last: bool  # last wave of this (layer, core)


class _Core:
    """Per-core state machine: reload port + 2 macro buffers + MAC path."""

    def __init__(self, cid: int):
        self.cid = cid
        self.reload_q: List[_Wave] = []  # FIFO awaiting the write port
        self.loaded_q: List[Tuple[_Wave, float]] = []  # (wave, load_done)
        self.reload_busy = False
        self.compute_busy = False
        self.buffers_free = 2  # ping-pong macros not holding live weights
        self.t_reload_free = 0.0
        self.t_compute_free = 0.0


def _layer_waves(node: LayerNode, alloc: A.LayerAllocation,
                 hw: HardwareConfig, w_bits: int, a_bits: int,
                 dense: bool) -> List[List[_Wave]]:
    """Cut one layer into per-core wave task lists."""
    l = node.layer
    pass_f = hw.pass_factor(w_bits, a_bits)
    out: List[List[_Wave]] = []
    for asg in alloc.assignments:
        waves: List[_Wave] = []
        n_kg = len(asg.kernel_groups)
        for v, gs in enumerate(asg.waves):
            compute = l.out_pixels * gs * pass_f
            fm = float(l.out_pixels * gs)  # one IFM fetch per (pixel, gs)
            if v == len(asg.waves) - 1:  # OFM partial-sum writes drain last
                fm += l.out_pixels * n_kg
            reload = hw.reload_cycles(gs, w_bits, alloc.group, alloc.alpha)
            waves.append(_Wave(node.name, v, gs, compute, fm, reload,
                               last=v == len(asg.waves) - 1))
        out.append(waves)
    return out


def simulate(graph: LayerGraph | Sequence[ConvLayer],
             hw: HardwareConfig = DEFAULT_HW, w_bits: int = 8,
             a_bits: int = 4, *, dense: bool = False, pipeline: bool = True,
             group: Optional[int] = None, alpha: Optional[int] = None,
             keep_events: bool = True) -> SimResult:
    """Simulate one inference frame over the layer DAG.

    ``dense=True`` runs the no-skip baseline (every group-set computed and
    fetched); ``group``/``alpha`` override the paper's 16x16 tiling for
    mapping search.
    """
    if not isinstance(graph, LayerGraph):
        graph = graph_from_layers(graph)
    order = graph.topo_order()
    g = hw.group if group is None else group
    a = hw.alpha if alpha is None else alpha

    allocs = {n: A.allocate_node(graph.nodes[n], hw, w_bits, g, a, dense=dense)
              for n in order}
    waves = {n: _layer_waves(graph.nodes[n], allocs[n], hw, w_bits, a_bits,
                             dense) for n in order}

    cores = [_Core(c) for c in range(hw.cores)]
    seq = itertools.count()
    heap: List[Tuple[float, int, str, int, Optional[_Wave]]] = []
    events: List[SimEvent] = []
    timing: Dict[str, LayerTiming] = {}
    retired: Dict[str, float] = {}  # layer -> APW retire time
    pending_compute: Dict[str, int] = {}  # (layer) -> waves still to compute
    compute_busy: Dict[str, float] = {}  # layer -> MAC-path cycles occupied
    reload_busy: Dict[str, float] = {}  # layer -> write-port cycles occupied
    reload_started: Dict[str, float] = {}
    compute_started: Dict[str, float] = {}
    released: set = set()  # layers whose waves entered reload queues
    compute_ready: set = set()  # layers whose deps have retired

    def deps_retired(name: str) -> bool:
        return all(d in retired for d in graph.nodes[name].deps)

    def release(name: str, now: float) -> None:
        """Queue a layer's waves on its cores' reload FIFOs."""
        released.add(name)
        total = 0
        for c, wl in enumerate(waves[name]):
            cores[c].reload_q.extend(wl)
            total += len(wl)
        pending_compute[name] = total
        if total == 0:  # degenerate empty layer: retire instantly
            _retire(name, now)

    def _retire(name: str, now: float) -> None:
        retired[name] = now
        for s in order:
            if s not in compute_ready and deps_retired(s):
                compute_ready.add(s)
                if not pipeline and s not in released:
                    release(s, now)

    def kick(core: _Core, now: float) -> None:
        """Start whatever this core can legally start at ``now``."""
        # reload: port idle + a free macro buffer + head-of-queue exists
        if (not core.reload_busy and core.buffers_free > 0 and core.reload_q):
            w = core.reload_q.pop(0)
            core.reload_busy = True
            core.buffers_free -= 1
            t0 = max(now, core.t_reload_free)
            t1 = t0 + w.reload
            core.t_reload_free = t1
            reload_started.setdefault(w.layer, t0)
            reload_busy[w.layer] = reload_busy.get(w.layer, 0.0) + (t1 - t0)
            heapq.heappush(heap, (t1, next(seq), RELOAD, core.cid, w))
            if keep_events:
                events.append(SimEvent(t0, t1, RELOAD, w.layer, core.cid, w.wave))
        # compute: MAC path idle + head-of-loaded-FIFO's layer is ready
        if not core.compute_busy and core.loaded_q:
            w, t_loaded = core.loaded_q[0]
            if w.layer in compute_ready:
                core.loaded_q.pop(0)
                core.compute_busy = True
                t0 = max(now, core.t_compute_free, t_loaded)
                t1 = t0 + max(w.compute, w.fm)
                core.t_compute_free = t1
                compute_started.setdefault(w.layer, t0)
                compute_busy[w.layer] = (compute_busy.get(w.layer, 0.0)
                                         + (t1 - t0))
                heapq.heappush(heap, (t1, next(seq), COMPUTE, core.cid, w))
                if keep_events:
                    events.append(SimEvent(t0, t1, COMPUTE, w.layer,
                                           core.cid, w.wave))

    # --- prime the queues -------------------------------------------------
    for n in order:
        if deps_retired(n):
            compute_ready.add(n)
    if pipeline:
        for n in order:  # weights are static: all reloads may prefetch
            release(n, 0.0)
    else:
        for n in order:
            # a zero-wave root may retire inside release() and release its
            # successors via _retire - don't queue those twice
            if n in compute_ready and n not in released:
                release(n, 0.0)
    for c in cores:
        kick(c, 0.0)

    # --- event loop -------------------------------------------------------
    makespan = 0.0
    while heap:
        t, _, kind, cid, w = heapq.heappop(heap)
        makespan = max(makespan, t)
        if kind == RELOAD:
            core = cores[cid]
            core.reload_busy = False
            core.loaded_q.append((w, t))
            kick(core, t)
        elif kind == COMPUTE:
            core = cores[cid]
            core.compute_busy = False
            core.buffers_free += 1  # macro free for the next refill
            pending_compute[w.layer] -= 1
            if pending_compute[w.layer] == 0:
                l = graph.nodes[w.layer].layer
                t_apw = t + hw.ctrl_overhead * l.out_pixels
                heapq.heappush(heap, (t_apw, next(seq), APW, -1, w))
                if keep_events:
                    events.append(SimEvent(t, t_apw, APW, w.layer, -1, 0))
            kick(core, t)
        else:  # APW retire: dependents' activations now exist
            _retire(w.layer, t)
            for c in cores:
                kick(c, t)

    if len(retired) != len(order):
        missing = [n for n in order if n not in retired]
        raise RuntimeError(f"simulation deadlocked; unretired: {missing[:5]}")

    layer_timings = []
    for n in order:
        node = graph.nodes[n]
        comp = compute_busy.get(n, 0.0)
        rel = reload_busy.get(n, 0.0)
        fm = sum(max(w.fm - w.compute, 0.0) for wl in waves[n] for w in wl)
        t0 = reload_started.get(n, 0.0)
        tc = compute_started.get(n, t0)
        te = retired[n]
        span = te - tc
        stall = max(0.0, span * hw.cores - comp
                    - hw.ctrl_overhead * node.layer.out_pixels)
        layer_timings.append(LayerTiming(n, t0, tc, te, comp, rel, fm, stall))

    return SimResult(makespan, hw.cim_freq / max(makespan, 1e-9),
                     layer_timings, events if keep_events else [],
                     hw, w_bits, a_bits,
                     compute_busy_total=sum(compute_busy.values()))


def cross_validate(layers: Sequence[ConvLayer], hw: HardwareConfig = DEFAULT_HW,
                   w_bits: int = 8, a_bits: int = 4,
                   dense: bool = True) -> dict:
    """Simulated vs closed-form cycles on the same layer table."""
    from ..core import perf_model as PM

    res = simulate(graph_from_layers(layers), hw, w_bits, a_bits,
                   dense=dense, pipeline=False)
    perf = PM.evaluate_network(layers, w_bits, a_bits, hw=hw)
    analytic = sum(p.cycles_dense if dense else p.cycles_mars for p in perf)
    return {
        "sim_cycles": res.cycles,
        "analytic_cycles": analytic,
        "ratio": res.cycles / max(analytic, 1e-9),
        "sim_fps": res.fps,
        "analytic_fps": hw.cim_freq / max(analytic, 1e-9),
    }
