"""Macro allocation: surviving group-sets -> the 4 cores x 2 macros fabric.

The paper stores a layer's nonzero group-sets densely in the macros
(Fig. 5b); what it does not spell out is *which* core gets which
kernel-group when survival counts are ragged. This allocator:

  * assigns kernel-groups (columns of alpha kernels) to cores with LPT
    greedy load balancing on surviving group-set counts - a kernel-group
    never splits across cores because its alpha kernels share one set of
    bit-lines / one APW accumulation;
  * tracks macro residency: each core's share is cut into reload *waves*
    of at most one macro's capacity, so the simulator can ping-pong the
    two macros (compute from one while the write port refills the other);
  * reports partition occupancy so utilization is visible per macro.

Conservation is a hard invariant: every surviving group-set is placed in
exactly one (core, wave) slot - ``verify_conservation`` checks it and the
test suite enforces it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from ..core.mapping import GroupsetPacking
from ..core.perf_model import DEFAULT_HW, HardwareConfig

from .graph import LayerNode


@dataclasses.dataclass
class CoreAssignment:
    """One core's share of a layer."""

    core: int
    kernel_groups: List[int]  # output-group columns owned by this core
    nnz: int  # surviving group-sets assigned
    waves: List[int]  # group-sets per reload wave (<= one macro's capacity)

    @property
    def n_waves(self) -> int:
        return len(self.waves)


@dataclasses.dataclass
class LayerAllocation:
    name: str
    nnz_total: int
    capacity_per_macro: int  # group-sets resident in ONE macro buffer
    assignments: List[CoreAssignment]
    group: int
    alpha: int
    w_bits: int

    @property
    def reload_waves(self) -> int:
        return max((a.n_waves for a in self.assignments), default=0)

    @property
    def placed(self) -> int:
        return sum(a.nnz for a in self.assignments)

    @property
    def imbalance(self) -> float:
        """max core load / mean core load (1.0 = perfectly balanced)."""
        loads = [a.nnz for a in self.assignments]
        mean = sum(loads) / max(len(loads), 1)
        return max(loads) / mean if mean > 0 else 1.0

    @property
    def macro_occupancy(self) -> float:
        """Busiest wave's fill fraction of one macro buffer."""
        busiest = max((max(a.waves, default=0) for a in self.assignments),
                      default=0)
        return min(1.0, busiest / max(self.capacity_per_macro, 1))


def allocate_counts(counts: Sequence[int], hw: HardwareConfig = DEFAULT_HW,
                    w_bits: int = 8, group: Optional[int] = None,
                    alpha: Optional[int] = None, name: str = "") -> LayerAllocation:
    """Place per-kernel-group survival ``counts`` onto the macro fabric.

    LPT greedy: kernel-groups sorted by descending count, each assigned to
    the currently least-loaded core. Guarantees max load <= (4/3 - 1/3m) x
    optimum, plenty for the <= 2x count skew real layers show.
    """
    g = hw.group if group is None else group
    a = hw.alpha if alpha is None else alpha
    counts = np.asarray(counts, dtype=np.int64)
    cap = hw.capacity_groupsets(w_bits, g, a, macros=1)
    order = np.argsort(-counts, kind="stable")
    loads = np.zeros(hw.cores, dtype=np.int64)
    owned: List[List[int]] = [[] for _ in range(hw.cores)]
    for j in order:
        if counts[j] == 0:
            continue
        c = int(np.argmin(loads))
        owned[c].append(int(j))
        loads[c] += counts[j]
    assignments = []
    for c in range(hw.cores):
        nnz = int(loads[c])
        waves = [cap] * (nnz // cap)
        if nnz % cap:
            waves.append(nnz % cap)
        assignments.append(CoreAssignment(c, sorted(owned[c]), nnz, waves))
    return LayerAllocation(name, int(counts.sum()), cap, assignments,
                           g, a, w_bits)


def allocate_node(node: LayerNode, hw: HardwareConfig = DEFAULT_HW,
                  w_bits: int = 8, group: Optional[int] = None,
                  alpha: Optional[int] = None,
                  dense: bool = False) -> LayerAllocation:
    g = hw.group if group is None else group
    a = hw.alpha if alpha is None else alpha
    counts = node.kernel_group_counts(g, a, dense=dense)
    return allocate_counts(counts, hw, w_bits, g, a, name=node.name)


def allocate_packing(p: GroupsetPacking, hw: HardwareConfig = DEFAULT_HW,
                     w_bits: int = 8, group: Optional[int] = None,
                     alpha: Optional[int] = None,
                     name: str = "") -> LayerAllocation:
    """Allocate directly from a ``pack_groupsets`` artifact (the paper
    path): survival counts come from the packed index codes."""
    go = int(p.channel_pos.max(initial=-1)) + 1
    counts = np.bincount(p.channel_pos, minlength=max(go, 1))
    return allocate_counts(counts, hw, w_bits, group, alpha, name=name)


def device_assignment(counts: Sequence[int], n_devices: int) -> np.ndarray:
    """Kernel-group columns -> serving devices: the LPT policy of
    ``allocate_counts``, constrained to equal cardinality per device.

    The TPU serving mesh plays the role of the macro cluster, but unlike
    the paper's cores a ``shard_map`` shard must hold the SAME number of
    block columns on every device (equal-shaped shards). So: columns sorted
    by descending surviving-block count, each placed on the least-loaded
    device that still has column slots free. Requires
    ``len(counts) % n_devices == 0``; returns the (n_columns,) device id
    per column. Every column is placed exactly once, every device owns
    exactly ``n_columns / n_devices`` columns, and the nnz imbalance is
    never worse than the contiguous split's.
    """
    counts = np.asarray(counts, dtype=np.int64)
    go = counts.shape[0]
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if go % n_devices:
        raise ValueError(
            f"{go} kernel-group columns do not split evenly over "
            f"{n_devices} devices")
    slots = go // n_devices
    loads = np.zeros(n_devices, dtype=np.int64)
    owned = np.zeros(n_devices, dtype=np.int64)
    dev = np.zeros(go, dtype=np.int32)
    for j in np.argsort(-counts, kind="stable"):
        open_devs = np.flatnonzero(owned < slots)
        d = open_devs[np.argmin(loads[open_devs])]
        dev[j] = d
        loads[d] += counts[j]
        owned[d] += 1
    return dev


def verify_conservation(alloc: LayerAllocation) -> bool:
    """Every surviving group-set placed exactly once; waves cover loads."""
    if alloc.placed != alloc.nnz_total:
        return False
    all_kgs: List[int] = []
    for a in alloc.assignments:
        if sum(a.waves) != a.nnz:
            return False
        if any(w <= 0 or w > alloc.capacity_per_macro for w in a.waves):
            return False
        all_kgs.extend(a.kernel_groups)
    return len(all_kgs) == len(set(all_kgs))
