"""Measured-latency tile autotuner: close the search loop on the stopwatch.

``search_mapping`` ranks (group, alpha) tiles by SIMULATED cycles on the
modeled MARS fabric - but the tile is also the Pallas BSR kernel's block
shape, and what a block shape costs on the backend actually serving the
model (CPU interpret in CI, TPU in deployment) is not what it costs on the
modeled 28 nm fabric. CIM-Tuner's answer, reproduced here: keep the
analytic search as the PROPOSER, then time the top-N proposals through the
real ``bsr_matmul_stacked`` kernels - prefill and decode row counts, fenced
with :class:`~repro.kernels.timing.DispatchTimer` - and let measured wall
clock pick the winner. The simulated pick is always in the shortlist, so
the measured winner is never slower than it on the timed workload.

Measurements are expensive (each candidate packs + dispatches every
distinct projection shape), so results persist as an :class:`AutotuneCache`
keyed by (arch, projection shapes, backend) inside the PR 4 serving
artifact's manifest - a booted artifact reuses the measurement instead of
re-timing, and a backend change (cache taken on TPU, booted on CPU) misses
the key and falls back to the simulated tile rather than trusting a stale
clock. The per-sample (phase cycles, measured seconds) pairs feed
``perf_model.fit_cycle_constants`` so the simulator's constants track the
machine (``refit_from_table``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import perf_model as PM
from ..core.deploy import bm_for_rows
from ..core.perf_model import DEFAULT_HW, HardwareConfig
from ..core.sparsity import prune_mask_2d
from ..kernels import ops
from ..kernels.timing import DispatchTimer
from .graph import lm_graph
from .search import SearchResult, search_mapping

CACHE_SCHEMA = 1


# ---------------------------------------------------------------------------
# Workload signature: the shapes a serving config actually dispatches
# ---------------------------------------------------------------------------


def projection_shapes(cfg) -> List[Tuple[int, int, int]]:
    """Distinct CIM projection shapes of ``cfg`` as (d_in, d_out, count),
    sorted - the workload signature the autotuner times and keys on.
    Counts aggregate identical shapes across blocks (timing one and
    weighting by count, instead of re-timing the same matmul L times)."""
    counts: Dict[Tuple[int, int], int] = {}
    for node in lm_graph(cfg, seq_len=1).nodes.values():
        l = node.layer
        key = (l.kh * l.kw * l.cin, l.cout)
        counts[key] = counts.get(key, 0) + 1
    return sorted((d_in, d_out, n) for (d_in, d_out), n in counts.items())


def autotune_key(cfg, backend: Optional[str] = None) -> str:
    """Cache key: arch | backend | shape signature. The backend is part of
    the key on purpose - a wall-clock ranking taken on one backend says
    nothing about another, so a mismatch must read as a MISS."""
    import jax

    backend = backend or jax.default_backend()
    shapes = ";".join(f"{i}x{o}x{n}" for i, o, n in projection_shapes(cfg))
    return f"{cfg.name}|{backend}|{shapes}"


# ---------------------------------------------------------------------------
# Measurement: one tile through the real stacked kernel, fenced
# ---------------------------------------------------------------------------


def _stack_packs(packs: List[dict]) -> Tuple:
    """Stack per-layer ``pack_for_kernel`` dicts into the uniform-envelope
    arrays ``bsr_matmul_stacked`` takes, padding to the widest nnz_max
    (padding blocks are zero -> mathematically inert)."""
    nnz_max = max(int(p["row_idx"].shape[1]) for p in packs)

    def pad(a, width):
        a = np.asarray(a)
        if a.shape[1] == width:
            return a
        pads = [(0, 0)] * a.ndim
        pads[1] = (0, width - a.shape[1])
        return np.pad(a, pads)

    import jax.numpy as jnp

    blocks = jnp.asarray(np.stack([pad(p["blocks"], nnz_max) for p in packs]))
    scales = jnp.asarray(np.stack([pad(p["scales"], nnz_max) for p in packs]))
    row_idx = jnp.asarray(np.stack([pad(p["row_idx"], nnz_max) for p in packs]))
    nnz = jnp.asarray(np.stack([np.asarray(p["nnz"]) for p in packs]))
    return blocks, scales, row_idx, nnz


def measure_tile(shapes: Sequence[Tuple[int, int, int]],
                 tile: Tuple[int, int], sparsity: float,
                 w_bits: int = 8, a_bits: int = 8,
                 prefill_rows: int = 32, decode_rows: int = 4,
                 repeats: int = 2, stack_layers: int = 2,
                 timer: Optional[DispatchTimer] = None,
                 hw: HardwareConfig = DEFAULT_HW) -> dict:
    """Fenced wall clock of ONE candidate tile over a workload signature.

    For every (d_in, d_out, count) shape, packs ``stack_layers`` synthetic
    pruned weights into a uniform envelope and dispatches the real
    ``bsr_matmul_stacked`` kernel at prefill and decode row counts; the
    first dispatch per shape is compile/trace and is excluded. Returns a
    JSON-ready row: count-weighted prefill/decode/total seconds plus the
    per-sample (phase cycles, measured seconds) pairs the cost-constant
    re-fit consumes."""
    import jax
    import jax.numpy as jnp

    bk, bn = int(tile[0]), int(tile[1])
    timer = timer if timer is not None else DispatchTimer(enabled=True)
    hw_t = dataclasses.replace(hw, group=bk, alpha=bn)
    rng = np.random.default_rng(0)
    layer0 = jnp.asarray(0, jnp.int32)
    prefill_s = decode_s = 0.0
    samples: List[dict] = []
    for d_in, d_out, count in shapes:
        packs = []
        for _ in range(max(stack_layers, 1)):
            w = rng.standard_normal((d_in, d_out)).astype(np.float32) * 0.05
            if sparsity > 0:
                w = w * np.asarray(prune_mask_2d(jnp.asarray(w), bk, bn,
                                                 sparsity))
            packs.append(ops.pack_for_kernel(w, bits=w_bits, bk=bk, bn=bn))
        stacked = _stack_packs(packs)
        for phase, rows in (("prefill", prefill_rows), ("decode", decode_rows)):
            x = jnp.asarray(
                rng.standard_normal((rows, d_in)).astype(np.float32))
            bm = bm_for_rows(rows)
            args = (x, *stacked, layer0)
            # warm call outside the timer: trace + compile, not dispatch
            jax.block_until_ready(ops.bsr_matmul_stacked(*args, bm=bm))
            best = None
            for _ in range(max(repeats, 1)):
                n_before = len(timer.records)
                timer.timed(f"autotune.{phase}", (rows, d_in, d_out),
                            (bk, bn), ops.bsr_matmul_stacked, *args, bm=bm)
                s = timer.records[n_before].seconds
                best = s if best is None else min(best, s)
            best = max(best, 1e-9)
            if phase == "prefill":
                prefill_s += best * count
            else:
                decode_s += best * count
            layer = PM.ConvLayer(1, 1, d_in, d_out, 1, rows, sparsity)
            samples.append({
                "shape": [rows, d_in, d_out],
                "phases": PM.layer_phase_cycles(layer, w_bits, a_bits,
                                                hw=hw_t),
                "measured_s": best,
            })
    return {
        "tile": [bk, bn],
        "backend": jax.default_backend(),
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "total_s": prefill_s + decode_s,
        "samples": samples,
    }


def refit_from_table(table: Sequence[dict],
                     hw: HardwareConfig = DEFAULT_HW) -> PM.RefitResult:
    """Cost-constant re-fit over every (phases, measured_s) sample a
    ``measure_tile`` table collected."""
    samples = [(s["phases"], s["measured_s"])
               for row in table for s in row.get("samples", ())]
    return PM.fit_cycle_constants(samples, hw=hw)


# ---------------------------------------------------------------------------
# Cache: measurements persist inside the serving artifact manifest
# ---------------------------------------------------------------------------


class AutotuneCache:
    """Per-(arch, shapes, backend) measured-tile store, JSON round-trippable
    through the serving artifact's ``extra`` manifest slot."""

    def __init__(self, entries: Optional[Dict[str, dict]] = None):
        self.entries: Dict[str, dict] = dict(entries or {})

    def get(self, key: str) -> Optional[dict]:
        return self.entries.get(key)

    def put(self, key: str, result: "AutotuneResult") -> None:
        self.entries[key] = {
            "backend": result.backend,
            "best_tile": list(result.best_tile),
            "simulated_tile": list(result.simulated_tile),
            "table": [{k: v for k, v in row.items() if k != "samples"}
                      for row in result.table],
        }

    def to_json(self) -> dict:
        return {"schema": CACHE_SCHEMA, "entries": self.entries}

    @classmethod
    def from_json(cls, obj) -> "AutotuneCache":
        if not isinstance(obj, dict) or "entries" not in obj:
            raise ValueError(f"autotune cache: malformed payload {type(obj)}")
        if obj.get("schema") != CACHE_SCHEMA:
            raise ValueError(
                f"autotune cache: schema {obj.get('schema')!r} != {CACHE_SCHEMA}")
        entries = obj["entries"]
        if not isinstance(entries, dict):
            raise ValueError("autotune cache: entries is not a mapping")
        for key, e in entries.items():
            tile = e.get("best_tile") if isinstance(e, dict) else None
            if (not isinstance(tile, (list, tuple)) or len(tile) != 2
                    or not all(isinstance(t, int) and t > 0 for t in tile)):
                raise ValueError(f"autotune cache: entry {key!r} has bad "
                                 f"best_tile {tile!r}")
        return cls(entries)


# ---------------------------------------------------------------------------
# The autotuner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AutotuneResult:
    """Outcome of one autotune pass. ``best_tile`` is what to pack with;
    ``table`` holds the measured rows (empty on a cache hit or when
    measurement was disabled and the simulated tile won by default)."""

    best_tile: Tuple[int, int]
    simulated_tile: Tuple[int, int]
    table: List[dict]
    cache_hit: bool
    key: str
    backend: str

    def to_json(self) -> dict:
        return {
            "best_tile": list(self.best_tile),
            "simulated_tile": list(self.simulated_tile),
            "cache_hit": self.cache_hit,
            "backend": self.backend,
            "table": [{k: v for k, v in row.items() if k != "samples"}
                      for row in self.table],
        }


def autotune(cfg, top_n: int = 3, *, target_sparsity: float = 0.6,
             groups: Sequence[int] = (8, 16, 32),
             alphas: Sequence[int] = (8, 16, 32),
             seq_len: int = 128, prefill_rows: int = 32,
             decode_rows: int = 4, repeats: int = 2, stack_layers: int = 2,
             hw: HardwareConfig = DEFAULT_HW,
             cache: Optional[AutotuneCache] = None,
             timer: Optional[DispatchTimer] = None,
             allow_measure: bool = True,
             search: Optional[SearchResult] = None) -> AutotuneResult:
    """Pick the serving tile by measured wall clock.

    Runs the uniform-envelope mapping search (unless a ``search`` result is
    passed in), shortlists its top-``top_n`` tiles by simulated FPS, times
    each through the real stacked BSR kernels and returns the measured
    winner. A populated ``cache`` short-circuits the measurement entirely
    (cache HIT); with ``allow_measure=False`` a MISS falls back to the
    simulated tile instead of timing (the offline / wrong-backend path)."""
    import jax

    backend = jax.default_backend()
    key = autotune_key(cfg, backend)
    if search is None:
        graph = lm_graph(cfg, seq_len=seq_len, sparsity_gs=target_sparsity)
        search = search_mapping(graph, hw, cfg.w_bits, cfg.a_bits,
                                groups=groups, alphas=alphas, uniform=True)
    sim_tile = search.best.candidate.tile
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            return AutotuneResult(tuple(hit["best_tile"]), sim_tile,
                                  [], True, key, backend)
    if not allow_measure:
        return AutotuneResult(sim_tile, sim_tile, [], False, key, backend)

    shapes = projection_shapes(cfg)
    ranked = sorted(search.table, key=lambda r: r.fps, reverse=True)
    seen: set = set()
    shortlist = []
    for r in ranked:
        if r.candidate.tile not in seen:
            seen.add(r.candidate.tile)
            shortlist.append(r)
        if len(shortlist) >= max(top_n, 1):
            break
    table = []
    for r in shortlist:
        row = measure_tile(shapes, r.candidate.tile, target_sparsity,
                           w_bits=cfg.w_bits, a_bits=cfg.a_bits,
                           prefill_rows=prefill_rows,
                           decode_rows=decode_rows, repeats=repeats,
                           stack_layers=stack_layers, timer=timer, hw=hw)
        row["sim_fps"] = round(r.fps, 2)
        row["sim_cycles"] = round(r.cycles, 1)
        table.append(row)
    best = min(table, key=lambda row: row["total_s"])
    result = AutotuneResult(tuple(best["tile"]), sim_tile, table, False,
                            key, backend)
    if cache is not None:
        cache.put(key, result)
    return result
