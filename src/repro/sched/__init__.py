"""repro.sched - multi-macro scheduling & event-driven CIM simulation.

The pipeline: extract a layer DAG from a model config (``graph``), place
surviving group-sets onto the 4 cores x 2 macros fabric (``allocate``),
simulate the schedule event-by-event (``simulate``), search the mapping
space for a faster tiling (``search``), and execute the winner on the real
Pallas BSR path with the same artifact (``executor``).
"""
from .allocate import (CoreAssignment, LayerAllocation, allocate_counts,
                       allocate_node, allocate_packing, device_assignment,
                       verify_conservation)
# NOTE: the bare function is intentionally NOT imported here - binding the
# name ``autotune`` in the package would shadow the submodule attribute
from .autotune import (AutotuneCache, AutotuneResult, autotune_key,
                       measure_tile, projection_shapes, refit_from_table)
from .graph import (LayerGraph, LayerNode, attach_weights, graph_from_layers,
                    lm_graph, resnet18_graph, vgg16_graph)
from .executor import (LayerSchedule, NetworkSchedule, build_schedule,
                       deploy_layer, execute_layer, execute_network,
                       schedule_from_search, verify_layer)
from .pricing import Pricer, RequestPrice, StepPrice
from .search import (CandidateResult, MappingCandidate, SearchResult,
                     SpecCalibration, SpecSearchResult,
                     default_candidate, greedy_search, search_mapping,
                     search_spec)
from .simulate import SimEvent, SimResult, cross_validate, simulate

__all__ = [
    "CoreAssignment", "LayerAllocation", "allocate_counts", "allocate_node",
    "allocate_packing", "device_assignment", "verify_conservation",
    "AutotuneCache", "AutotuneResult", "autotune_key",
    "measure_tile", "projection_shapes", "refit_from_table",
    "LayerGraph", "LayerNode", "attach_weights", "graph_from_layers",
    "lm_graph", "resnet18_graph", "vgg16_graph",
    "LayerSchedule", "NetworkSchedule", "build_schedule", "deploy_layer",
    "execute_layer", "execute_network", "schedule_from_search", "verify_layer",
    "Pricer", "RequestPrice", "StepPrice",
    "CandidateResult", "MappingCandidate", "SearchResult",
    "SpecCalibration", "SpecSearchResult", "default_candidate",
    "greedy_search", "search_mapping", "search_spec",
    "SimEvent", "SimResult", "cross_validate", "simulate",
]
