"""Request pricing: the event-driven simulator as an admission oracle.

CIMinus's thesis is that a calibrated cost model can price sparse workloads
on a CIM fabric BEFORE running them; the serving analogue is admission
control. This module turns the PR 1 event-driven simulator (plus the PR 7
re-fit cycle constants) into a per-request price: predicted prefill seconds
and per-decode-token seconds at a tenant's (arch, sparsity), so a gateway
can decide admit / defer / shed without ever dispatching a kernel.

Two honesty points:

  * the simulator prices CIM cycles on the MODELED fabric. Raw
    ``cycles / hw.cim_freq`` seconds are therefore fabric-seconds, not
    host-seconds - fine for RELATIVE decisions (which request is heavier,
    which tenant's backlog is longer). Passing ``refit`` (a
    ``core.perf_model.RefitResult`` or its ``seconds_per_cycle`` dict,
    i.e. the PR 7 measured-constants fit) converts phase cycles with the
    MEASURED per-phase constants instead, so prices live on the same
    clock as the SLOs they gate.
  * simulation is not free. Prices are memoized per
    ``(arch, seq-bucket, sparsity, n_devices)`` with sequence lengths
    bucketed to the next power of two - admission control needs a stable
    order of magnitude per shape class, not a fresh DAG simulation per
    request.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from ..core import perf_model as PM
from .graph import lm_graph
from .simulate import simulate


def _seq_bucket(n: int) -> int:
    """Next power of two >= n (min 1): the pricing cache granularity."""
    b = 1
    while b < max(1, int(n)):
        b <<= 1
    return b


@dataclasses.dataclass(frozen=True)
class StepPrice:
    """Simulated cost of one forward pass at ``seq_len`` rows."""

    seconds: float
    cycles: float
    phases: Dict[str, float]  # compute/reload/fm/stall cycle totals
    seq_bucket: int


@dataclasses.dataclass(frozen=True)
class RequestPrice:
    """Predicted serving cost of one request on the modeled fabric.

    ``prefill_s`` covers the whole prompt in one pass (the bucketed
    sequence length); ``per_token_s`` is one decode step; ``total_s`` is
    the request end to end (prefill + max_new decode steps) - the number
    admission backlogs sum over."""

    prefill_s: float
    per_token_s: float
    max_new_tokens: int

    @property
    def decode_s(self) -> float:
        return self.per_token_s * self.max_new_tokens

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    def to_json(self) -> dict:
        return {"prefill_ms": round(self.prefill_s * 1e3, 4),
                "per_token_ms": round(self.per_token_s * 1e3, 4),
                "total_ms": round(self.total_s * 1e3, 4)}


def _refit_coeffs(refit) -> Optional[Dict[str, float]]:
    """Normalize ``refit`` into a seconds-per-cycle dict (or None).

    Accepts a :class:`~repro.core.perf_model.RefitResult`, its
    ``seconds_per_cycle`` mapping, or a BENCH_sched ``post_refit`` entry
    (which nests the mapping under ``seconds_per_cycle``)."""
    if refit is None:
        return None
    if hasattr(refit, "seconds_per_cycle"):
        refit = refit.seconds_per_cycle
    if isinstance(refit, dict) and "seconds_per_cycle" in refit:
        refit = refit["seconds_per_cycle"]
    if not isinstance(refit, dict):
        raise TypeError(f"pricing: refit must be a RefitResult or a "
                        f"seconds_per_cycle mapping, got {type(refit)}")
    coeffs = {k: float(refit.get(k, 0.0)) for k in PM.REFIT_COEFFS}
    if not any(v > 0 for v in coeffs.values()):
        raise ValueError(f"pricing: refit constants all zero: {refit}")
    return coeffs


class Pricer:
    """Memoizing price oracle over the event-driven simulator.

    One Pricer serves every tenant: the cache key carries the arch name,
    so tenants with different models (or the same model at different
    sparsity) price independently."""

    def __init__(self, hw: Optional[PM.HardwareConfig] = None, refit=None):
        self.hw = hw or PM.DEFAULT_HW
        self._refit = _refit_coeffs(refit)
        self._cache: Dict[Tuple, StepPrice] = {}

    @property
    def calibrated(self) -> bool:
        """True when prices run on measured (re-fit) constants."""
        return self._refit is not None

    def _seconds(self, cycles: float, phases: Dict[str, float]) -> float:
        if self._refit is None:
            return cycles / self.hw.cim_freq
        feats = PM.phase_features(phases)
        return sum(c * t for c, t in
                   zip(feats, (self._refit[k] for k in PM.REFIT_COEFFS)))

    def step_price(self, cfg, seq_len: int, sparsity_gs: float,
                   n_devices: int = 1) -> StepPrice:
        """Simulated cost of ONE forward pass of ``cfg``'s CIM projection
        graph at ``seq_len`` rows (bucketed up to a power of two)."""
        bucket = _seq_bucket(seq_len)
        key = (cfg.name, bucket, round(float(sparsity_gs), 4), n_devices)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        graph = lm_graph(cfg, seq_len=bucket, sparsity_gs=sparsity_gs)
        sim = simulate(graph, hw=self.hw, w_bits=cfg.w_bits,
                       a_bits=cfg.a_bits, keep_events=False)
        phases = {
            "compute": sum(l.compute_cycles for l in sim.layers),
            "reload": sum(l.reload_cycles for l in sim.layers),
            "fm": sum(l.fm_cycles for l in sim.layers),
            "stall": sum(l.stall_cycles for l in sim.layers),
        }
        cycles = float(sim.cycles)
        if n_devices > 1:
            collective = sum(
                self.hw.allgather_cycles(l.out_h * l.out_w * l.cout * 4,
                                         n_devices)
                for l in graph.layers())
            phases["collective"] = collective
            cycles += collective
        price = StepPrice(seconds=self._seconds(cycles, phases),
                          cycles=cycles, phases=phases, seq_bucket=bucket)
        self._cache[key] = price
        return price

    def price_request(self, cfg, prompt_len: int, max_new_tokens: int,
                      sparsity_gs: float, n_devices: int = 1) -> RequestPrice:
        """Price one request: a bucketed full-prompt prefill pass plus
        ``max_new_tokens`` one-token decode steps."""
        prefill = self.step_price(cfg, prompt_len, sparsity_gs,
                                  n_devices=n_devices)
        decode = self.step_price(cfg, 1, sparsity_gs, n_devices=n_devices)
        return RequestPrice(prefill_s=prefill.seconds,
                            per_token_s=decode.seconds,
                            max_new_tokens=int(max_new_tokens))
