"""Schedule execution: one artifact format for the simulator and the TPU.

``NetworkSchedule`` is the contract: per layer it records the tiling the
search chose, the allocator's placement, and the simulated timing - and
the *same* (group, alpha) tile becomes the (bk, bn) block shape that
``core.deploy`` packs and the Pallas BSR kernel consumes. A schedule that
simulated fast is therefore directly runnable: ``execute_layer`` feeds a
real weight through ``deploy_weight -> deployed_matmul`` with the
schedule's tiling, and ``verify_layer`` asserts the result matches the
dense quantized oracle bit-for-bit in float tolerance - scheduling must
never change numerics, only time.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core import deploy as D
from ..core.cim_layer import CIMConfig
from ..core.perf_model import DEFAULT_HW, HardwareConfig

from . import allocate as A
from .graph import LayerGraph
from .search import MappingCandidate, SearchResult
from .simulate import SimResult, simulate


@dataclasses.dataclass
class LayerSchedule:
    name: str
    group: int
    alpha: int
    nnz: int
    total_groupsets: int
    reload_waves: int
    imbalance: float
    core_loads: List[int]
    t_start: float = 0.0
    t_end: float = 0.0

    @property
    def tile(self) -> tuple:
        return (self.group, self.alpha)


@dataclasses.dataclass
class NetworkSchedule:
    hw: HardwareConfig
    w_bits: int
    a_bits: int
    candidate: MappingCandidate
    layers: List[LayerSchedule]
    cycles: float
    fps: float
    # set by the measured-latency autotuner (``sched.autotune``) when wall
    # clock overrode (or confirmed) the simulated pick; None = never timed
    measured_tile: Optional[tuple] = None

    @property
    def uniform_tile(self) -> tuple:
        """The single (group, alpha) every layer was scheduled with - the
        uniform-envelope contract ``stack_deployed`` builds on. Raises if
        the schedule is heterogeneous (a future per-layer search would
        need per-layer stacks)."""
        tiles = {(s.group, s.alpha) for s in self.layers}
        if len(tiles) > 1:
            raise ValueError(
                f"schedule is not uniform-tile: {sorted(tiles)} - re-search "
                "with search_mapping(uniform=True)")
        return tiles.pop() if tiles else self.candidate.tile

    def to_json(self) -> dict:
        return {
            "group": self.candidate.group,
            "alpha": self.candidate.alpha,
            "pipeline": self.candidate.pipeline,
            "w_bits": self.w_bits,
            "a_bits": self.a_bits,
            "cycles": round(self.cycles, 1),
            "fps": round(self.fps, 2),
            "measured_tile": (list(self.measured_tile)
                              if self.measured_tile is not None else None),
            "layers": [
                {
                    "name": s.name,
                    "tile": list(s.tile),
                    "nnz": s.nnz,
                    "total_groupsets": s.total_groupsets,
                    "reload_waves": s.reload_waves,
                    "imbalance": round(s.imbalance, 3),
                    "core_loads": s.core_loads,
                    "t_start": round(s.t_start, 1),
                    "t_end": round(s.t_end, 1),
                }
                for s in self.layers
            ],
        }


def build_schedule(graph: LayerGraph, candidate: MappingCandidate,
                   hw: HardwareConfig = DEFAULT_HW, w_bits: int = 8,
                   a_bits: int = 4,
                   sim: Optional[SimResult] = None) -> NetworkSchedule:
    """Materialize the artifact for a chosen mapping: allocator placement
    per layer + simulated timeline."""
    if sim is None:
        sim = simulate(graph, hw, w_bits, a_bits, pipeline=candidate.pipeline,
                       group=candidate.group, alpha=candidate.alpha)
    timing = {t.name: t for t in sim.layers}
    layers = []
    for name in graph.topo_order():
        node = graph.nodes[name]
        alloc = A.allocate_node(node, hw, w_bits, candidate.group,
                                candidate.alpha)
        t = timing[name]
        layers.append(LayerSchedule(
            name=name,
            group=candidate.group,
            alpha=candidate.alpha,
            nnz=alloc.nnz_total,
            total_groupsets=node.layer.groupsets_for(candidate.group,
                                                     candidate.alpha),
            reload_waves=alloc.reload_waves,
            imbalance=alloc.imbalance,
            core_loads=[asg.nnz for asg in alloc.assignments],
            t_start=t.t_start,
            t_end=t.t_end,
        ))
    return NetworkSchedule(hw, w_bits, a_bits, candidate, layers,
                           sim.cycles, sim.fps)


def schedule_from_search(graph: LayerGraph, result: SearchResult,
                         hw: HardwareConfig = DEFAULT_HW, w_bits: int = 8,
                         a_bits: int = 4) -> NetworkSchedule:
    return build_schedule(graph, result.best.candidate, hw, w_bits, a_bits)


# ---------------------------------------------------------------------------
# TPU execution path: the schedule's tile IS the kernel's block shape
# ---------------------------------------------------------------------------


def _deploy_tile(sched: LayerSchedule, d_in: int, d_out: int) -> tuple:
    """(bk, bn) for the kernel: the schedule tile, clipped to a divisor
    of the weight shape (pack_bsr requires exact tiling)."""
    return D.fit_tile(d_in, d_out, sched.group, sched.alpha)


def deploy_layer(w, sched: LayerSchedule, cim: CIMConfig,
                 target_sparsity: Optional[float] = None) -> D.DeployedWeight:
    """Pack one real weight for serving with the schedule's tiling."""
    d_in, d_out = int(w.shape[-2]), int(w.shape[-1])
    bk, bn = _deploy_tile(sched, d_in, d_out)
    return D.deploy_weight(w, cim, bk=bk, bn=bn,
                           target_sparsity=target_sparsity)


def execute_layer(x, w, sched: LayerSchedule, cim: CIMConfig,
                  target_sparsity: Optional[float] = None,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Run one scheduled layer on the real kernel path."""
    dw = deploy_layer(w, sched, cim, target_sparsity)
    return D.deployed_matmul(x, dw, a_bits=cim.quant.a_bits,
                             interpret=interpret)


def verify_layer(x, w, sched: LayerSchedule, cim: CIMConfig,
                 target_sparsity: Optional[float] = None,
                 atol: float = 1e-4) -> float:
    """Scheduled-kernel output vs the dense quantized oracle; returns the
    max abs error (raises if above tolerance)."""
    d_in, d_out = int(w.shape[-2]), int(w.shape[-1])
    bk, bn = _deploy_tile(sched, d_in, d_out)
    got = execute_layer(x, w, sched, cim, target_sparsity, interpret=True)
    want = D.reference_matmul(x, w, cim, target_sparsity=target_sparsity,
                              bk=bk, bn=bn)
    err = float(jnp.max(jnp.abs(got - want)))
    if err > atol:
        raise AssertionError(
            f"{sched.name}: scheduled execution diverged (max err {err})")
    return err


def execute_network(xs: Dict[str, jnp.ndarray], ws: Dict[str, jnp.ndarray],
                    schedule: NetworkSchedule, cim: CIMConfig,
                    interpret: Optional[bool] = None) -> Dict[str, jnp.ndarray]:
    """Execute every scheduled layer that has a weight + input provided."""
    by_name = {s.name: s for s in schedule.layers}
    out = {}
    for name, w in ws.items():
        if name not in by_name or name not in xs:
            continue
        out[name] = execute_layer(xs[name], w, by_name[name], cim,
                                  interpret=interpret)
    return out
