"""Radix-tree prefix KV reuse over the paged block pool.

Production traffic is dominated by shared prefixes - system prompts,
few-shot headers, multi-turn history - and the ``PagedKVCache`` block-table
indirection already supports aliasing: the same physical block can appear
in several slot tables. :class:`PrefixTrie` exploits that. It maps
block_size-sized chunks of prompt token ids to the physical block holding
that chunk's K/V, so an admission whose prompt shares a prefix with an
earlier request ADOPTS the matched block chain (refcount bump, zero copy)
and prefills only the unshared suffix. Cache-hit TTFT approaches one
decode step.

Design points:

  * matching granularity is ``block_size`` tokens - only FULL blocks are
    ever shared, and a match is capped so at least one suffix token
    remains (the forward pass that produces the first output token needs
    at least one input position).
  * the trie holds its OWN reference on every registered block, so shared
    KV survives ``free_slot`` of the request that produced it. Writes
    never mutate shared blocks: every pool write path is copy-on-write
    (see ``batching.PagedKVCache._ensure_owned``).
  * eviction is LRU over leaves, restricted to blocks the trie is the
    LAST holder of (refcount 1) - dropping those actually frees pool
    blocks, which is the only reason admission control ever asks.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


class _Node:
    __slots__ = ("key", "parent", "children", "block", "last_used")

    def __init__(self, key: Optional[Tuple[int, ...]],
                 parent: Optional["_Node"], block: int):
        self.key = key
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.block = block  # physical block id (-1 for the root)
        self.last_used = 0


class PrefixTrie:
    """Maps prompt-token prefixes (in block_size chunks) to live KV blocks."""

    def __init__(self, kv) -> None:
        self.kv = kv
        self.block_size = kv.block_size
        self._root = _Node(None, None, -1)
        self._clock = 0
        # stats
        self.n_lookups = 0
        self.n_hits = 0
        self.n_hit_blocks = 0
        self.n_inserted = 0
        self.n_evicted = 0

    # -- helpers ------------------------------------------------------------

    def _chunks(self, prompt: np.ndarray, n: int) -> List[Tuple[int, ...]]:
        bs = self.block_size
        toks = np.asarray(prompt).reshape(-1)
        return [tuple(int(x) for x in toks[j * bs:(j + 1) * bs])
                for j in range(n)]

    def held_blocks(self) -> int:
        n = 0
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            n += 1
            stack.extend(node.children.values())
        return n

    # -- lookup / registration ----------------------------------------------

    def match(self, prompt: np.ndarray) -> List[int]:
        """Longest registered full-block prefix of ``prompt`` -> physical
        block chain. Capped so >= 1 suffix token stays unmatched. Bumps
        LRU clocks along the matched path."""
        self.n_lookups += 1
        self._clock += 1
        bs = self.block_size
        n_max = (len(np.asarray(prompt).reshape(-1)) - 1) // bs
        blocks: List[int] = []
        node = self._root
        for key in self._chunks(prompt, n_max):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._clock
            blocks.append(child.block)
            node = child
        if blocks:
            self.n_hits += 1
            self.n_hit_blocks += len(blocks)
        return blocks

    def insert(self, prompt: np.ndarray, blocks: List[int]) -> None:
        """Register ``blocks`` (physical ids holding the K/V of the first
        ``len(blocks)`` full blocks of ``prompt``). The trie retains every
        NEWLY registered block; chunks already present keep their existing
        block (first writer wins - both hold identical K/V by
        construction). Call AFTER the KV writes land, so fresh blocks are
        never copy-on-write'd away from their own prefill."""
        self._clock += 1
        node = self._root
        for key, b in zip(self._chunks(prompt, len(blocks)), blocks):
            child = node.children.get(key)
            if child is None:
                self.kv.retain(b)
                child = _Node(key, node, b)
                node.children[key] = child
                self.n_inserted += 1
            child.last_used = self._clock
            node = child

    # -- eviction ------------------------------------------------------------

    def _leaves(self) -> List[_Node]:
        out: List[_Node] = []
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            else:
                out.append(node)
        return out

    def _drop(self, node: _Node) -> None:
        del node.parent.children[node.key]
        self.kv.release(node.block)
        self.n_evicted += 1

    def evict(self, n_blocks: int) -> int:
        """Drop least-recently-used leaves until ``n_blocks`` pool blocks
        were actually freed (only blocks whose LAST reference is the trie
        free anything) or no evictable leaf remains. Returns blocks freed."""
        freed = 0
        while freed < n_blocks:
            evictable = [nd for nd in self._leaves()
                         if self.kv.refcnt[nd.block] == 1]
            if not evictable:
                break
            victim = min(evictable, key=lambda nd: nd.last_used)
            self._drop(victim)
            freed += 1
        return freed

    # -- reporting ------------------------------------------------------------

    def stats(self) -> dict:
        return {
            "lookups": self.n_lookups,
            "hits": self.n_hits,
            "hit_rate": self.n_hits / max(1, self.n_lookups),
            "hit_blocks": self.n_hit_blocks,
            "hit_tokens": self.n_hit_blocks * self.block_size,
            "inserted_blocks": self.n_inserted,
            "evicted_blocks": self.n_evicted,
            "held_blocks": self.held_blocks(),
        }
