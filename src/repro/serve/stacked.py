"""Compiled serving runtime: one jitted ``lax.scan`` over a layer stack.

The loop runtime (``serve.deployed``) executes a python loop over per-layer
packed weights, so every decode step traces L separate BSR-kernel dispatches
and restacks the whole KV cache - exactly the per-macro-dispatch overhead
the MARS multi-macro fabric exists to amortize. This module is the
compiled form:

  * :func:`stack` folds a :class:`~repro.serve.deployed.ServingParams` into
    a :class:`StackedParams`: dense per-layer leaves are stacked along a
    leading layer axis, and every compressed projection becomes a
    :class:`~repro.core.deploy.StackedWeight` uniform envelope
    (``stack_deployed``: slot axis padded to the per-projection max,
    per-layer ``nnz``/``row_idx`` exact).
  * prefill / decode then run ONE ``lax.scan`` over the layer index: the
    scan body builds a per-layer view where each compressed projection is a
    :class:`~repro.core.deploy.StackedLayerView` dispatching to the
    layer-indexed kernel - a single compiled decode step, no per-layer
    kernel launches, KV written via ``dynamic_update_slice`` into donated
    cache buffers (the scan's ys replace the loop runtime's per-step
    ``jnp.stack(ks)``).

Honesty contract: for the same ServingParams this runtime produces BIT-
IDENTICAL greedy tokens to the loop runtime - dense or compressed, single
device or macro-sharded. ``tests/test_stacked.py`` enforces it.

Timing a scan step is only meaningful at the dispatch boundary (the whole
layer loop is ONE compiled call, so per-layer wall clocks don't exist):
``BatchServer`` wraps the decode dispatch in
``repro.kernels.timing.DispatchTimer`` - fenced with ``block_until_ready``,
labeled ``decode.scan`` per (view shape, tile, backend) - when
observability (``repro.obs``) is enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..core import deploy as D
from ..models import registry, transformer
from ..models import layers as L
from ..models.config import ModelConfig
from . import deployed as DP


@dataclasses.dataclass
class StackedParams:
    """Layer-stacked serving weights (pytree).

    ``dense`` holds the stacked (L, ...) per-layer leaves that stay on the
    float path (norm gains, MoE routers/expert stacks, any un-packed
    projection); ``packed`` maps projection name -> StackedWeight uniform
    envelope. ``head_t`` is the build-time tied-embeddings head."""

    embed: Any
    final_ln: Any
    dense: Dict[str, Any]
    packed: Dict[str, D.StackedWeight]
    head: Any = None
    mm_proj: Any = None
    head_t: Any = None

    @property
    def n_layers(self) -> int:
        if self.dense:
            return int(next(iter(self.dense.values())).shape[0])
        return int(next(iter(self.packed.values())).n_layers)


jax.tree_util.register_pytree_node(
    StackedParams,
    lambda sp: ((sp.embed, sp.final_ln, sp.dense, sp.packed, sp.head,
                 sp.mm_proj, sp.head_t), None),
    lambda aux, ch: StackedParams(*ch),
)


def stack(sp: DP.ServingParams) -> StackedParams:
    """ServingParams (per-layer dicts) -> StackedParams (leading layer axis).

    Every projection key must be uniformly typed across layers (all packed
    or all dense) and, when packed, share the uniform envelope geometry -
    ``stack_deployed`` raises with a pointer at the uniform-tile search
    otherwise. Stacking is placement-preserving: macro-sharded projections
    stack into macro-sharded envelopes.
    """
    if not sp.layers:
        raise ValueError("stack: ServingParams has no layers")
    keys = list(sp.layers[0].keys())
    for i, p in enumerate(sp.layers[1:], 1):
        if list(p.keys()) != keys:
            raise ValueError(
                f"stack: layer {i} keys {sorted(p)} != layer 0 {sorted(keys)}")
    dense: Dict[str, Any] = {}
    packed: Dict[str, D.StackedWeight] = {}
    for k in keys:
        vs = [p[k] for p in sp.layers]
        n_packed = sum(isinstance(v, D.DeployedWeight) for v in vs)
        if n_packed == len(vs):
            packed[k] = D.stack_deployed(vs)
        elif n_packed == 0:
            dense[k] = jnp.stack([jnp.asarray(v) for v in vs])
        else:
            raise ValueError(
                f"stack: projection {k!r} is packed in {n_packed}/{len(vs)} "
                "layers - compress() packs all layers or none")
    return StackedParams(embed=sp.embed, final_ln=sp.final_ln, dense=dense,
                         packed=packed, head=sp.head, mm_proj=sp.mm_proj,
                         head_t=sp.head_t)


# StackedParams exposes the same head/head_t/embed fields as ServingParams,
# so the loop runtime's head resolution applies verbatim - one source of
# truth for the tied-head precompute keeps the runtimes in lockstep
_head = DP._head


def _layer_view(sxp: StackedParams, p_dense: dict, li) -> dict:
    """Per-layer param dict for the standard block bodies: dense leaves are
    the scan's sliced xs; packed projections are layer-indexed views into
    the uniform envelopes (``li`` is the traced scan index)."""
    p = dict(p_dense)
    for k, sw in sxp.packed.items():
        p[k] = D.StackedLayerView(sw, li)
    return p


def _scan_xs(sxp: StackedParams, cfg: ModelConfig, *extra):
    window_arr, theta_arr = transformer._layer_kind_arrays(cfg)
    return (jnp.arange(cfg.n_layers), sxp.dense, window_arr, theta_arr,
            *extra)


# ---------------------------------------------------------------------------
# Forward paths: single lax.scan over the stacked layer pytree
# ---------------------------------------------------------------------------


def prefill_hidden(sxp: StackedParams, batch: dict, cfg: ModelConfig):
    """Full-sequence forward, same math as ``deployed.prefill_hidden`` but
    one compiled scan. Returns (hidden (B,S,D), cache k/v (L,B,S,KV,dh))."""
    x = transformer._embed_inputs(
        {"embed": sxp.embed, "mm_proj": sxp.mm_proj}, batch, cfg)
    _, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    def body(x, xs):
        li, p_dense, w, t = xs
        p = _layer_view(sxp, p_dense, li)
        x, _, kv = transformer._attn_mlp_body(p, x, cfg, w, t, positions)
        return x, kv

    x, (ks, vs) = jax.lax.scan(body, x, _scan_xs(sxp, cfg))
    x = L.rmsnorm(x, sxp.final_ln)
    return x, {"k": ks, "v": vs}


def prefill(sxp: StackedParams, batch: dict, cfg: ModelConfig):
    """Registry-signature prefill: (last-position logits, cache w/ 'pos')."""
    hidden, cache = prefill_hidden(sxp, batch, cfg)
    logits = L.logits_out(_head(sxp), hidden[:, -1:, :], cfg.cim)[:, 0, : cfg.vocab]
    total = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        total += batch["patch_embeds"].shape[1]
    cache["pos"] = jnp.asarray(total, jnp.int32)
    return logits, cache


def prefill_last(sxp: StackedParams, tokens: jnp.ndarray,
                 true_len: jnp.ndarray, cfg: ModelConfig):
    """Batch-server prefill over padded prompts (see
    ``deployed.prefill_last`` for the causality argument)."""
    hidden, cache = prefill_hidden(sxp, {"tokens": tokens}, cfg)
    h_last = jnp.take(hidden, jnp.asarray(true_len - 1, jnp.int32), axis=1)
    logits = L.logits_out(_head(sxp), h_last[:, None, :], cfg.cim)[:, 0, : cfg.vocab]
    return logits, cache["k"], cache["v"]


def decode_step(sxp: StackedParams, cache: dict, tokens: jnp.ndarray,
                cfg: ModelConfig):
    """One decode step, single compiled scan; the per-layer KV write is a
    ``dynamic_update_slice`` into the scanned cache slice and the scan's ys
    ARE the new stacked cache (no per-step restack). Math-identical to
    ``deployed.decode_step``."""
    x = L.embed(sxp.embed, tokens, cfg.param_dtype)
    pos = cache["pos"]

    def body(x, xs):
        li, p_dense, w, t, kc, vc = xs
        p = _layer_view(sxp, p_dense, li)
        cfg_l = transformer._with_theta(cfg, t)
        h = L.rmsnorm(x, p["ln1"])
        attn, kc, vc = L.decode_attention(p, h, kc, vc, pos, cfg_l, window=w)
        x = x + attn
        h = L.rmsnorm(x, p["ln2"])
        x = x + DP._mlp(p, h, cfg)
        return x, (kc, vc)

    x, (k, v) = jax.lax.scan(
        body, x, _scan_xs(sxp, cfg, cache["k"], cache["v"]))
    new_cache = {"k": k, "v": v, "pos": pos + 1}
    x = L.rmsnorm(x, sxp.final_ln)
    logits = L.logits_out(_head(sxp), x, cfg.cim)[:, 0, : cfg.vocab]
    return logits, new_cache


def decode_step_paged(sxp: StackedParams, views_k: jnp.ndarray,
                      views_v: jnp.ndarray, pos: jnp.ndarray,
                      tokens: jnp.ndarray, cfg: ModelConfig):
    """One continuous-batching decode step over gathered paged-KV views,
    compiled as a single scan (the loop runtime's ``jnp.stack(ks)`` becomes
    the scan's ys). Same signature/semantics as
    ``deployed.decode_step_paged``."""
    x = L.embed(sxp.embed, tokens, cfg.param_dtype)

    def body(x, xs):
        li, p_dense, w, t, kview, vview = xs
        p = _layer_view(sxp, p_dense, li)
        cfg_l = transformer._with_theta(cfg, t)
        h = L.rmsnorm(x, p["ln1"])
        attn, kn, vn = L.decode_attention_multi(p, h, kview, vview, pos,
                                                cfg_l, window=w)
        x = x + attn
        h = L.rmsnorm(x, p["ln2"])
        x = x + DP._mlp(p, h, cfg)
        return x, (kn[:, 0], vn[:, 0])

    x, (ks, vs) = jax.lax.scan(
        body, x, _scan_xs(sxp, cfg, views_k, views_v))
    x = L.rmsnorm(x, sxp.final_ln)
    logits = L.logits_out(_head(sxp), x, cfg.cim)[:, 0, : cfg.vocab]
    return logits, ks, vs


def decode_step_masked(sxp: StackedParams, views_k: jnp.ndarray,
                       views_v: jnp.ndarray, pos: jnp.ndarray,
                       tokens: jnp.ndarray, cfg: ModelConfig,
                       attn_on: jnp.ndarray, mlp_on: jnp.ndarray):
    """One decode step over a SUBLAYER SUBSET of the same stacked envelope.

    ``attn_on`` / ``mlp_on`` are (L,) 0/1 masks scanned alongside the layer
    index: a masked-off sublayer is skipped with ``lax.cond`` - the branch
    genuinely elides the BSR matmuls (HLO conditional, not a multiply-by-
    zero), so a layer-skip draft really costs ~``keep`` of a target step.
    This is the layer-skip speculative draft's forward: the SAME
    StackedWeight envelope, no second packing, no extra weight memory -
    PR 4's layer-indexed kernel makes any layer subset addressable for
    free. Skipped-attention layers return zero KV rows; nothing ever reads
    them (a layer whose attention is off never attends), and draft KV is
    never committed to the pool anyway.

    Same signature/returns as :func:`decode_step_paged` plus the masks."""
    x = L.embed(sxp.embed, tokens, cfg.param_dtype)

    def body(x, xs):
        li, p_dense, w, t, kview, vview, a_on, m_on = xs
        p = _layer_view(sxp, p_dense, li)
        cfg_l = transformer._with_theta(cfg, t)

        def run_attn(args):
            x, kview, vview = args
            h = L.rmsnorm(x, p["ln1"])
            attn, kn, vn = L.decode_attention_multi(p, h, kview, vview, pos,
                                                    cfg_l, window=w)
            return x + attn, kn[:, 0], vn[:, 0]

        def skip_attn(args):
            x, kview, _ = args
            z = jnp.zeros_like(kview[:, 0])
            return x, z, z

        x, kn, vn = jax.lax.cond(a_on > 0, run_attn, skip_attn,
                                 (x, kview, vview))

        def run_mlp(x):
            h = L.rmsnorm(x, p["ln2"])
            return x + DP._mlp(p, h, cfg)

        x = jax.lax.cond(m_on > 0, run_mlp, lambda x: x, x)
        return x, (kn, vn)

    x, (ks, vs) = jax.lax.scan(
        body, x, _scan_xs(sxp, cfg, views_k, views_v, attn_on, mlp_on))
    x = L.rmsnorm(x, sxp.final_ln)
    logits = L.logits_out(_head(sxp), x, cfg.cim)[:, 0, : cfg.vocab]
    return logits, ks, vs


# MLP over (B, T, D) with sequential-decode semantics per token - one
# source of truth, shared with the loop runtime (docstring there)
_mlp_tokenwise = DP._mlp_tokenwise


def verify_step(sxp: StackedParams, views_k: jnp.ndarray,
                views_v: jnp.ndarray, pos: jnp.ndarray, tokens: jnp.ndarray,
                cfg: ModelConfig):
    """Batched multi-token target pass for speculative decoding.

    ``tokens`` (B, T) are row b's next T input tokens at absolute positions
    ``pos[b] .. pos[b]+T-1`` (the pending token followed by the draft run);
    a prefill-style causal pass over the gathered paged views with per-row
    positions (``layers.decode_attention_multi``), compiled as the same
    single ``lax.scan`` as :func:`decode_step_paged`.

    Returns (logits (B, T, V), k_new (L, B, T, KV, dh), v_new): position
    ``t``'s logits are BIT-IDENTICAL to what T sequential
    ``decode_step_paged`` calls would produce after consuming
    ``tokens[:, :t+1]`` - every op is row/position-independent and masked
    view padding is numerically inert - so greedy acceptance against these
    logits reproduces target-only greedy decode exactly. The caller commits
    only the accepted prefix of k_new/v_new to the KV pool (rejecting a
    draft suffix is a write-back rollback, not a compute rollback)."""
    x = L.embed(sxp.embed, tokens, cfg.param_dtype)  # (B, T, D)

    def body(x, xs):
        li, p_dense, w, t, kview, vview = xs
        p = _layer_view(sxp, p_dense, li)
        cfg_l = transformer._with_theta(cfg, t)
        h = L.rmsnorm(x, p["ln1"])
        attn, kn, vn = L.decode_attention_multi(p, h, kview, vview, pos,
                                                cfg_l, window=w)
        x = x + attn
        h = L.rmsnorm(x, p["ln2"])
        x = x + _mlp_tokenwise(p, h, cfg)
        return x, (kn, vn)

    x, (ks, vs) = jax.lax.scan(
        body, x, _scan_xs(sxp, cfg, views_k, views_v))
    x = L.rmsnorm(x, sxp.final_ln)
    logits = L.logits_out(_head(sxp), x, cfg.cim)[..., : cfg.vocab]
    return logits, ks, vs


def model_fns(cfg: ModelConfig) -> registry.ModelFns:
    """ModelFns over a :class:`StackedParams` - plug into ``serve.Engine``
    (``fns=stacked.model_fns(cfg)``) to serve the compiled runtime through
    the same loop as the registry/loop engines."""
    DP._check_family(cfg)

    def _no_init(*a, **k):
        raise NotImplementedError(
            "StackedParams are built from ServingParams via serve.stacked."
            "stack, not initialized")

    return registry.ModelFns(
        init_params=_no_init,
        train_loss=_no_init,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=transformer.init_cache,
    )
