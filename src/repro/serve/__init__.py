from .engine import Engine, ServeConfig  # noqa: F401
