"""repro.serve - serving engines over raw or BSR-compressed weights.

  * :class:`Engine` - static-batch prefill+decode loop (any registry family).
  * :mod:`deployed` - ``ServingParams``/``compress``: pack every CIM-mapped
    projection through ``deploy_weight`` so the int8 BSR Pallas kernel is
    the decode hot path.
  * :mod:`batching` / :class:`BatchServer` - continuous batching with a
    paged (block-allocated) KV cache and slot-level admission.
  * ``deployed.shard`` + ``BatchServer(mesh=...)`` - tensor-parallel
    compressed serving over a ``macro`` mesh axis (the TPU stand-in for the
    MARS multi-macro cluster): projections column-sharded with the
    scheduler's LPT assignment, KV views sharded heads-wise, bit-identical
    tokens to single-device serving.
  * :mod:`stacked` + ``BatchServer(engine="scan")`` - the compiled runtime:
    per-layer packings fold into uniform-envelope ``StackedWeight`` stacks
    and every decode step is ONE jitted ``lax.scan`` (layer-indexed kernel,
    no per-layer dispatches), bit-identical to the loop runtime.
  * :mod:`spec` + ``BatchServer(engine="spec")`` - self-speculative
    decoding over two-tier compression: a higher-sparsity draft packing of
    the SAME weights proposes k tokens, one batched multi-token target
    verify accepts the longest greedy-matching prefix plus a correction
    token - greedy tokens stay bit-identical to target-only decode while
    multiple tokens commit per target pass.
  * ``deployed.save_artifact`` / ``load_artifact`` - offline serving
    artifacts: pack once at compile time, boot without re-packing
    (two-tier artifacts carry the draft packing alongside the target).
  * :mod:`prefix` / ``BatchConfig(prefix_cache=True)`` - radix-tree prefix
    KV reuse: refcounted, copy-on-write paged blocks let admissions whose
    prompt shares a full-block prefix adopt the cached block chain and
    prefill only the unshared suffix (cache-hit TTFT ~ one decode step),
    with greedy tokens bit-identical to sharing off.
  * ``BatchServer(tracer=..., metrics=...)`` - opt-in observability
    (:mod:`repro.obs`): fenced phase spans (admit/prefill/gather/dispatch/
    sample/writeback, spec draft/verify/commit), per-request lifecycle
    tracks, occupancy gauges and per-(shape, tile, backend) kernel
    dispatch timing; disabled by default at no-op cost.
"""
from . import batching, deployed, prefix, server, spec, stacked  # noqa: F401
from .batching import PagedKVCache, Request, RequestQueue  # noqa: F401
from .engine import Engine, ServeConfig  # noqa: F401
from .prefix import PrefixTrie  # noqa: F401
from .server import BatchConfig, BatchServer, ServeReport  # noqa: F401
from .spec import SpecConfig, SpecParams  # noqa: F401
from .stacked import StackedParams  # noqa: F401
