"""Continuous-batching serve loop over (optionally compressed) weights.

One :class:`BatchServer` drives ``deployed.decode_step_paged`` over a fixed
number of slots. Every step decodes all slots in one batched call; finished
requests free their KV blocks and the freed slot admits the next queued
request immediately (continuous batching). With ``continuous=False`` the
same loop becomes the static baseline: admission waits until EVERY slot has
drained, so lanes idle exactly as a static batcher's padding rows do -
making static-vs-continuous a pure scheduling-policy comparison (identical
kernels, identical per-step cost).

Because the model functions route every projection through
``layers.cim_matmul``, the server serves raw float weights and BSR-packed
:class:`~repro.serve.deployed.ServingParams` identically - compressed
serving is a constructor argument, not a separate engine.

Admission reserves worst-case blocks (prompt + max_new) so a mid-stream
request can never deadlock the pool; a request that cannot fit even in an
empty pool is rejected at ``run`` time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..kernels.cim_bsr_matmul import MACRO_AXIS
from ..kernels.timing import DispatchTimer
from ..models.config import ModelConfig
from ..obs import NULL_METRICS, NULL_TRACER, phase_scope
from . import deployed, stacked
from . import spec as spec_mod
from .batching import PagedKVCache, Request, RequestQueue, Slot, kv_view_spec
from .engine import ServeConfig, sample_tokens
from .prefix import PrefixTrie


@dataclasses.dataclass
class BatchConfig:
    n_slots: int = 4
    block_size: int = 8
    # KV block budget PER DEVICE: when a macro-mesh server shards every
    # block's heads over N devices, the same per-device memory holds N x
    # blocks and the pool scales to n_blocks * N; if the heads do NOT
    # divide the mesh the views stay replicated and the pool stays at
    # n_blocks (scaling it would overrun every device's budget N-fold)
    n_blocks: int = 64
    # round the gathered view up to a multiple of this many blocks so jit
    # recompiles O(log) times instead of once per sequence-length block
    view_bucket: int = 2
    idle_wait_s: float = 0.002
    # radix-tree prefix KV reuse: admissions whose prompt shares a
    # full-block prefix with an earlier request adopt the cached block
    # chain (refcount bump, copy-on-write on divergence) and prefill only
    # the unshared suffix. Greedy tokens are bit-identical either way.
    prefix_cache: bool = True


def _percentiles(xs: List[float]) -> dict:
    """Latency percentiles; empty or non-finite-only traces (a run that
    decoded nothing) report zeros instead of NaN-poisoning the benchmark
    JSON."""
    a = np.asarray([x for x in xs if np.isfinite(x)], np.float64)
    if a.size == 0:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean())}


@dataclasses.dataclass
class ServeReport:
    """Throughput / latency summary of one trace."""

    n_requests: int
    total_tokens: int
    wall_s: float
    n_decode_steps: int
    ttft_s: List[float]  # per request
    tpot_s: List[float]  # per decode token, pooled across requests
    outputs: Dict[str, np.ndarray]
    kv_stats: dict
    spec: Optional[dict] = None  # speculative-decode acceptance telemetry
    # per-request admission-minus-arrival: the scheduling share of TTFT
    queue_wait_s: List[float] = dataclasses.field(default_factory=list)
    metrics: Optional[dict] = None  # obs snapshot (instrumented runs only)
    # prefix-cache telemetry: trie hit/insert/evict counts plus the
    # hit-vs-miss split of service TTFT (None when prefix_cache=False)
    prefix: Optional[dict] = None
    # gateway attribution: which tenant this report covers ("" = the
    # whole single-tenant server run)
    tenant: str = ""

    @property
    def tokens_per_s(self) -> float:
        """0.0 for an empty trace or a zero-duration run (nothing decoded
        in no time is throughput 0, not 0/0)."""
        if self.total_tokens == 0 or self.wall_s <= 0.0:
            return 0.0
        return self.total_tokens / self.wall_s

    _n_slots: int = 1

    @property
    def slot_efficiency(self) -> float:
        """Fraction of decoded lanes that produced a kept token (prefill
        emits each request's first token, so those don't count)."""
        if self.n_decode_steps == 0 or self._n_slots < 1:
            return 1.0
        return min(1.0, max(0.0, self.total_tokens - self.n_requests)
                   / (self.n_decode_steps * self._n_slots))

    def to_json(self) -> dict:
        out = {
            **({"tenant": self.tenant} if self.tenant else {}),
            "n_requests": self.n_requests,
            "total_tokens": self.total_tokens,
            "wall_s": round(self.wall_s, 4),
            "tokens_per_s": round(self.tokens_per_s, 2),
            "n_decode_steps": self.n_decode_steps,
            "slot_efficiency": round(self.slot_efficiency, 4),
            "ttft": {k: round(v, 5) for k, v in _percentiles(self.ttft_s).items()},
            "tpot": {k: round(v, 5) for k, v in _percentiles(self.tpot_s).items()},
            "kv": self.kv_stats,
        }
        # TTFT = queue wait (scheduling) + service (prefill-to-first-token):
        # reported separately so load-induced queueing can't masquerade as a
        # prefill regression (and vice versa)
        service = [max(t - w, 0.0)
                   for t, w in zip(self.ttft_s, self.queue_wait_s)]
        out["queue_wait"] = {k: round(v, 5) for k, v in
                            _percentiles(self.queue_wait_s).items()}
        out["ttft_service"] = {k: round(v, 5) for k, v in
                               _percentiles(service).items()}
        if self.spec is not None:
            out["spec"] = self.spec
        if self.metrics is not None:
            out["metrics"] = self.metrics
        if self.prefix is not None:
            out["prefix"] = self.prefix
        return out


class BatchServer:
    """Slot-based serving engine (continuous or static batching)."""

    def __init__(self, cfg: ModelConfig, sp: deployed.ServingParams,
                 scfg: Optional[ServeConfig] = None,
                 bcfg: Optional[BatchConfig] = None,
                 continuous: bool = True, mesh: Optional[Mesh] = None,
                 engine: str = "loop",
                 draft: Optional[deployed.ServingParams] = None,
                 spec: Optional[spec_mod.SpecConfig] = None,
                 tracer=None, metrics=None):
        """``mesh`` (with a ``macro`` axis) turns on macro-cluster serving:
        pass ``deployed.shard(sp, mesh)`` as ``sp`` so projections run
        tensor-parallel, the gathered KV views are sharded heads-wise, and
        the block pool scales to ``bcfg.n_blocks`` per device. The loop
        itself is unchanged - 1 and N devices run the same code.

        ``engine`` picks the decode runtime over the SAME weights:
        ``"loop"`` (python loop over per-layer packed weights), ``"scan"``
        (``serve.stacked``: one jitted lax.scan per step over the uniform
        envelope, views donated), or ``"spec"`` (self-speculative: a draft
        tier proposes up to ``spec.k`` tokens with the scan runtime - the
        reprune family over a higher-sparsity ``draft`` packing, the
        layerskip family by an nnz-ranked sublayer subset of the target's
        own envelope - and ONE multi-token target verify accepts the
        longest greedy-matching prefix plus a correction token; per-slot
        adaptive k collapses the draft length when acceptance dies). All
        three produce
        bit-identical greedy tokens; spec additionally requires greedy
        decoding (temperature 0) - with sampling the acceptance rule would
        need distribution-preserving rejection sampling, which this engine
        does not implement.

        ``tracer`` / ``metrics`` (a :class:`repro.obs.Tracer` /
        :class:`repro.obs.MetricsRegistry`) opt the loop into phase spans,
        per-request lifecycle tracks, occupancy gauges and fenced kernel
        dispatch timing. Default is the shared no-op singletons: every
        phase boundary fence is gated on them, so the un-instrumented hot
        path is byte-identical to an uninstrumented server."""
        if cfg.family == "vlm":
            raise NotImplementedError(
                "BatchServer serves token-only requests; vlm prefill needs "
                "per-request patch embeddings (use serve.Engine)")
        deployed._check_family(cfg)
        if engine not in ("loop", "scan", "spec"):
            raise ValueError(
                f"engine must be 'loop', 'scan' or 'spec', got {engine!r}")
        if engine != "spec" and (draft is not None or spec is not None):
            raise ValueError(
                f"draft/spec are speculative-decode arguments but engine="
                f"{engine!r} - pass engine='spec' to use them")
        self.cfg = cfg
        self.sp = sp
        self.engine = engine
        self.scfg = scfg if scfg is not None else ServeConfig()
        self.bcfg = bcfg if bcfg is not None else BatchConfig()
        self.continuous = continuous
        self.mesh = mesh
        self.n_devices = (int(mesh.shape[MACRO_AXIS])
                          if mesh is not None and MACRO_AXIS in mesh.axis_names
                          else 1)
        # pool scaling is earned by head sharding, not by device count
        self._kv_scale = (self.n_devices
                          if mesh is not None
                          and kv_view_spec(cfg, mesh) is not None else 1)
        # the gathered views are throwaways: donate them so the scan's
        # in-view dynamic_update_slice KV writes reuse the buffers
        # (CPU XLA can't alias freshly-transferred host arrays and only
        # warns, so donation is gated to real accelerator backends)
        donate = (1, 2) if jax.default_backend() != "cpu" else ()
        self.spec = None
        if engine == "spec":
            self.spec = spec if spec is not None else spec_mod.SpecConfig()
            if self.spec.draft == "reprune" and draft is None:
                raise ValueError(
                    "engine='spec' with the reprune family needs a draft "
                    "tier: pass draft=spec.draft_serving(cfg, sp, "
                    "draft_sparsity)")
            if self.spec.draft == "layerskip" and draft is not None:
                raise ValueError(
                    "the layerskip family drafts with a sublayer subset of "
                    "the TARGET envelope - there is no draft packing; drop "
                    "the draft= argument (or pick draft='reprune')")
            if self.scfg.temperature > 0.0:
                raise ValueError(
                    "engine='spec' is greedy-only (temperature=0): the "
                    "accept rule matches draft tokens against the target's "
                    "argmaxes, which is exact only for greedy decode")
            self._params = spec_mod.SpecParams.build(sp, draft)
            self._prefill = jax.jit(stacked.prefill_last,
                                    static_argnames=("cfg",))
            self._verify = jax.jit(stacked.verify_step,
                                   static_argnames=("cfg",),
                                   donate_argnums=donate)
            if self.spec.draft == "layerskip":
                # sublayer masks, ranked by the packed envelope's own nnz:
                # sublayers the compression already killed are dropped first
                # (skipping them cannot change a logit)
                imp = spec_mod.sublayer_importance(self._params.target)
                attn_on, mlp_on = spec_mod.layerskip_masks(
                    cfg.n_layers, self.spec.keep, importance=imp)
                self.spec_masks = (attn_on, mlp_on)
                self._attn_on = jnp.asarray(attn_on, jnp.float32)
                self._mlp_on = jnp.asarray(mlp_on, jnp.float32)
                self._draft_propose = jax.jit(
                    spec_mod.draft_propose_layerskip,
                    static_argnames=("cfg", "k"), donate_argnums=donate)
            else:
                self.spec_masks = None
                self._draft_propose = jax.jit(spec_mod.draft_propose,
                                              static_argnames=("cfg", "k"),
                                              donate_argnums=donate)
        elif engine == "scan":
            self._params = stacked.stack(sp)
            self._prefill = jax.jit(stacked.prefill_last,
                                    static_argnames=("cfg",))
            self._decode = jax.jit(stacked.decode_step_paged,
                                   static_argnames=("cfg",),
                                   donate_argnums=donate)
            # multi-token pass for the prefix-cache suffix prefill
            self._verify = jax.jit(stacked.verify_step,
                                   static_argnames=("cfg",),
                                   donate_argnums=donate)
        else:
            self._params = sp
            self._prefill = jax.jit(deployed.prefill_last,
                                    static_argnames=("cfg",))
            self._decode = jax.jit(deployed.decode_step_paged,
                                   static_argnames=("cfg",))
            self._verify = jax.jit(deployed.verify_step,
                                   static_argnames=("cfg",))
        # speculative lookahead: a verify writes KV up to pos+k, so
        # worst-case reservation must cover k extra positions per slot
        self._lookahead = self.spec.k if self.spec is not None else 0
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else NULL_METRICS
        self._obs = bool(self.tracer.recording or self.metrics.recording)
        # fenced per-(shape, tile, backend) kernel dispatch wall times;
        # disabled with observability so tracing-off never serializes jax.
        # The registry hookup mirrors each record into the
        # kernel_dispatch_s histogram for the metrics snapshot.
        self.timer = DispatchTimer(enabled=self._obs, metrics=self.metrics)
        dep = sp.deployed()
        self._tile = next(iter(dep.values())).tile if dep else None

    def _phase(self, name: str, **args):
        return phase_scope(self.tracer, self.metrics, name, **args)

    def _sample_row(self, logits: jnp.ndarray, key) -> np.ndarray:
        return np.asarray(sample_tokens(logits, key, self.scfg), np.int32)

    # -- admission ----------------------------------------------------------

    def _worst_blocks(self, req: Request) -> int:
        """Worst-case block demand: prompt + every decode token, plus the
        speculative lookahead (a verify pass writes candidate KV up to k
        positions past the committed stream)."""
        worst = len(req.prompt) + req.max_new_tokens + self._lookahead
        return -(-worst // self.bcfg.block_size)

    def _reserved(self, slots: List[Optional[Slot]], kv: PagedKVCache) -> int:
        """Blocks active slots may still demand beyond what they hold."""
        r = 0
        for i, s in enumerate(slots):
            if s is not None:
                r += max(0, kv.blocks_for(s.worst_positions + self._lookahead)
                         - len(kv.tables[i]))
        return r

    def _admit(self, q: RequestQueue, slots: List[Optional[Slot]],
               kv: PagedKVCache, now: float, key) -> None:
        if not self.continuous and any(s is not None for s in slots):
            return  # static policy: only whole-batch admission
        for i in range(self.bcfg.n_slots):
            if slots[i] is not None:
                continue
            req = q.pop_ready(now)
            if req is None:
                return
            wb = self._worst_blocks(req)
            if wb > kv.n_blocks - 1:
                raise ValueError(
                    f"{req.rid}: needs {wb} blocks, pool "
                    f"has {kv.n_blocks - 1} - raise n_blocks/block_size")
            # prefix-cache lookup: adopt the matched chain FIRST (refcount
            # bump) so the trie eviction below can never free it out from
            # under this admission
            shared: List[int] = []
            if self._trie is not None:
                shared = self._trie.match(req.prompt)
                if shared:
                    kv.adopt(i, shared)
                if self._obs:
                    self.metrics.counter("prefix_lookups").inc()
                    if shared:
                        self.metrics.counter("prefix_hits").inc()
                        self.metrics.counter("prefix_tokens_reused").inc(
                            len(shared) * self.bcfg.block_size)
            # sharing-aware reservation: adopted blocks are already live,
            # so only the UNSHARED span demands fresh blocks
            need = wb - len(shared)
            avail = kv.free_blocks - self._reserved(slots, kv)
            if need > avail and self._trie is not None:
                # drop cold cached prefixes the trie is the last holder of
                self._trie.evict(need - avail)
                avail = kv.free_blocks - self._reserved(slots, kv)
            if need > avail:
                kv.free_slot(i)  # roll back the adoption - leaks nothing
                q.requeue(req)  # backpressure: wait for a drain, keep FIFO
                return
            if self._obs and self._trie is not None:
                self.metrics.gauge("prefix_trie_blocks").set(
                    self._trie.held_blocks())
            key, sub = jax.random.split(key)
            # stamp queue wait at THIS request's admission, not the wave's
            # entry time: when several slots fill in one wave, the time a
            # later request spent behind earlier prefills is queue wait,
            # not its own service (TTFT splits on that boundary)
            slots[i] = self._prefill_slot(
                i, req, kv, sub, n_shared=len(shared),
                queue_wait=max(0.0, self._now() - max(req.arrival, 0.0)))

    def _prefill_slot(self, i: int, req: Request, kv: PagedKVCache,
                      key, queue_wait: float = 0.0, n_shared: int = 0) -> Slot:
        with self._phase("prefill", rid=req.rid, slot=i,
                         shared_blocks=n_shared):
            return self._prefill_impl(i, req, kv, key, queue_wait, n_shared)

    def _prefill_impl(self, i: int, req: Request, kv: PagedKVCache,
                      key, queue_wait: float, n_shared: int = 0) -> Slot:
        bs = self.bcfg.block_size
        tlen = len(req.prompt)
        if n_shared:
            logits = self._suffix_prefill(i, req, kv, n_shared)
        else:
            pad = (-tlen) % bs
            toks = np.pad(req.prompt, (0, pad))[None]  # (1, S_pad)
            target = (self._params.target if self.spec is not None
                      else self._params)
            logits, k, v = self._prefill(target, jnp.asarray(toks),
                                         jnp.asarray(tlen, jnp.int32),
                                         cfg=self.cfg)
            kv.write_prefill(i, k[:, 0], v[:, 0], tlen)
            if self.spec is not None and self._params.draft is not None:
                # reprune draft-tier prefill: keeps the draft cache in
                # lockstep with the target from the first decode step (its
                # logits are unused - the first emitted token is the
                # TARGET's, like any engine). The layerskip family has no
                # draft cache: its draft reads the target's own KV.
                _, kd, vd = self._prefill(self._params.draft,
                                          jnp.asarray(toks),
                                          jnp.asarray(tlen, jnp.int32),
                                          cfg=self.cfg)
                kv.write_prefill(i, kd[:, 0], vd[:, 0], tlen, tier=1)
        if self._trie is not None:
            # register this prompt's full blocks AFTER the KV writes land
            # (inserting first would let the writes copy-on-write the fresh
            # blocks away from their own prefill); chunks already cached
            # keep their existing block - including the ones just adopted
            nf = tlen // bs
            if nf:
                self._trie.insert(req.prompt[: nf * bs], kv.tables[i][:nf])
        if self.spec is not None and self.spec.adaptive_k:
            self._adaptive[i] = spec_mod.AdaptiveK(
                k_max=self.spec.k, ewma=self.spec.ewma,
                collapse_below=self.spec.collapse_below,
                expand_above=self.spec.expand_above)
        tok = int(self._sample_row(logits, key)[0])
        now = self._now()
        return Slot(req=req, pos=tlen, next_token=tok, out=[tok],
                    t_admit=now, token_times=[now], queue_wait_s=queue_wait,
                    prefix_tokens=n_shared * bs)

    def _suffix_prefill(self, i: int, req: Request, kv: PagedKVCache,
                        n_shared: int) -> jnp.ndarray:
        """Prefix-cache hit: positions [0, n_shared*bs) were adopted from
        the trie, so only the unshared suffix runs - ONE multi-token
        ``verify_step`` over the gathered paged views (the same pass
        speculative decode verifies drafts with) computes the suffix KV and
        the last real position's logits. Cache-hit TTFT is therefore one
        (multi-token) decode step, not a full prefill."""
        bs = self.bcfg.block_size
        tlen = len(req.prompt)
        m = n_shared * bs
        t = tlen - m  # >= 1 by the trie's match cap
        t_pad = -(-t // bs) * bs
        kv.ensure(i, tlen)
        # pad suffix tokens sit at positions >= tlen: causal per-row masking
        # keeps them out of every real position's logits/KV, and their own
        # KV is simply never committed
        toks = jnp.asarray(np.pad(req.prompt[m:], (0, t_pad - t))[None])
        pos = jnp.asarray([m], jnp.int32)
        nv = -(-kv.blocks_for(m + t_pad) // self.bcfg.view_bucket) \
            * self.bcfg.view_bucket
        target = (self._params.target if self.spec is not None
                  else self._params)
        vk, vv = kv.gather(nv, tier=0, slots=[i])
        logits, ks, vs = self._verify(target, vk, vv,
                                      pos, toks, cfg=self.cfg)
        ks, vs = np.asarray(ks), np.asarray(vs)
        kv.write_run(i, m, ks[:, 0, :t], vs[:, 0, :t])
        if self.spec is not None and self._params.draft is not None:
            # reprune draft tier: same suffix pass over the tier-1 views, so
            # the draft cache stays in lockstep from the first spec round
            dk, dv = kv.gather(nv, tier=1, slots=[i])
            _, kd, vd = self._verify(self._params.draft,
                                     dk, dv,
                                     pos, toks, cfg=self.cfg)
            kd, vd = np.asarray(kd), np.asarray(vd)
            kv.write_run(i, m, kd[:, 0, :t], vd[:, 0, :t], tier=1)
        return logits[:, t - 1]

    # -- main loop -----------------------------------------------------------

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def _gather_views(self, slots: List[Optional[Slot]], kv: PagedKVCache,
                      active: List[int], lookahead: int, tier: int = 0):
        """Grow tables to cover this step's writes, then gather a bucketed
        contiguous view of one KV tier."""
        for i in active:
            kv.ensure(i, slots[i].pos + 1 + lookahead)
        nv = max(len(kv.tables[i]) for i in active)
        nv = -(-nv // self.bcfg.view_bucket) * self.bcfg.view_bucket
        return kv.gather(nv, tier=tier)

    def _decode_step(self, slots: List[Optional[Slot]], kv: PagedKVCache,
                     active: List[int], key) -> List[tuple]:
        """One single-token decode over all slots (loop/scan engines).
        Returns [(slot index, [token]), ...] after committing the KV.

        Instrumented phases fence at their boundary (``block_until_ready``
        / host transfers) so the spans partition the step honestly; with
        observability off no extra fence runs and dispatch stays async."""
        with self._phase("step.gather", n_active=len(active)):
            views_k, views_v = self._gather_views(slots, kv, active, 0)
            if self._obs:
                jax.block_until_ready((views_k, views_v))
        pos = np.array([s.pos if s else 0 for s in slots], np.int32)
        toks = np.array([[s.next_token if s else 0] for s in slots],
                        np.int32)
        with self._phase("step.dispatch", engine=self.engine):
            logits, k_new, v_new = self.timer.timed(
                f"decode.{self.engine}",
                (int(views_k.shape[1]), int(views_k.shape[2])), self._tile,
                self._decode, self._params, views_k, views_v,
                jnp.asarray(pos), jnp.asarray(toks), cfg=self.cfg)
        with self._phase("step.writeback"):
            pb, off = kv.write_coords([s.pos if s else None for s in slots])
            kv.write_token(pb, off, k_new, v_new)
        with self._phase("step.sample"):
            sampled = self._sample_row(logits, key)
        return [(i, [int(sampled[i])]) for i in active]

    def _round_k(self, active: List[int]) -> int:
        """This round's draft length: the MAX of the active slots' adaptive
        k (one mispredicting slot can therefore never drag the whole batch
        down to its collapsed k - it just stops accepting, while the batch
        keeps drafting for the slots that do), or the static spec.k with
        adaptation off. The doubling ladder keeps the set of distinct round
        shapes - and thus jit recompiles - at O(log k_max)."""
        if not self.spec.adaptive_k:
            return self.spec.k
        return max(self._adaptive[i].k for i in active)

    def _spec_step(self, slots: List[Optional[Slot]], kv: PagedKVCache,
                   active: List[int]) -> List[tuple]:
        """One draft-k-verify speculative round over all slots.

        The jitted draft loop proposes ``k`` tokens per row - the reprune
        family over its own higher-sparsity tier-1 views, the layerskip
        family by early-exit over the TARGET's envelope and tier-0 views;
        ONE batched multi-token ``verify_step`` scores the pending token
        plus the whole draft run on the target tier. Per slot, the longest
        prefix of the draft run matching the target's own greedy argmaxes
        is accepted, plus the target's correction token - so the emitted
        stream is bit-identical to target-only greedy decode. Only the
        accepted entries of the candidate KV are committed (``write_run``;
        both tiers for reprune, target-only for layerskip - its draft
        writes nothing anywhere); rejected draft KV never reaches the
        pool - that is the rollback. Returns [(slot index, tokens), ...]
        with 1..k+1 tokens per slot."""
        t_round = time.monotonic()
        k = self._round_k(active)
        layerskip = self._params.draft is None
        pos_np = np.array([s.pos if s else 0 for s in slots], np.int32)
        toks = np.array([[s.next_token if s else 0] for s in slots],
                        np.int32)
        pos = jnp.asarray(pos_np)
        with self._phase("spec.draft", k=k, n_active=len(active)):
            if layerskip:
                dk, dv = self._gather_views(slots, kv, active, k, tier=0)
                props = self._draft_propose(
                    self._params.target, dk, dv, pos, jnp.asarray(toks),
                    cfg=self.cfg, k=k, attn_on=self._attn_on,
                    mlp_on=self._mlp_on)
                d_ks = d_vs = None
            else:
                dk, dv = self._gather_views(slots, kv, active, k, tier=1)
                props, d_ks, d_vs = self._draft_propose(
                    self._params.draft, dk, dv, pos, jnp.asarray(toks),
                    cfg=self.cfg, k=k)
            # fencing props is ~free (the verify consumes them immediately)
            # and makes the draft/verify wall-time split honest
            props = jax.block_until_ready(props)
        t_draft = time.monotonic()
        with self._phase("spec.verify", k=k, n_active=len(active)):
            tk, tv = self._gather_views(slots, kv, active, k, tier=0)
            ver_toks = jnp.concatenate([jnp.asarray(toks), props], axis=1)
            logits, t_ks, t_vs = self._verify(self._params.target, tk, tv,
                                              pos, ver_toks, cfg=self.cfg)
            # greedy targets for every position of the run (B, k+1)
            y = np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        t_verify = time.monotonic()
        with self._phase("spec.commit"):
            props_np = np.asarray(props)
            if not layerskip:
                d_ks, d_vs = np.asarray(d_ks), np.asarray(d_vs)
            t_ks, t_vs = np.asarray(t_ks), np.asarray(t_vs)
            runs = []
            for i in active:
                s = slots[i]
                a = spec_mod.accept_greedy(props_np[i], y[i, :k])
                emitted = [int(t) for t in y[i, : a + 1]]
                # cap at the request budget and cut at EOS - exactly where
                # sequential decode would have stopped emitting
                emitted = emitted[: s.req.max_new_tokens - len(s.out)]
                if self.scfg.eos_id >= 0 and self.scfg.eos_id in emitted:
                    emitted = emitted[: emitted.index(self.scfg.eos_id) + 1]
                e = len(emitted)
                kv.write_run(i, s.pos, t_ks[:, i, :e], t_vs[:, i, :e], tier=0)
                if not layerskip:
                    kv.write_run(i, s.pos, d_ks[:, i, :e], d_vs[:, i, :e],
                                 tier=1)
                self._spec_stats.record(n_proposed=k,
                                        n_accepted=min(a, e - 1),
                                        n_emitted=e)
                if self.spec.adaptive_k:
                    # the tracker sees the RAW agreement a/k (not the
                    # budget/EOS-capped commit count): end-of-request
                    # truncation says nothing about draft quality
                    ad = self._adaptive[i]
                    k_was = ad.k
                    ad.observe(n_proposed=k, n_accepted=a)
                    if ad.k < k_was:
                        self._spec_stats.k_collapses += 1
                        if self._obs:
                            self.metrics.counter("spec_k_collapses").inc()
                    elif ad.k > k_was:
                        self._spec_stats.k_expands += 1
                runs.append((i, emitted))
                if self._obs:
                    self.metrics.counter("spec_accepted_tokens").inc(
                        min(a, e - 1))
                    self.metrics.counter("spec_rejected_tokens").inc(
                        k - min(a, e - 1))
        self._spec_stats.draft_s.append(t_draft - t_round)
        self._spec_stats.verify_s.append(t_verify - t_draft)
        self._spec_stats.round_s.append(time.monotonic() - t_round)
        return runs

    def run(self, requests: List[Request]) -> ServeReport:
        cfg, bcfg, scfg = self.cfg, self.bcfg, self.scfg
        q = RequestQueue(requests)
        kv = PagedKVCache(cfg, bcfg.n_slots, bcfg.n_blocks * self._kv_scale,
                          bcfg.block_size, mesh=self.mesh,
                          # only the reprune family keeps a second KV tier;
                          # the layerskip draft reads the target's own cache
                          tiers=2 if (self.spec is not None
                                      and self._params.draft is not None)
                          else 1)
        slots: List[Optional[Slot]] = [None] * bcfg.n_slots
        # the trie lives per run() so traces are independent (and warmup
        # runs never warm the cache of a timed run)
        self._trie = PrefixTrie(kv) if bcfg.prefix_cache else None
        outputs: Dict[str, np.ndarray] = {}
        ttft: List[float] = []
        tpot: List[float] = []
        queue_wait: List[float] = []
        ttft_hit: List[float] = []  # service TTFT, split hit vs miss
        ttft_miss: List[float] = []
        key = jax.random.PRNGKey(scfg.seed)
        n_steps = 0
        self._spec_stats = (spec_mod.SpecStats(self.spec.k,
                                               self.spec.draft_sparsity,
                                               family=self.spec.draft,
                                               keep=self.spec.keep)
                            if self.spec is not None else None)
        # per-slot adaptive-k trackers, created at admission and dropped
        # with the slot (a new request starts from the optimistic prior)
        self._adaptive: Dict[int, spec_mod.AdaptiveK] = {}
        self._t0 = time.monotonic()

        def finish(i: int) -> None:
            s = slots[i]
            outputs[s.req.rid] = np.asarray(s.out, np.int32)
            ttft.append(s.token_times[0] - max(s.req.arrival, 0.0))
            queue_wait.append(s.queue_wait_s)
            service = max(ttft[-1] - s.queue_wait_s, 0.0)
            (ttft_hit if s.prefix_tokens else ttft_miss).append(service)
            tpot.extend(np.diff(s.token_times).tolist())
            if self.tracer.recording:
                # retroactive lifecycle spans: queued -> served, on a queue
                # track plus the slot's own track (slots serialize requests,
                # so per-track spans never overlap). Slot clocks are
                # t0-relative; the tracer wants epoch-relative seconds.
                off = self._t0 - self.tracer.epoch
                arr = max(s.req.arrival, 0.0)
                self.tracer.complete(
                    f"queued:{s.req.rid}", off + arr,
                    off + arr + s.queue_wait_s, track="queue", rid=s.req.rid)
                self.tracer.complete(
                    f"req:{s.req.rid}", off + s.t_admit,
                    off + s.token_times[-1], track=f"slot{i}",
                    rid=s.req.rid, tokens=len(s.out))
            self.metrics.counter("requests_finished").inc()
            kv.free_slot(i)
            slots[i] = None
            self._adaptive.pop(i, None)

        while len(q) or any(s is not None for s in slots):
            key, k_adm, k_dec = jax.random.split(key, 3)
            with self._phase("step.admit"):
                self._admit(q, slots, kv, self._now(), k_adm)
            # a request may be done straight out of prefill (max_new=1/EOS)
            for i, s in enumerate(slots):
                if s is not None and (s.done or s.next_token == scfg.eos_id):
                    finish(i)
            active = [i for i, s in enumerate(slots) if s is not None]
            if not active:
                if len(q):
                    nxt = q.next_arrival()
                    wait = 0.0 if nxt is None else nxt - self._now()
                    if wait > 0:
                        time.sleep(min(wait, bcfg.idle_wait_s))
                continue

            with self._phase("decode_step", step=n_steps,
                             engine=self.engine, n_active=len(active)):
                if self.spec is not None:
                    runs = self._spec_step(slots, kv, active)
                else:
                    runs = self._decode_step(slots, kv, active, k_dec)
            n_steps += 1
            if self._obs:
                in_use = kv.blocks_in_use
                self.metrics.gauge("slots_active").set(len(active))
                self.metrics.gauge("kv_blocks_in_use").set(in_use)
                self.metrics.gauge("kv_utilization").set(
                    in_use / kv.n_blocks)
                self.metrics.counter("decode_steps").inc()
                self.tracer.counter("serve", slots_active=len(active),
                                    kv_blocks_in_use=in_use)
            now = self._now()
            for i, toks in runs:
                s = slots[i]
                for tok in toks:
                    s.pos += 1
                    s.out.append(tok)
                    s.token_times.append(now)
                    s.next_token = tok
                if s.done or s.next_token == scfg.eos_id:
                    finish(i)

        wall = self._now()
        total = sum(len(o) for o in outputs.values())
        stats = kv.stats()
        stats["n_devices"] = self.n_devices
        snap = None
        if self._obs:
            snap = self.metrics.snapshot() or None
            disp = self.timer.summary()
            if disp and snap is not None:
                snap["kernel_dispatch"] = disp
        prefix = None
        if self._trie is not None:
            prefix = {k: (round(v, 4) if isinstance(v, float) else v)
                      for k, v in self._trie.stats().items()}
            prefix["cow_copies"] = kv.n_cow
            # hit-vs-miss split of SERVICE TTFT (queue wait excluded): the
            # number a cache hit is supposed to shrink toward one decode step
            prefix["ttft_service_hit"] = {
                k: round(v, 5) for k, v in _percentiles(ttft_hit).items()}
            prefix["ttft_service_miss"] = {
                k: round(v, 5) for k, v in _percentiles(ttft_miss).items()}
        rep = ServeReport(
            n_requests=len(outputs), total_tokens=total, wall_s=wall,
            n_decode_steps=n_steps, ttft_s=ttft, tpot_s=tpot,
            outputs=outputs, kv_stats=stats,
            spec=(self._spec_stats.to_json()
                  if self._spec_stats is not None else None),
            queue_wait_s=queue_wait, metrics=snap, prefix=prefix,
        )
        rep._n_slots = bcfg.n_slots
        return rep
