"""Self-speculative decoding over two-tier CIM compression.

MARS's co-design insight is that CIM-aware sparsity is a *knob*: the same
macro fabric can host the same weights at different compression points
(CIMPool pushes pooled weights to aggressive compression; CIMinus models
the sparse-tier cost). This module turns that knob into decode throughput:

  * the DRAFT tier is a second, higher-sparsity BSR packing of the same
    ServingParams - every deployed projection is re-pruned with
    ``core.sparsity.prune_mask_2d`` at ``draft_sparsity``, packed with the
    SAME uniform tile, and stacked through ``core.deploy.stack_deployed``
    (:func:`draft_serving`);
  * the TARGET tier is the existing compressed (or dense) model;
  * :class:`SpecParams` holds both tiers as ``StackedParams`` sharing one
    :class:`~repro.serve.batching.PagedKVCache` layout (tier 0 = target KV,
    tier 1 = draft KV - same block tables, same positions, and ONE refcount
    ledger: a prefix-cache hit adopts both tiers' KV at once, and a
    copy-on-write copies every tier of the shared block);
  * :func:`draft_propose` is the jitted draft loop: k greedy proposals with
    the compiled scan runtime (plus one trailing KV-fill step so the draft
    cache covers every position the target may commit);
  * ``serve.stacked.verify_step`` is the single batched multi-token target
    pass that scores the whole draft run at once.

Exactness contract: greedy acceptance takes the longest prefix of the
draft run that matches the target's own greedy argmaxes, plus the target's
correction token - so the emitted stream is BIT-IDENTICAL to target-only
greedy decode (``tests/test_spec.py`` enforces it, dense and compressed,
single-device and macro-sharded). The draft tier can only change HOW FAST
tokens appear, never WHICH tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import deploy as D
from ..core import sparsity as S
from ..kernels import ops
from ..models.config import ModelConfig
from . import deployed as DP
from . import stacked as ST


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode knobs. ``k`` draft tokens are proposed per
    verify; ``draft_sparsity`` is the draft tier's block-pruning target
    (``sched.search.search_spec`` picks both from the simulated
    reload+compute cost)."""

    k: int = 4
    draft_sparsity: float = 0.9

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("spec: k must be >= 1")
        if not 0.0 <= self.draft_sparsity < 1.0:
            raise ValueError("spec: draft_sparsity must be in [0, 1)")


@dataclasses.dataclass
class SpecParams:
    """Two-tier stacked serving weights (pytree): the compressed/dense
    ``target`` and the higher-sparsity ``draft``, both as StackedParams so
    either tier runs the compiled scan runtime. Both tiers describe the
    same architecture, so one PagedKVCache block layout serves both
    caches."""

    target: ST.StackedParams
    draft: ST.StackedParams

    def __post_init__(self):
        if self.target.n_layers != self.draft.n_layers:
            raise ValueError(
                f"spec: target has {self.target.n_layers} layers, draft "
                f"{self.draft.n_layers} - tiers must share the architecture")
        for k, sw in self.draft.packed.items():
            tw = self.target.packed.get(k)
            if tw is not None and tw.tile != sw.tile:
                raise ValueError(
                    f"spec: projection {k!r} packed with tile {sw.tile} in "
                    f"the draft but {tw.tile} in the target - the tiers "
                    "must share one uniform tile")

    @classmethod
    def build(cls, target_sp: DP.ServingParams,
              draft_sp: DP.ServingParams) -> "SpecParams":
        """Stack both tiers' ServingParams into the compiled envelopes."""
        return cls(target=ST.stack(target_sp), draft=ST.stack(draft_sp))


jax.tree_util.register_pytree_node(
    SpecParams,
    lambda sp: ((sp.target, sp.draft), None),
    lambda aux, ch: SpecParams(*ch),
)


# ---------------------------------------------------------------------------
# Draft tier construction: re-prune the SAME weights at a higher sparsity
# ---------------------------------------------------------------------------


def _dense_from_packed(p: dict, d_in: int, d_out: int,
                       bits: int) -> np.ndarray:
    """Dequantized dense view of one packed projection dict (host-side;
    ``core.mapping.bsr_to_dense`` handles the truncated-packing guard).
    ``pack_for_kernel`` packings carry ONE uniform scale, so dequant is a
    scalar multiply."""
    from ..core.mapping import BsrWeight, bsr_to_dense

    blocks = np.asarray(p["blocks"])
    bk, bn = blocks.shape[2], blocks.shape[3]
    bw = BsrWeight(blocks, np.asarray(p["row_idx"]), np.asarray(p["nnz"]),
                   bk, bn, d_in, d_out)
    scales = np.asarray(p["scales"])
    scale = (float(scales.max()) if scales.size and scales.max() > 0
             else 1.0 / 2.0 ** (bits - 1))
    return bsr_to_dense(bw).astype(np.float32) * scale


def _redeploy_sparser(dw: D.DeployedWeight, draft_sparsity: float
                      ) -> D.DeployedWeight:
    """Re-prune an already-packed projection at a higher sparsity.

    The packed blocks are dequantized to their dense (already quantized)
    values, ``prune_mask_2d`` drops the lowest-norm tiles down to
    ``draft_sparsity``, and the survivors are re-packed with the SAME tile.
    Masking quantized levels with 0/1 keeps the surviving blocks' int8
    levels bit-identical to the target tier's - the draft differs from the
    target ONLY in which blocks exist."""
    if dw.mesh is not None:
        raise ValueError(
            "build the draft tier from the placement-free packing and "
            "shard both tiers afterwards (deployed.shard)")
    bk, bn = dw.tile
    packed = []
    for p in dw.packed:
        w = _dense_from_packed(p, dw.d_in, dw.d_out, dw.bits)
        mask = np.asarray(S.prune_mask_2d(jnp.asarray(w), bk, bn,
                                          draft_sparsity))
        packed.append(ops.pack_for_kernel(w * mask, bits=dw.bits,
                                          bk=bk, bn=bn))
    return D.DeployedWeight(packed, dw.d_in, dw.d_out, dw.bits)


def draft_serving(cfg: ModelConfig, sp: DP.ServingParams,
                  draft_sparsity: float,
                  tile: Optional[Tuple[int, int]] = None
                  ) -> DP.ServingParams:
    """Second, higher-sparsity BSR packing of the same ServingParams.

    Compressed projections are re-pruned (:func:`_redeploy_sparser`) with
    their existing tile; raw (dense-serving) projections run the full
    ``deploy_weight`` pipeline at ``draft_sparsity`` with one uniform tile
    (``tile`` or the model's ``cim_alpha``, fitted network-wide so the
    draft stacks). Dense leaves (embed, norms, MoE expert stacks, the
    tied-head cache) are SHARED BY REFERENCE with the target - two-tier
    artifacts store them once.
    """
    g, a = tile if tile is not None else (cfg.cim_alpha, cfg.cim_alpha)
    net_tile = D.uniform_fit_tile(DP._projection_shapes(sp), g, a)

    def pack(v):
        if isinstance(v, D.DeployedWeight):
            return _redeploy_sparser(v, draft_sparsity)
        return D.deploy_weight(v, cfg.cim, bk=net_tile[0], bn=net_tile[1],
                               target_sparsity=draft_sparsity)

    layers = []
    for p in sp.layers:
        q = dict(p)
        for proj in DP.PROJECTIONS:
            w = q.get(proj)
            if w is None:
                continue
            if isinstance(w, D.DeployedWeight) or getattr(w, "ndim", 0) == 2:
                q[proj] = pack(w)
        layers.append(q)
    head = pack(sp.head) if sp.head is not None else None
    return DP.ServingParams(embed=sp.embed, final_ln=sp.final_ln,
                            layers=layers, head=head, mm_proj=sp.mm_proj,
                            head_t=sp.head_t)


# ---------------------------------------------------------------------------
# The jitted draft loop: k greedy proposals with the scan runtime
# ---------------------------------------------------------------------------


def draft_propose(draft: ST.StackedParams, views_k: jnp.ndarray,
                  views_v: jnp.ndarray, pos: jnp.ndarray,
                  tokens: jnp.ndarray, cfg: ModelConfig, k: int):
    """Greedy-propose ``k`` draft tokens per row over the draft-tier views.

    Runs ``k+1`` compiled ``decode_step_paged`` scan steps (the compiled
    runtime - one kernel dispatch per step), carrying the in-flight KV
    writes through the gathered views. The extra trailing step consumes the
    last proposal so the returned draft KV covers positions
    ``pos .. pos+k`` - every position the target may commit when the whole
    run is accepted - keeping the draft cache in lockstep with the target
    cache at all acceptance outcomes.

    Returns (proposals (B, k) int32, k_new (L, B, k+1, KV, dh), v_new).
    """
    b = tokens.shape[0]
    rows = jnp.arange(b)
    props, ks_all, vs_all = [], [], []
    tok = tokens  # (B, 1): each row's pending input token
    for t in range(k + 1):
        logits, ks, vs = ST.decode_step_paged(draft, views_k, views_v,
                                              pos + t, tok, cfg)
        views_k = views_k.at[:, rows, pos + t].set(ks)
        views_v = views_v.at[:, rows, pos + t].set(vs)
        ks_all.append(ks)
        vs_all.append(vs)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if t < k:
            props.append(tok[:, 0])
    return (jnp.stack(props, axis=1), jnp.stack(ks_all, axis=2),
            jnp.stack(vs_all, axis=2))


def accept_greedy(proposals: np.ndarray, targets: np.ndarray) -> int:
    """Longest greedy-matching prefix: the number of draft tokens (row
    vectors ``proposals`` (k,) vs the target's argmaxes ``targets`` (k,))
    accepted before the first disagreement."""
    a = 0
    while a < len(proposals) and int(proposals[a]) == int(targets[a]):
        a += 1
    return a


@dataclasses.dataclass
class SpecStats:
    """Host-side acceptance + round-latency telemetry over a serve run.

    All tokens of one round materialize together (one draft loop + one
    verify), so per-token arrival diffs inside a round are legitimately
    zero - the meaningful decode-latency unit for the spec engine is the
    ROUND, recorded here (``round_s``), not the pooled per-token diffs.

    ``record`` is called once per ACTIVE SLOT of a round: ``slot_rounds``
    / ``proposed`` / ``accepted`` count slot-rounds (a round over B active
    slots proposes B*k draft tokens), while ``len(round_s)`` counts the
    batched rounds themselves."""

    k: int
    draft_sparsity: float
    slot_rounds: int = 0
    proposed: int = 0
    accepted: int = 0
    emitted: int = 0
    round_s: list = dataclasses.field(default_factory=list)
    # per-round sub-phases: draft_s covers the draft-tier gather + k-token
    # propose (fenced on the proposals), verify_s the target gather + one
    # multi-token verify + argmax transfer; round_s additionally includes
    # the host-side accept/commit tail
    draft_s: list = dataclasses.field(default_factory=list)
    verify_s: list = dataclasses.field(default_factory=list)

    def record(self, n_accepted: int, n_emitted: int) -> None:
        self.slot_rounds += 1
        self.proposed += self.k
        self.accepted += n_accepted
        self.emitted += n_emitted

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def tokens_per_verify(self) -> float:
        """Emitted tokens per slot-round (= per verify lane)."""
        return self.emitted / self.slot_rounds if self.slot_rounds else 0.0

    @property
    def round_p50_s(self) -> float:
        return float(np.percentile(self.round_s, 50)) if self.round_s else 0.0

    def to_json(self) -> dict:
        per_tok = (self.round_p50_s / max(self.tokens_per_verify, 1e-9)
                   if self.round_s else 0.0)
        out = {
            "k": self.k,
            "draft_sparsity": self.draft_sparsity,
            "n_rounds": len(self.round_s),  # batched draft+verify rounds
            "slot_rounds": self.slot_rounds,  # per-active-slot lanes
            "proposed": self.proposed,
            "accepted": self.accepted,
            "acceptance_rate": round(self.acceptance_rate, 4),
            "tokens_per_verify": round(self.tokens_per_verify, 3),
            "round_p50_ms": round(self.round_p50_s * 1e3, 3),
            "ms_per_token_p50": round(per_tok * 1e3, 3),
        }
        if self.draft_s:
            d50 = float(np.percentile(self.draft_s, 50))
            v50 = float(np.percentile(self.verify_s, 50))
            out.update({
                "draft_p50_ms": round(d50 * 1e3, 3),
                "verify_p50_ms": round(v50 * 1e3, 3),
                # share of the round spent drafting - the quantity the
                # speculative_summary cost model predicts from c_draft/c_verify
                "draft_share": round(d50 / max(d50 + v50, 1e-12), 4),
            })
        return out
