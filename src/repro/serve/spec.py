"""Self-speculative decoding over two-tier CIM compression.

MARS's co-design insight is that CIM-aware sparsity is a *knob*: the same
macro fabric can host the same weights at different compression points
(CIMPool pushes pooled weights to aggressive compression; CIMinus models
the sparse-tier cost). This module turns that knob into decode throughput:

  * the DRAFT tier is a second, higher-sparsity BSR packing of the same
    ServingParams - every deployed projection is re-pruned with
    ``core.sparsity.prune_mask_2d`` at ``draft_sparsity``, packed with the
    SAME uniform tile, and stacked through ``core.deploy.stack_deployed``
    (:func:`draft_serving`);
  * the TARGET tier is the existing compressed (or dense) model;
  * :class:`SpecParams` holds both tiers as ``StackedParams`` sharing one
    :class:`~repro.serve.batching.PagedKVCache` layout (tier 0 = target KV,
    tier 1 = draft KV - same block tables, same positions, and ONE refcount
    ledger: a prefix-cache hit adopts both tiers' KV at once, and a
    copy-on-write copies every tier of the shared block);
  * :func:`draft_propose` is the jitted draft loop: k greedy proposals with
    the compiled scan runtime (plus one trailing KV-fill step so the draft
    cache covers every position the target may commit);
  * ``serve.stacked.verify_step`` is the single batched multi-token target
    pass that scores the whole draft run at once.

Exactness contract: greedy acceptance takes the longest prefix of the
draft run that matches the target's own greedy argmaxes, plus the target's
correction token - so the emitted stream is BIT-IDENTICAL to target-only
greedy decode (``tests/test_spec.py`` enforces it, dense and compressed,
single-device and macro-sharded). The draft tier can only change HOW FAST
tokens appear, never WHICH tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import deploy as D
from ..core import sparsity as S
from ..kernels import ops
from ..models.config import ModelConfig
from . import deployed as DP
from . import stacked as ST


DRAFT_FAMILIES = ("reprune", "layerskip")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode knobs.

    ``k`` draft tokens are proposed per verify. The ``draft`` family picks
    HOW the draft tier is built from the same weights:

      * ``"reprune"``  - a second, higher-sparsity BSR packing
        (:func:`draft_serving`); ``draft_sparsity`` is its pruning target;
      * ``"layerskip"`` - a sublayer-subset ``lax.scan`` over the TARGET's
        own stacked envelope (no second packing, no extra weight memory,
        no draft KV tier); ``keep`` is the fraction of sublayer units
        (attention/MLP, 2 per layer) the draft executes -
        :func:`layerskip_masks` drops the least-important units first,
        ranked by the packed envelope's own per-layer nnz.

    ``adaptive_k`` turns on the per-slot EWMA acceptance tracker
    (:class:`AdaptiveK`): a slot whose smoothed acceptance falls below
    ``collapse_below`` collapses its k to 1 (draft cost ~ 0) and re-expands
    through a doubling ladder once it recovers past ``expand_above``.
    ``sched.search.search_spec`` picks (family, k, knob) from the
    simulated cost and the calibrated acceptance prior."""

    k: int = 4
    draft_sparsity: float = 0.9
    draft: str = "reprune"
    keep: float = 0.5
    adaptive_k: bool = True
    ewma: float = 0.35
    collapse_below: float = 0.2
    expand_above: float = 0.6

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("spec: k must be >= 1")
        if not 0.0 <= self.draft_sparsity < 1.0:
            raise ValueError("spec: draft_sparsity must be in [0, 1)")
        if self.draft not in DRAFT_FAMILIES:
            raise ValueError(
                f"spec: draft must be one of {DRAFT_FAMILIES}, "
                f"got {self.draft!r}")
        if not 0.0 < self.keep <= 1.0:
            raise ValueError("spec: keep must be in (0, 1]")
        if not 0.0 < self.ewma <= 1.0:
            raise ValueError("spec: ewma must be in (0, 1]")
        if not (0.0 <= self.collapse_below <= self.expand_above <= 1.0):
            raise ValueError(
                "spec: need 0 <= collapse_below <= expand_above <= 1 "
                "(the hysteresis band)")

    @property
    def knob(self) -> float:
        """The family's draft knob: re-prune sparsity or layer-skip keep."""
        return self.draft_sparsity if self.draft == "reprune" else self.keep


@dataclasses.dataclass
class SpecParams:
    """Two-tier stacked serving weights (pytree): the compressed/dense
    ``target`` and the higher-sparsity ``draft``, both as StackedParams so
    either tier runs the compiled scan runtime. Both tiers describe the
    same architecture, so one PagedKVCache block layout serves both
    caches."""

    target: ST.StackedParams
    draft: Optional[ST.StackedParams] = None  # None: layerskip family
    # (the draft IS a sublayer subset of the target envelope - no second
    # packing and no draft KV tier exist)

    def __post_init__(self):
        if self.draft is None:
            return
        if self.target.n_layers != self.draft.n_layers:
            raise ValueError(
                f"spec: target has {self.target.n_layers} layers, draft "
                f"{self.draft.n_layers} - tiers must share the architecture")
        for k, sw in self.draft.packed.items():
            tw = self.target.packed.get(k)
            if tw is not None and tw.tile != sw.tile:
                raise ValueError(
                    f"spec: projection {k!r} packed with tile {sw.tile} in "
                    f"the draft but {tw.tile} in the target - the tiers "
                    "must share one uniform tile")

    @classmethod
    def build(cls, target_sp: DP.ServingParams,
              draft_sp: Optional[DP.ServingParams] = None) -> "SpecParams":
        """Stack both tiers' ServingParams into the compiled envelopes
        (``draft_sp=None`` for the layer-skip family: one envelope serves
        both roles)."""
        return cls(target=ST.stack(target_sp),
                   draft=ST.stack(draft_sp) if draft_sp is not None else None)


jax.tree_util.register_pytree_node(
    SpecParams,
    lambda sp: ((sp.target, sp.draft), None),
    lambda aux, ch: SpecParams(*ch),
)


# ---------------------------------------------------------------------------
# Draft tier construction: re-prune the SAME weights at a higher sparsity
# ---------------------------------------------------------------------------


def _dense_from_packed(p: dict, d_in: int, d_out: int,
                       bits: int) -> np.ndarray:
    """Dequantized dense view of one packed projection dict (host-side;
    ``core.mapping.bsr_to_dense`` handles the truncated-packing guard).
    ``pack_for_kernel`` packings carry ONE uniform scale, so dequant is a
    scalar multiply."""
    from ..core.mapping import BsrWeight, bsr_to_dense

    blocks = np.asarray(p["blocks"])
    bk, bn = blocks.shape[2], blocks.shape[3]
    bw = BsrWeight(blocks, np.asarray(p["row_idx"]), np.asarray(p["nnz"]),
                   bk, bn, d_in, d_out)
    scales = np.asarray(p["scales"])
    scale = (float(scales.max()) if scales.size and scales.max() > 0
             else 1.0 / 2.0 ** (bits - 1))
    return bsr_to_dense(bw).astype(np.float32) * scale


def _redeploy_sparser(dw: D.DeployedWeight, draft_sparsity: float
                      ) -> D.DeployedWeight:
    """Re-prune an already-packed projection at a higher sparsity.

    The packed blocks are dequantized to their dense (already quantized)
    values, ``prune_mask_2d`` drops the lowest-norm tiles down to
    ``draft_sparsity``, and the survivors are re-packed with the SAME tile.
    Masking quantized levels with 0/1 keeps the surviving blocks' int8
    levels bit-identical to the target tier's - the draft differs from the
    target ONLY in which blocks exist."""
    if dw.mesh is not None:
        raise ValueError(
            "build the draft tier from the placement-free packing and "
            "shard both tiers afterwards (deployed.shard)")
    bk, bn = dw.tile
    packed = []
    for p in dw.packed:
        w = _dense_from_packed(p, dw.d_in, dw.d_out, dw.bits)
        mask = np.asarray(S.prune_mask_2d(jnp.asarray(w), bk, bn,
                                          draft_sparsity))
        packed.append(ops.pack_for_kernel(w * mask, bits=dw.bits,
                                          bk=bk, bn=bn))
    return D.DeployedWeight(packed, dw.d_in, dw.d_out, dw.bits)


def draft_serving(cfg: ModelConfig, sp: DP.ServingParams,
                  draft_sparsity: float,
                  tile: Optional[Tuple[int, int]] = None
                  ) -> DP.ServingParams:
    """Second, higher-sparsity BSR packing of the same ServingParams.

    Compressed projections are re-pruned (:func:`_redeploy_sparser`) with
    their existing tile; raw (dense-serving) projections run the full
    ``deploy_weight`` pipeline at ``draft_sparsity`` with one uniform tile
    (``tile`` or the model's ``cim_alpha``, fitted network-wide so the
    draft stacks). Dense leaves (embed, norms, MoE expert stacks, the
    tied-head cache) are SHARED BY REFERENCE with the target - two-tier
    artifacts store them once.
    """
    g, a = tile if tile is not None else (cfg.cim_alpha, cfg.cim_alpha)
    net_tile = D.uniform_fit_tile(DP._projection_shapes(sp), g, a)

    def pack(v):
        if isinstance(v, D.DeployedWeight):
            return _redeploy_sparser(v, draft_sparsity)
        return D.deploy_weight(v, cfg.cim, bk=net_tile[0], bn=net_tile[1],
                               target_sparsity=draft_sparsity)

    layers = []
    for p in sp.layers:
        q = dict(p)
        for proj in DP.PROJECTIONS:
            w = q.get(proj)
            if w is None:
                continue
            if isinstance(w, D.DeployedWeight) or getattr(w, "ndim", 0) == 2:
                q[proj] = pack(w)
        layers.append(q)
    head = pack(sp.head) if sp.head is not None else None
    return DP.ServingParams(embed=sp.embed, final_ln=sp.final_ln,
                            layers=layers, head=head, mm_proj=sp.mm_proj,
                            head_t=sp.head_t)


# ---------------------------------------------------------------------------
# Layer-skip draft family: a sublayer subset of the TARGET's own envelope
# ---------------------------------------------------------------------------


def _block_set(sw: D.StackedWeight, li: int) -> set:
    """The set of live (block-row, block-col) coordinates of layer ``li``
    in a stacked envelope (host-side)."""
    nnz = np.asarray(sw.nnz[li])
    ri = np.asarray(sw.row_idx[li])
    return {(int(ri[g, s]), g)
            for g in range(nnz.shape[0]) for s in range(int(nnz[g]))}


def _proj_nnz(sxp: ST.StackedParams, name: str, li: int) -> Optional[int]:
    sw = sxp.packed.get(name)
    if sw is None:
        return None  # dense-serving projection: never counts as prunable
    return int(np.asarray(sw.nnz[li]).sum())


def sublayer_importance(sxp: ST.StackedParams
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-sublayer liveness score from the packed envelope's own nnz.

    CIM-aware pruning can kill a whole sublayer: attention is dead when any
    serial member (q/k/v/o) lost every block (v=0 makes the weighted sum 0
    no matter the scores), and a gated MLP is dead when the gate and up
    projections keep DISJOINT block supports (``silu(0)*u = g*0 = 0``
    elementwise) or the down projection is empty. The score is the min
    live-block count along each sublayer's serial chain - 0 means skipping
    it cannot change a single logit, so the layer-skip draft drops it for
    free. Dense (un-packed) members are treated as fully live.

    Returns (attn (L,), mlp (L,)) float arrays."""
    L = sxp.n_layers
    attn = np.full(L, np.inf)
    mlp = np.full(L, np.inf)
    for li in range(L):
        serial = [_proj_nnz(sxp, n, li) for n in ("wq", "wk", "wv", "wo")]
        live = [s for s in serial if s is not None]
        if live:
            attn[li] = float(min(live))
        gate, up = sxp.packed.get("w_gate"), sxp.packed.get("w_up")
        parts = []
        if gate is not None and up is not None:
            parts.append(len(_block_set(gate, li) & _block_set(up, li)))
        elif up is not None:
            parts.append(int(np.asarray(up.nnz[li]).sum()))
        down = _proj_nnz(sxp, "w_down", li)
        if down is not None:
            parts.append(down)
        if parts:
            mlp[li] = float(min(parts))
    return attn, mlp


def layerskip_masks(n_layers: int, keep: float,
                    importance: Optional[Tuple[np.ndarray, np.ndarray]] = None
                    ) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Pick which sublayers the layer-skip draft executes.

    ``keep`` is the fraction of the 2L sublayer units (one attention + one
    MLP per layer) kept; at least one unit always survives, and the LAST
    layer's attention is never dropped (the draft should still read the
    newest context even when an nnz ranking calls it cheap). Units are
    dropped least-important-first: ``importance`` is
    (:func:`sublayer_importance`'s) (attn, mlp) score pair - dead sublayers
    (score 0) go first, so on aggressively-compressed packings the draft
    sheds exactly the compute the pruning already killed. Without a score,
    the positional prior drops MLPs front-first, then attentions
    front-first (early-exit shape).

    Returns (attn_on, mlp_on) 0/1 tuples of length ``n_layers``."""
    L = n_layers
    n_keep = min(2 * L, max(1, int(round(keep * 2 * L))))
    if importance is None:
        attn_imp = np.asarray([2.0 + li / L for li in range(L)])
        mlp_imp = np.asarray([1.0 + li / L for li in range(L)])
    else:
        attn_imp, mlp_imp = (np.asarray(importance[0], np.float64),
                             np.asarray(importance[1], np.float64))
    # (importance, position) sort: least important first, earlier layers
    # break ties (their outputs get re-derived by more surviving layers)
    units = [(mlp_imp[li], li, "mlp", li) for li in range(L)]
    units += [(attn_imp[li], li, "attn", li) for li in range(L - 1)]
    units.sort(key=lambda u: (u[0], u[1]))
    attn_on = [1] * L
    mlp_on = [1] * L
    for imp, _, kind, li in units[: max(0, 2 * L - n_keep)]:
        (attn_on if kind == "attn" else mlp_on)[li] = 0
    return tuple(attn_on), tuple(mlp_on)


def kept_fraction(attn_on: Tuple[int, ...], mlp_on: Tuple[int, ...]) -> float:
    """Fraction of sublayer units the masks execute - the layer-skip
    draft's per-step cost relative to a full target step (the quantity
    ``perf_model.speculative_summary`` prices the draft with)."""
    total = len(attn_on) + len(mlp_on)
    return (sum(attn_on) + sum(mlp_on)) / max(total, 1)


def draft_propose_layerskip(target: ST.StackedParams, views_k: jnp.ndarray,
                            views_v: jnp.ndarray, pos: jnp.ndarray,
                            tokens: jnp.ndarray, cfg: ModelConfig, k: int,
                            attn_on: jnp.ndarray, mlp_on: jnp.ndarray):
    """Greedy-propose ``k`` tokens by early-exit over the target's layers.

    Runs ``k`` masked decode steps (``stacked.decode_step_masked``) over
    the TARGET envelope and the TARGET's own committed KV views - the
    layer-skip family has no draft weights and no draft KV tier. In-flight
    KV for the stepped positions is carried through the gathered views and
    thrown away with them: the verify pass recomputes exact target KV for
    every emitted position, so nothing here is ever committed (which is
    also why only ``k`` steps run - there is no trailing KV-fill step to
    keep a second cache in lockstep).

    Returns proposals (B, k) int32."""
    b = tokens.shape[0]
    rows = jnp.arange(b)
    props = []
    tok = tokens  # (B, 1): each row's pending input token
    for t in range(k):
        logits, ks, vs = ST.decode_step_masked(target, views_k, views_v,
                                               pos + t, tok, cfg,
                                               attn_on, mlp_on)
        views_k = views_k.at[:, rows, pos + t].set(ks)
        views_v = views_v.at[:, rows, pos + t].set(vs)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        props.append(tok[:, 0])
    return jnp.stack(props, axis=1)


# ---------------------------------------------------------------------------
# The jitted draft loop: k greedy proposals with the scan runtime
# ---------------------------------------------------------------------------


def draft_propose(draft: ST.StackedParams, views_k: jnp.ndarray,
                  views_v: jnp.ndarray, pos: jnp.ndarray,
                  tokens: jnp.ndarray, cfg: ModelConfig, k: int):
    """Greedy-propose ``k`` draft tokens per row over the draft-tier views.

    Runs ``k+1`` compiled ``decode_step_paged`` scan steps (the compiled
    runtime - one kernel dispatch per step), carrying the in-flight KV
    writes through the gathered views. The extra trailing step consumes the
    last proposal so the returned draft KV covers positions
    ``pos .. pos+k`` - every position the target may commit when the whole
    run is accepted - keeping the draft cache in lockstep with the target
    cache at all acceptance outcomes.

    Returns (proposals (B, k) int32, k_new (L, B, k+1, KV, dh), v_new).
    """
    b = tokens.shape[0]
    rows = jnp.arange(b)
    props, ks_all, vs_all = [], [], []
    tok = tokens  # (B, 1): each row's pending input token
    for t in range(k + 1):
        logits, ks, vs = ST.decode_step_paged(draft, views_k, views_v,
                                              pos + t, tok, cfg)
        views_k = views_k.at[:, rows, pos + t].set(ks)
        views_v = views_v.at[:, rows, pos + t].set(vs)
        ks_all.append(ks)
        vs_all.append(vs)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if t < k:
            props.append(tok[:, 0])
    return (jnp.stack(props, axis=1), jnp.stack(ks_all, axis=2),
            jnp.stack(vs_all, axis=2))


def accept_greedy(proposals: np.ndarray, targets: np.ndarray) -> int:
    """Longest greedy-matching prefix: the number of draft tokens (row
    vectors ``proposals`` (k,) vs the target's argmaxes ``targets`` (k,))
    accepted before the first disagreement."""
    a = 0
    while a < len(proposals) and int(proposals[a]) == int(targets[a]):
        a += 1
    return a


@dataclasses.dataclass
class AdaptiveK:
    """Per-slot EWMA acceptance tracker with collapse/recovery hysteresis.

    Every round :meth:`observe` folds the slot's measured acceptance into
    an EWMA (``ewma`` is the newest round's weight). When the smoothed rate
    falls below ``collapse_below`` the slot's k COLLAPSES to 1 - one draft
    step per round, so a mispredicting slot pays nearly nothing while still
    sampling acceptance every round (that one proposal is the probe that
    makes recovery observable). When the rate recovers past
    ``expand_above`` the k re-expands through a doubling ladder
    (1 -> 2 -> 4 -> ... -> k_max), so jit sees O(log k_max) distinct round
    shapes, not a new one per round. Between the thresholds k holds - the
    hysteresis band keeps a borderline slot from thrashing compilations.

    The tracker only modulates HOW MANY tokens are drafted; acceptance
    itself stays the greedy-exact rule, so emitted tokens are bit-identical
    at every k trajectory."""

    k_max: int
    ewma: float = 0.35
    collapse_below: float = 0.2
    expand_above: float = 0.6
    acc: float = dataclasses.field(init=False)
    k: int = dataclasses.field(init=False)
    collapses: int = 0
    expands: int = 0

    def __post_init__(self):
        # optimistic start: at the expand threshold with k wide open - the
        # first rounds measure, and a genuinely bad draft collapses within
        # ~log(collapse_below/expand_above)/log(1-ewma) rounds
        self.acc = self.expand_above
        self.k = self.k_max

    def observe(self, n_proposed: int, n_accepted: int) -> int:
        """Fold one round's (proposed, accepted) in; returns the slot's
        NEXT round k."""
        if n_proposed > 0:
            rate = n_accepted / n_proposed
            self.acc += self.ewma * (rate - self.acc)
        if self.k > 1 and self.acc < self.collapse_below:
            self.k = 1
            self.collapses += 1
        elif self.k < self.k_max and self.acc >= self.expand_above:
            self.k = min(self.k_max, self.k * 2)
            self.expands += 1
        return self.k


@dataclasses.dataclass
class SpecStats:
    """Host-side acceptance + round-latency telemetry over a serve run.

    All tokens of one round materialize together (one draft loop + one
    verify), so per-token arrival diffs inside a round are legitimately
    zero - the meaningful decode-latency unit for the spec engine is the
    ROUND, recorded here (``round_s``), not the pooled per-token diffs.

    ``record`` is called once per ACTIVE SLOT of a round: ``slot_rounds``
    / ``proposed`` / ``accepted`` count slot-rounds (a round over B active
    slots at round-k k proposes B*k draft tokens), while ``len(round_s)``
    counts the batched rounds themselves. With adaptive k the per-round
    proposal count varies, so ``record`` takes it explicitly;
    ``accept_hist`` buckets the accepted-prefix length per slot-round
    (index a = rounds whose first a proposals all matched)."""

    k: int
    draft_sparsity: float
    family: str = "reprune"
    keep: float = 1.0
    slot_rounds: int = 0
    proposed: int = 0
    accepted: int = 0
    emitted: int = 0
    k_collapses: int = 0
    k_expands: int = 0
    accept_hist: dict = dataclasses.field(default_factory=dict)
    round_s: list = dataclasses.field(default_factory=list)
    # per-round sub-phases: draft_s covers the draft-tier gather + k-token
    # propose (fenced on the proposals), verify_s the target gather + one
    # multi-token verify + argmax transfer; round_s additionally includes
    # the host-side accept/commit tail
    draft_s: list = dataclasses.field(default_factory=list)
    verify_s: list = dataclasses.field(default_factory=list)

    def record(self, n_proposed: int, n_accepted: int,
               n_emitted: int) -> None:
        self.slot_rounds += 1
        self.proposed += n_proposed
        self.accepted += n_accepted
        self.emitted += n_emitted
        self.accept_hist[n_accepted] = self.accept_hist.get(n_accepted, 0) + 1

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def tokens_per_verify(self) -> float:
        """Emitted tokens per slot-round (= per verify lane)."""
        return self.emitted / self.slot_rounds if self.slot_rounds else 0.0

    @property
    def round_p50_s(self) -> float:
        return float(np.percentile(self.round_s, 50)) if self.round_s else 0.0

    def to_json(self) -> dict:
        per_tok = (self.round_p50_s / max(self.tokens_per_verify, 1e-9)
                   if self.round_s else 0.0)
        out = {
            "k": self.k,
            "family": self.family,
            "draft_sparsity": self.draft_sparsity,
            "keep": self.keep,
            "n_rounds": len(self.round_s),  # batched draft+verify rounds
            "slot_rounds": self.slot_rounds,  # per-active-slot lanes
            "proposed": self.proposed,
            "accepted": self.accepted,
            "acceptance_rate": round(self.acceptance_rate, 4),
            "tokens_per_verify": round(self.tokens_per_verify, 3),
            # the per-family obs counters, mirrored here so un-instrumented
            # runs still report them
            "spec_accepted_tokens": self.accepted,
            "spec_rejected_tokens": self.proposed - self.accepted,
            "spec_k_collapses": self.k_collapses,
            "spec_k_expands": self.k_expands,
            # accepted-prefix-length histogram: list index a = slot-rounds
            # whose first a proposals all matched the target
            "accepted_len_hist": [
                self.accept_hist.get(a, 0)
                for a in range(max(self.accept_hist, default=0) + 1)],
            "round_p50_ms": round(self.round_p50_s * 1e3, 3),
            "ms_per_token_p50": round(per_tok * 1e3, 3),
        }
        if self.draft_s:
            d50 = float(np.percentile(self.draft_s, 50))
            v50 = float(np.percentile(self.verify_s, 50))
            out.update({
                "draft_p50_ms": round(d50 * 1e3, 3),
                "verify_p50_ms": round(v50 * 1e3, 3),
                # share of the round spent drafting - the quantity the
                # speculative_summary cost model predicts from c_draft/c_verify
                "draft_share": round(d50 / max(d50 + v50, 1e-12), 4),
            })
        return out
