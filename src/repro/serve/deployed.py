"""Compressed serving: registry params -> BSR-packed weights on the hot path.

MARS's inference premise (§III) is that the compressed representation - the
nonzero group-sets plus index codes - is BOTH the at-rest and the at-compute
form. For the LM zoo that means every CIM-mapped projection must execute
through ``core.deploy.deployed_matmul`` (the int8 block-sparse Pallas
kernel) at serving time, not just in kernel benchmarks.

This module provides the bridge:

  * :class:`ServingParams` - per-layer serving weights for the dense / moe /
    vlm families, registered as a jax pytree. Leaves are either raw arrays
    (dense serving) or :class:`~repro.core.deploy.DeployedWeight` (compressed
    serving); ``models.layers.cim_matmul`` dispatches per leaf, so the SAME
    forward code serves both.
  * :func:`compress` - walks a registry model's params and runs every
    2-D CIM-mapped projection (QKV/O, MLP, LM head) through ``deploy_weight``.
    The (bk, bn) block shape per projection comes from a ``sched.search``
    schedule, so the tile the simulator chose IS the tile the kernel runs.
  * :func:`model_fns` - prefill / decode_step with the registry signatures
    (python loop over per-layer packed weights), so ``serve.Engine`` serves
    compressed weights unchanged. This is the LOOP runtime - the reference
    the compiled ``serve.stacked`` scan runtime must reproduce bit-exactly.
  * :func:`decode_step_paged` - the per-row-position decode step the
    continuous-batching server drives over a paged KV view.
  * :func:`save_artifact` / :func:`load_artifact` - the offline serving
    artifact flow: mapping search + quantize + prune + BSR packing run ONCE
    at compile time, the packed :class:`ServingParams` lands on disk via
    ``train.checkpoint``, and a serving host boots from the directory
    without re-packing (``launch/serve.py --artifact DIR``).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import deploy as D
from ..models import registry, transformer
from ..models import layers as L
from ..models.config import ModelConfig
from ..sched import (NetworkSchedule, lm_graph, schedule_from_search,
                     search_mapping)
from ..train import checkpoint as ckpt

# projections deployed per transformer block (2-D leaves only: MoE expert
# stacks are 3-D and stay on the dense/QAT path)
PROJECTIONS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")

SUPPORTED_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass
class ServingParams:
    """Per-layer serving weights (pytree). ``layers[i]`` holds one block's
    params; projection leaves are arrays or DeployedWeight.

    ``head_t`` caches the tied-embeddings output head (``embed.T``),
    materialized ONCE at build time instead of re-transposing the full
    (V, D) embedding inside every prefill/decode trace. It is None whenever
    an explicit ``head`` exists, and is rebuilt (not stored) by the artifact
    loader."""

    embed: Any
    final_ln: Any
    layers: List[dict]
    head: Any = None  # None => tied embeddings (use head_t == embed.T)
    mm_proj: Any = None  # vlm projector (kept in float)
    head_t: Any = None  # precomputed embed.T for tied embeddings

    def deployed(self) -> Dict[str, D.DeployedWeight]:
        """Name -> DeployedWeight for every compressed projection."""
        out = {}
        for i, p in enumerate(self.layers):
            for k, v in p.items():
                if isinstance(v, D.DeployedWeight):
                    out[f"blk{i}_{k}"] = v
        if isinstance(self.head, D.DeployedWeight):
            out["head"] = self.head
        return out

    def report(self) -> dict:
        """Table IV-style storage accounting over the deployed projections."""
        return D.deployment_report(self.deployed())


jax.tree_util.register_pytree_node(
    ServingParams,
    lambda sp: ((sp.embed, sp.final_ln, sp.layers, sp.head, sp.mm_proj,
                 sp.head_t), None),
    lambda aux, ch: ServingParams(*ch),
)


def _check_family(cfg: ModelConfig) -> None:
    if cfg.family not in SUPPORTED_FAMILIES:
        raise NotImplementedError(
            f"serve.deployed supports families {SUPPORTED_FAMILIES}, not "
            f"{cfg.family!r} (ssm/hybrid/encdec caches have no paged-KV "
            "adaptation yet)")


def from_params(cfg: ModelConfig, params: dict) -> ServingParams:
    """Unstack registry params (stacked (L, ...) leaves) into per-layer
    dicts, without compressing anything."""
    _check_family(cfg)
    layers = [jax.tree.map(lambda a: a[i], params["layers"])
              for i in range(cfg.n_layers)]
    head = params.get("head")
    return ServingParams(
        embed=params["embed"], final_ln=params["final_ln"], layers=layers,
        head=head, mm_proj=params.get("mm_proj"),
        # tied embeddings: materialize the output head once, not per trace
        head_t=None if head is not None else jnp.asarray(params["embed"]).T,
    )


def default_schedule(cfg: ModelConfig, seq_len: int = 128,
                     groups=(16, 32, 64), alphas=(16, 32, 64),
                     sparsity_gs: float = 0.6,
                     uniform: bool = False) -> NetworkSchedule:
    """Mapping search over the model's CIM projection graph: the returned
    schedule's per-layer (group, alpha) becomes the serving (bk, bn).
    ``uniform=True`` restricts the search to tiles that exactly divide every
    projection (the stacked-deployment envelope)."""
    graph = lm_graph(cfg, seq_len=seq_len, sparsity_gs=sparsity_gs)
    result = search_mapping(graph, w_bits=cfg.w_bits, a_bits=cfg.a_bits,
                            groups=groups, alphas=alphas, uniform=uniform)
    return schedule_from_search(graph, result, w_bits=cfg.w_bits,
                                a_bits=cfg.a_bits)


def _projection_shapes(sp: ServingParams) -> List[Tuple[int, int]]:
    """(d_in, d_out) of every 2-D projection that compress() would pack
    (or already has - re-packing flows like the speculative draft tier
    walk compressed ServingParams too)."""

    def dims(w) -> Optional[Tuple[int, int]]:
        if isinstance(w, D.DeployedWeight):
            return (w.d_in, w.d_out)
        if getattr(w, "ndim", 0) == 2:
            return (int(w.shape[-2]), int(w.shape[-1]))
        return None

    shapes = []
    for p in sp.layers:
        for proj in PROJECTIONS:
            d = dims(p.get(proj))
            if d is not None:
                shapes.append(d)
    if sp.head is not None:
        d = dims(sp.head)
        if d is not None:
            shapes.append(d)
    return shapes


def compress(cfg: ModelConfig, params: dict,
             target_sparsity: Optional[float] = None,
             schedule: Optional[NetworkSchedule] = None,
             tile: Optional[Tuple[int, int]] = None,
             uniform: bool = False) -> ServingParams:
    """Pack every CIM-mapped 2-D projection for the BSR kernel.

    ``schedule`` (from ``sched.search`` over ``lm_graph(cfg)``) supplies the
    per-projection tile; without one, ``tile`` (or the model's ``cim_alpha``)
    is used (clipped to exact divisors). MoE expert stacks (3-D) and norm
    gains stay dense. ``target_sparsity=0`` packs every block (no pruning) -
    the numerically-honest configuration that must reproduce dense-math
    tokens.

    ``uniform=True`` packs the WHOLE network (head included) with one
    (bk, bn): the schedule's ``uniform_tile`` (or the requested ``tile``)
    clipped once to the largest shape that divides every projection, instead
    of per-projection clipping. This is the envelope contract
    ``serve.stacked`` / ``core.deploy.stack_deployed`` require.
    """
    sp = from_params(cfg, params)
    cim = cfg.cim
    tiles = {}
    if schedule is not None:
        tiles = {s.name: (s.group, s.alpha) for s in schedule.layers}
    fallback = tile if tile is not None else (cfg.cim_alpha, cfg.cim_alpha)
    if uniform:
        g, a = schedule.uniform_tile if schedule is not None else fallback
        net_tile = D.uniform_fit_tile(_projection_shapes(sp), g, a)
        tiles, fallback = {}, net_tile

    def pack(name: str, w) -> D.DeployedWeight:
        d_in, d_out = int(w.shape[-2]), int(w.shape[-1])
        g, a = tiles.get(name, fallback)
        bk, bn = D.fit_tile(d_in, d_out, g, a)
        return D.deploy_weight(w, cim, bk=bk, bn=bn,
                               target_sparsity=target_sparsity)

    for i, p in enumerate(sp.layers):
        for proj in PROJECTIONS:
            w = p.get(proj)
            if w is None or getattr(w, "ndim", 0) != 2:
                continue  # MoE expert stacks are (E, d, ff): leave dense
            p[proj] = pack(f"blk{i}_{proj}", w)
    if sp.head is not None:
        sp.head = pack("head", sp.head)
    return sp


def shard(sp: ServingParams, mesh) -> ServingParams:
    """Lay a compressed model over the serving macro cluster.

    Every :class:`~repro.core.deploy.DeployedWeight` is column-sharded over
    the mesh's ``macro`` axis with the SAME LPT policy the scheduler uses to
    balance kernel-groups over macros (``sched.allocate.device_assignment``
    on the per-column surviving-block counts). Projections whose column
    count does not divide the axis stay replicated - sharding never changes
    which blocks exist, so tokens are bit-identical to single-device
    serving. Dense leaves (embed, norms, MoE stacks) stay replicated.
    """
    from ..sched.allocate import device_assignment

    def maybe(v):
        if isinstance(v, D.DeployedWeight):
            return D.shard_weight(v, mesh, assign=device_assignment)
        return v

    return ServingParams(
        embed=sp.embed, final_ln=sp.final_ln,
        layers=[{k: maybe(v) for k, v in p.items()} for p in sp.layers],
        head=maybe(sp.head) if sp.head is not None else None,
        mm_proj=sp.mm_proj, head_t=sp.head_t,
    )


# ---------------------------------------------------------------------------
# Offline serving artifacts: pack once, boot many times
# ---------------------------------------------------------------------------

# Manifest schema version. Bump when the manifest layout changes
# incompatibly; loaders refuse artifacts NEWER than they understand
# (artifacts saved before versioning carry no field and load as legacy).
ARTIFACT_SCHEMA = 1


def packed_tiles(sp: ServingParams) -> List[Tuple[int, int]]:
    """Sorted unique (bk, bn) tiles across every deployed projection.
    A single-element list means the packing is UNIFORM - the stacked-scan
    envelope (and therefore in-place hot-swap) is possible."""
    return sorted({dw.tile for dw in sp.deployed().values()})


def validate_artifact(path: str, extra: dict, *,
                      arch: Optional[str] = None,
                      family: Optional[str] = None,
                      tile: Optional[Tuple[int, int]] = None) -> None:
    """The hot-swap compatibility gate: check a loaded manifest against
    what the serving host expects and raise a CLEAR error (artifact path +
    expected vs found) instead of letting a mismatched artifact fail deep
    inside ``core.deploy.stack_deployed``.

    Every check is skipped when the expectation (or the manifest field) is
    absent, so legacy artifacts written before versioning still load."""
    schema = extra.get("schema")
    if schema is not None and int(schema) > ARTIFACT_SCHEMA:
        raise ValueError(
            f"{path}: artifact manifest schema {schema} is newer than this "
            f"host supports ({ARTIFACT_SCHEMA}) - upgrade the serving host "
            "or re-save the artifact")
    if arch is not None and extra.get("arch") not in (None, arch):
        raise ValueError(
            f"{path}: artifact arch mismatch - expected {arch!r}, found "
            f"{extra['arch']!r}")
    if family is not None and extra.get("family") not in (None, family):
        raise ValueError(
            f"{path}: artifact family mismatch - expected {family!r}, "
            f"found {extra['family']!r}")
    if tile is not None and extra.get("tiles"):
        found = [tuple(t) for t in extra["tiles"]]
        if len(found) != 1 or found[0] != tuple(tile):
            raise ValueError(
                f"{path}: artifact tile mismatch - expected uniform "
                f"{tuple(tile)}, found {found} (re-pack with that tile, or "
                "stage a re-jit instead of hot-swapping)")


def _strip_placement(sp: ServingParams) -> ServingParams:
    """Serialization form: logical column order, no mesh, no derived
    tied-head cache."""

    def strip(v):
        if isinstance(v, D.DeployedWeight):
            return D.unshard_weight(v)
        return v

    return ServingParams(
        embed=sp.embed, final_ln=sp.final_ln,
        layers=[{k: strip(v) for k, v in p.items()} for p in sp.layers],
        head=strip(sp.head) if sp.head is not None else None,
        mm_proj=sp.mm_proj, head_t=None,
    )


def save_artifact(path: str, sp: ServingParams, cfg: ModelConfig,
                  extra: Optional[dict] = None,
                  draft: Optional[ServingParams] = None) -> str:
    """Persist a (compressed or dense) ServingParams as a boot-ready
    serving artifact.

    Placement is stripped before serialization (macro-sharded projections
    are restored to logical column order via ``core.deploy.unshard_weight``;
    the mesh never enters the serialized aux), and the derived tied-head
    cache is dropped - the loader rebuilds both, so one artifact serves any
    mesh shape. Written atomically through ``train.checkpoint``.

    ``draft`` makes the artifact two-tier (speculative serving): the
    higher-sparsity draft packing is stored alongside the target. Dense
    leaves the tiers share BY REFERENCE (embed, norms - how
    ``spec.draft_serving`` builds them) are stored ONCE; the checkpoint
    spec dedupes identical leaf objects.
    """
    meta = {"schema": ARTIFACT_SCHEMA, "arch": cfg.name,
            "family": cfg.family, "n_layers": cfg.n_layers,
            "tiles": [list(t) for t in packed_tiles(sp)],
            **(extra or {})}
    clean = _strip_placement(sp)
    if draft is None:
        return ckpt.save_pytree(path, clean, extra=meta)
    meta["two_tier"] = True
    tree = {"target": clean, "draft": _strip_placement(draft)}
    return ckpt.save_pytree(path, tree, extra=meta)


def _rebuild_tied_head(sp: ServingParams) -> ServingParams:
    if sp.head is None and sp.head_t is None:
        sp.head_t = jnp.asarray(sp.embed).T
    return sp


def load_artifact_tiers(path: str, *, arch: Optional[str] = None,
                        tile: Optional[Tuple[int, int]] = None
                        ) -> Tuple[ServingParams,
                                   Optional[ServingParams], dict]:
    """Boot EVERY tier of a serving artifact from ONE deserialization pass.

    Returns (target, draft-or-None, manifest-extra). This is the
    speculative-serving boot path: loading the two-tier tree once keeps
    the dense leaves the tiers share deduped IN MEMORY too (the draft's
    embed/norm leaves are the same loaded arrays as the target's), where
    two separate :func:`load_artifact` calls would materialize the whole
    artifact twice.

    ``arch`` / ``tile`` are expectations checked by
    :func:`validate_artifact` against the MANIFEST (before any array
    deserialization), so a mismatched artifact fails with its path and
    the expected-vs-found fields instead of deep inside ``stack()``."""
    probe = load_artifact_extra(path)
    if probe:
        validate_artifact(path, probe, arch=arch, tile=tile)
    tree, manifest = ckpt.load_pytree(path)
    extra = manifest.get("extra", manifest)
    if isinstance(tree, ServingParams):
        return _rebuild_tied_head(tree), None, extra
    if isinstance(tree, dict) and "target" in tree:
        draft = tree.get("draft")
        return (_rebuild_tied_head(tree["target"]),
                _rebuild_tied_head(draft) if draft is not None else None,
                extra)
    raise TypeError(f"{path}: artifact does not contain ServingParams")


def load_artifact(path: str, tier: str = "target", *,
                  arch: Optional[str] = None,
                  tile: Optional[Tuple[int, int]] = None
                  ) -> Tuple[ServingParams, dict]:
    """Boot a ServingParams from :func:`save_artifact` output WITHOUT
    re-running search/quantize/prune/pack. Returns (sp, manifest-extra).
    The tied-head cache is recomputed; re-shard with :func:`shard` if a
    macro mesh is wanted.

    ``tier`` selects the packing of a two-tier (speculative) artifact:
    ``"target"`` (also the whole content of a single-tier artifact) or
    ``"draft"`` (raises on artifacts saved without one). To boot BOTH
    tiers, use :func:`load_artifact_tiers` - one deserialization pass
    instead of two. ``arch`` / ``tile`` gate the manifest first (see
    :func:`validate_artifact`)."""
    target, draft, extra = load_artifact_tiers(path, arch=arch, tile=tile)
    if tier == "target":
        return target, extra
    if tier == "draft":
        if draft is None:
            raise ValueError(
                f"{path}: artifact has no draft packing - re-save with "
                "save_artifact(..., draft=...) for speculative serving")
        return draft, extra
    raise ValueError(f"{path}: unknown tier {tier!r}")


def load_artifact_extra(path: str) -> dict:
    """Read ONLY the manifest-extra of a serving artifact (no array
    deserialization). Returns {} when the artifact does not exist - this
    is the cheap pre-boot probe for manifest-carried state (autotune
    cache, spec calibration)."""
    step = ckpt.latest_step(path)
    if step is None:
        return {}
    with open(os.path.join(path, f"step_{step:08d}", "manifest.json")) as f:
        manifest = json.load(f)
    return manifest.get("extra", {}) or {}


def update_artifact_extra(path: str, updates: dict) -> None:
    """Merge ``updates`` into an existing artifact's manifest-extra WITHOUT
    re-serializing the weight tree. This is how post-serve measurements
    (the spec-acceptance calibration) persist next to the packing they
    measured: the manifest is rewritten atomically, arrays untouched."""
    step = ckpt.latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no artifact at {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    manifest.setdefault("extra", {}).update(updates)
    tmp = os.path.join(d, ".manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(d, "manifest.json"))


# ---------------------------------------------------------------------------
# Forward paths: the LOOP runtime (python loop over per-layer weights).
# ``serve.stacked`` is the compiled lax.scan form over the uniform envelope;
# it must reproduce these functions' tokens bit-exactly.
# ---------------------------------------------------------------------------


def _layer_window_theta(cfg: ModelConfig) -> Tuple[list, list]:
    """Static per-layer (window, rope_theta) - mirrors
    ``transformer._layer_kind_arrays`` but as python values for the loop."""
    kinds = cfg.layer_kinds()
    windows = [cfg.window if k == 1 else 0 for k in kinds]
    if cfg.local_global_ratio > 0:
        thetas = [cfg.rope_theta if k == 1 else 1e6 for k in kinds]
    else:
        thetas = [cfg.rope_theta] * cfg.n_layers
    return windows, thetas


def _embed_inputs(sp: ServingParams, batch: dict, cfg: ModelConfig):
    return transformer._embed_inputs(
        {"embed": sp.embed, "mm_proj": sp.mm_proj}, batch, cfg)


def _head(sp: ServingParams):
    """Output head: explicit, or the build-time transposed tied embedding
    (never re-materialized per call)."""
    if sp.head is not None:
        return sp.head
    return sp.head_t if sp.head_t is not None else sp.embed.T


def prefill_hidden(sp: ServingParams, batch: dict, cfg: ModelConfig):
    """Full-sequence forward. Returns (hidden (B,S,D), cache k/v
    (L,B,S,KV,dh)) - the same math as ``transformer.forward_hidden`` for the
    dense/moe/vlm families, but layer-by-layer so projection leaves may be
    DeployedWeight."""
    x = _embed_inputs(sp, batch, cfg)
    _, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    windows, thetas = _layer_window_theta(cfg)
    ks, vs = [], []
    for i, p in enumerate(sp.layers):
        x, _, (k, v) = transformer._attn_mlp_body(
            p, x, cfg, windows[i], thetas[i], positions)
        ks.append(k)
        vs.append(v)
    x = L.rmsnorm(x, sp.final_ln)
    return x, {"k": jnp.stack(ks), "v": jnp.stack(vs)}


def prefill(sp: ServingParams, batch: dict, cfg: ModelConfig):
    """Registry-signature prefill: (last-position logits, cache w/ 'pos')."""
    hidden, cache = prefill_hidden(sp, batch, cfg)
    logits = L.logits_out(_head(sp), hidden[:, -1:, :], cfg.cim)[:, 0, : cfg.vocab]
    total = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        total += batch["patch_embeds"].shape[1]
    cache["pos"] = jnp.asarray(total, jnp.int32)
    return logits, cache


def prefill_last(sp: ServingParams, tokens: jnp.ndarray, true_len: jnp.ndarray,
                 cfg: ModelConfig):
    """Prefill for the batch server: ``tokens`` (B, S_pad) may be padded past
    the prompt; logits are taken at ``true_len - 1``. Causality guarantees
    the pad positions cannot influence them, and their (garbage) cache
    entries sit at positions >= true_len, which decode overwrites before it
    ever attends to them."""
    hidden, cache = prefill_hidden(sp, {"tokens": tokens}, cfg)
    h_last = jnp.take(hidden, jnp.asarray(true_len - 1, jnp.int32), axis=1)
    logits = L.logits_out(_head(sp), h_last[:, None, :], cfg.cim)[:, 0, : cfg.vocab]
    return logits, cache["k"], cache["v"]


def _mlp(p: dict, h, cfg: ModelConfig):
    if cfg.family == "moe":
        y, _ = L.moe_block(p, h, cfg)
        return y
    return L.gated_mlp(p, h, cfg.cim)


def decode_step(sp: ServingParams, cache: dict, tokens: jnp.ndarray,
                cfg: ModelConfig):
    """Registry-signature decode: contiguous per-batch cache, scalar pos.
    Math-identical to ``transformer.decode_step`` (dense branch)."""
    x = L.embed(sp.embed, tokens, cfg.param_dtype)
    pos = cache["pos"]
    windows, thetas = _layer_window_theta(cfg)
    ks, vs = [], []
    for i, p in enumerate(sp.layers):
        cfg_l = transformer._with_theta(cfg, thetas[i])
        h = L.rmsnorm(x, p["ln1"])
        attn, kc, vc = L.decode_attention(p, h, cache["k"][i], cache["v"][i],
                                          pos, cfg_l, window=windows[i])
        x = x + attn
        h = L.rmsnorm(x, p["ln2"])
        x = x + _mlp(p, h, cfg)
        ks.append(kc)
        vs.append(vc)
    new_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs), "pos": pos + 1}
    x = L.rmsnorm(x, sp.final_ln)
    logits = L.logits_out(_head(sp), x, cfg.cim)[:, 0, : cfg.vocab]
    return logits, new_cache


def decode_step_paged(sp: ServingParams, views_k: jnp.ndarray,
                      views_v: jnp.ndarray, pos: jnp.ndarray,
                      tokens: jnp.ndarray, cfg: ModelConfig):
    """One continuous-batching decode step over a gathered paged-KV view.

    views_k/views_v: (L, B, Sv, KV, dh) gathered blocks (logical positions
    0..Sv-1 per slot); pos: (B,) per-slot absolute positions; tokens: (B, 1).
    Returns (logits (B, V), k_new (L, B, KV, dh), v_new) - the new entries
    are written back into the block pool by the caller.
    """
    x = L.embed(sp.embed, tokens, cfg.param_dtype)
    windows, thetas = _layer_window_theta(cfg)
    ks, vs = [], []
    for i, p in enumerate(sp.layers):
        cfg_l = transformer._with_theta(cfg, thetas[i])
        h = L.rmsnorm(x, p["ln1"])
        attn, kn, vn = L.decode_attention_multi(
            p, h, views_k[i], views_v[i], pos, cfg_l, window=windows[i])
        x = x + attn
        h = L.rmsnorm(x, p["ln2"])
        x = x + _mlp(p, h, cfg)
        ks.append(kn[:, 0])
        vs.append(vn[:, 0])
    x = L.rmsnorm(x, sp.final_ln)
    logits = L.logits_out(_head(sp), x, cfg.cim)[:, 0, : cfg.vocab]
    return logits, jnp.stack(ks), jnp.stack(vs)


def _mlp_tokenwise(p: dict, h, cfg: ModelConfig):
    """MLP over (B, T, D) with SEQUENTIAL-DECODE semantics per token.

    The dense-family MLP is position-independent, but ``moe_block`` routes
    with a capacity computed from the sequence length - a T-token pass
    would share capacity across the T tokens and could drop a (token,
    expert) pair that a one-token decode step keeps. Folding T into the
    batch axis gives every token the exact s=1 routing the sequential
    decode steps use, which is what the verify pass's bit-exactness
    contract requires."""
    if cfg.family != "moe":
        return _mlp(p, h, cfg)
    b, t, d = h.shape
    return _mlp(p, h.reshape(b * t, 1, d), cfg).reshape(b, t, d)


def verify_step(sp: ServingParams, views_k: jnp.ndarray,
                views_v: jnp.ndarray, pos: jnp.ndarray, tokens: jnp.ndarray,
                cfg: ModelConfig):
    """Batched multi-token pass over gathered paged views (loop runtime).

    ``tokens`` (B, T) are row b's next T input tokens at absolute positions
    ``pos[b] .. pos[b]+T-1``. Position ``t``'s logits are BIT-IDENTICAL to
    what T sequential :func:`decode_step_paged` calls would produce after
    consuming ``tokens[:, :t+1]`` - every op is row/position-independent
    and masked view padding is numerically inert. The mirror of
    ``serve.stacked.verify_step`` for per-layer (non-stacked) weights;
    the suffix-prefill path after a prefix-cache hit runs the unshared
    prompt span through this in one pass instead of T decode steps.

    Returns (logits (B, T, V), k_new (L, B, T, KV, dh), v_new)."""
    x = L.embed(sp.embed, tokens, cfg.param_dtype)  # (B, T, D)
    windows, thetas = _layer_window_theta(cfg)
    ks, vs = [], []
    for i, p in enumerate(sp.layers):
        cfg_l = transformer._with_theta(cfg, thetas[i])
        h = L.rmsnorm(x, p["ln1"])
        attn, kn, vn = L.decode_attention_multi(
            p, h, views_k[i], views_v[i], pos, cfg_l, window=windows[i])
        x = x + attn
        h = L.rmsnorm(x, p["ln2"])
        x = x + _mlp_tokenwise(p, h, cfg)
        ks.append(kn)
        vs.append(vn)
    x = L.rmsnorm(x, sp.final_ln)
    logits = L.logits_out(_head(sp), x, cfg.cim)[..., : cfg.vocab]
    return logits, jnp.stack(ks), jnp.stack(vs)


def model_fns(cfg: ModelConfig) -> registry.ModelFns:
    """ModelFns whose prefill/decode consume a :class:`ServingParams` in
    place of raw params - plug into ``serve.Engine`` via its ``fns`` arg to
    serve compressed (or unstacked dense) weights."""
    _check_family(cfg)

    def _no_init(*a, **k):
        raise NotImplementedError(
            "ServingParams are built from trained params via "
            "serve.deployed.from_params/compress, not initialized")

    return registry.ModelFns(
        init_params=_no_init,
        train_loss=_no_init,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=transformer.init_cache,
    )
