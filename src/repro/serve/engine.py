"""Static-batch serving engine: prefill + decode with KV/state caches.

Serves any registry architecture. Greedy or temperature sampling, per-
sequence EOS tracking (a finished row keeps decoding pad tokens but its
output is frozen), bounded max_len. The decode jit donates the cache so
each step updates it in place rather than copying max_len of KV per token.
Pass ``fns=serve.deployed.model_fns(cfg)`` (with ``ServingParams`` as
``params``) to serve BSR-compressed weights through the same loop. For
request-level continuous batching see ``serve.server.BatchServer``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import registry, transformer
from ..models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = -1  # -1 => never stop early
    seed: int = 0


def sample_tokens(logits: jnp.ndarray, key, scfg: ServeConfig) -> jnp.ndarray:
    """(B, V) logits -> (B,) int32 tokens: greedy at temperature<=0, else
    temperature-scaled categorical. Shared by Engine and BatchServer; note
    the two engines only produce identical tokens under GREEDY decoding -
    with temperature>0 their PRNG key schedules differ (per-batch-step vs
    per-slot/admission splits)."""
    if scfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    tok = jax.random.categorical(key, logits / scfg.temperature, axis=-1)
    return tok.astype(jnp.int32)


class Engine:
    def __init__(self, cfg: ModelConfig, params,
                 scfg: Optional[ServeConfig] = None,
                 fns: Optional[registry.ModelFns] = None):
        """``params`` is whatever ``fns`` consumes: raw registry params by
        default, or a ``serve.deployed.ServingParams`` when paired with
        ``deployed.model_fns(cfg)`` (compressed/BSR serving). ``scfg``
        defaults to a fresh ServeConfig per engine (a shared default
        instance would leak config edits across engines)."""
        self.cfg = cfg
        self.params = params
        self.scfg = scfg if scfg is not None else ServeConfig()
        self.fns = fns if fns is not None else registry.model_fns(cfg)
        self._prefill = jax.jit(self.fns.prefill, static_argnames=("cfg",))
        # donate the cache: each decode step updates it in place instead of
        # allocating a fresh max_len-sized copy per token
        self._decode = jax.jit(self.fns.decode_step, static_argnames=("cfg",),
                               donate_argnums=(1,))

    def generate(self, batch: dict, max_new_tokens: Optional[int] = None) -> np.ndarray:
        """batch: tokens (B, S) [+ patch_embeds / frames]. Returns
        (B, max_new_tokens) generated ids."""
        scfg = self.scfg
        n_new = max_new_tokens or scfg.max_new_tokens
        B, S = batch["tokens"].shape
        total = S + n_new
        if self.cfg.family == "vlm":
            total += batch["patch_embeds"].shape[1]

        logits, cache = self._prefill(self.params, batch, cfg=self.cfg)
        if self.cfg.family == "encdec":
            dcache = self.fns.init_cache(self.cfg, B, max_len=total)
            dcache["xk"], dcache["xv"] = cache["xk"], cache["xv"]
            # replay self-attn cache from prefill (same layout, pad seq axis)
            pads = [(0, 0), (0, 0), (0, total - cache["k"].shape[2]), (0, 0), (0, 0)]
            dcache["k"] = jnp.pad(cache["k"], pads)
            dcache["v"] = jnp.pad(cache["v"], pads)
            dcache["pos"] = cache["pos"]
            cache = dcache
        elif self.cfg.family in ("dense", "moe", "vlm"):
            cache = transformer.pad_cache(cache, total)
        elif self.cfg.family == "hybrid":
            # shared-attn KV is a ring buffer bounded by the window
            kv_len = min(total, self.cfg.window) if self.cfg.window else total
            cache = transformer.pad_cache(cache, kv_len)

        key = jax.random.PRNGKey(scfg.seed)
        out = np.zeros((B, n_new), np.int32)
        done = np.zeros((B,), bool)
        tok = self._sample(logits, key)
        for t in range(n_new):
            out[:, t] = np.where(done, 0, np.asarray(tok)[:, 0])
            done |= np.asarray(tok)[:, 0] == scfg.eos_id
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, jnp.asarray(tok), cfg=self.cfg)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return out

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        return sample_tokens(logits, key, self.scfg)[:, None]
