"""Batched serving engine: prefill + decode with KV/state caches.

Serves any registry architecture. Greedy or temperature sampling, per-
sequence EOS tracking (a finished row keeps decoding pad tokens but its
output is frozen), bounded max_len. The pjit shardings for multi-chip
serving come from launch.shardings; on CPU this runs eagerly jitted.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import registry, transformer
from ..models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = -1  # -1 => never stop early
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.fns = registry.model_fns(cfg)
        self._prefill = jax.jit(self.fns.prefill, static_argnames=("cfg",))
        self._decode = jax.jit(self.fns.decode_step, static_argnames=("cfg",))

    def generate(self, batch: dict, max_new_tokens: Optional[int] = None) -> np.ndarray:
        """batch: tokens (B, S) [+ patch_embeds / frames]. Returns
        (B, max_new_tokens) generated ids."""
        scfg = self.scfg
        n_new = max_new_tokens or scfg.max_new_tokens
        B, S = batch["tokens"].shape
        total = S + n_new
        if self.cfg.family == "vlm":
            total += batch["patch_embeds"].shape[1]

        logits, cache = self._prefill(self.params, batch, cfg=self.cfg)
        if self.cfg.family == "encdec":
            dcache = self.fns.init_cache(self.cfg, B, max_len=total)
            dcache["xk"], dcache["xv"] = cache["xk"], cache["xv"]
            # replay self-attn cache from prefill (same layout, pad seq axis)
            pads = [(0, 0), (0, 0), (0, total - cache["k"].shape[2]), (0, 0), (0, 0)]
            dcache["k"] = jnp.pad(cache["k"], pads)
            dcache["v"] = jnp.pad(cache["v"], pads)
            dcache["pos"] = cache["pos"]
            cache = dcache
        elif self.cfg.family in ("dense", "moe", "vlm"):
            cache = transformer.pad_cache(cache, total)
        elif self.cfg.family == "hybrid":
            # shared-attn KV is a ring buffer bounded by the window
            kv_len = min(total, self.cfg.window) if self.cfg.window else total
            cache = transformer.pad_cache(cache, kv_len)

        key = jax.random.PRNGKey(scfg.seed)
        out = np.zeros((B, n_new), np.int32)
        done = np.zeros((B,), bool)
        tok = self._sample(logits, key)
        for t in range(n_new):
            out[:, t] = np.where(done, 0, np.asarray(tok)[:, 0])
            done |= np.asarray(tok)[:, 0] == scfg.eos_id
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, jnp.asarray(tok), cfg=self.cfg)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return out

    def _sample(self, logits: jnp.ndarray, key) -> jnp.ndarray:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        probs_logits = logits / self.scfg.temperature
        tok = jax.random.categorical(key, probs_logits, axis=-1)
        return tok[:, None].astype(jnp.int32)
