"""Request-level batching state: queue, slots, and a paged KV cache.

The pieces the continuous-batching server composes:

  * :class:`Request` / :class:`RequestQueue` - arrival-time-ordered intake.
  * :class:`Slot` - one occupied batch lane: position, pending input token,
    output buffer, timing marks (TTFT / per-token latency).
  * :class:`PagedKVCache` - a block pool with a free list. KV for every
    slot lives in fixed-size blocks indexed by a per-slot block table, so a
    mixed-length batch holds exactly the blocks its sequences need instead
    of ``n_slots * max_len`` of padding, and blocks freed by a finished
    request are immediately reusable by the next admission. This is the
    serving-side analogue of the macro free-list the MARS allocator manages:
    storage is granted at a fixed quantum and recycled wave by wave.

Physical block 0 is reserved as scratch: idle batch lanes read and write it
so every decode step keeps a fixed shape, and its contents are never
attended by a live slot.

Block lifecycle contract (load-bearing for prefix sharing, see
``serve.prefix``):

  * every non-scratch block carries a REFCOUNT. ``_alloc`` grants a block
    at refcount 1; ``retain``/``release`` move it; a block whose refcount
    hits 0 is SCRUBBED (zeroed, or NaN-poisoned under ``debug_poison``)
    and returned to the LIFO free list - a reused block can never leak the
    previous request's K/V into the next slot's gathered view.
  * the same physical block may appear in several slot tables (and in the
    prefix trie) - that is what a prefix-cache hit adopts. Accounting
    (``blocks_in_use``, ``peak_blocks``, the ``kv_utilization`` gauge)
    counts PHYSICAL live blocks, so shared blocks are never double-counted:
    ``free_blocks + blocks_in_use == n_blocks - 1`` always.
  * every write path (``write_prefill`` / ``write_token`` / ``write_run``)
    is copy-on-write: a write landing in a block with refcount > 1 first
    copies the block (ALL tiers - the tiers share one refcount ledger) into
    a fresh allocation and repoints only the writer's table entry.
  * ``ensure`` is all-or-nothing: on pool exhaustion it raises WITHOUT
    growing the table, so a caller that catches the error and requeues the
    request leaks nothing.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.cim_bsr_matmul import MACRO_AXIS
from ..models.config import ModelConfig


def kv_view_spec(cfg: ModelConfig, mesh: Mesh) -> Optional[P]:
    """PartitionSpec for the gathered paged-KV views (L, B, Sv, KV, dh):
    heads over the ``macro`` axis when the KV-head count divides it, else
    None (serve replicated - correctness first). The single source of truth
    for whether macro serving shards KV."""
    if MACRO_AXIS not in mesh.axis_names:
        return None
    n_dev = int(mesh.shape[MACRO_AXIS])
    if n_dev > 1 and cfg.n_kv_heads_eff % n_dev == 0:
        return P(None, None, None, MACRO_AXIS, None)
    return None


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is seconds relative to the start
    of the serve loop (0 = already waiting).

    ``priority`` orders READY requests (higher admits first; equal
    priorities keep strict FIFO). ``deadline`` is an absolute trace-clock
    second past which serving the request is pointless (the gateway sheds
    it instead of admitting); ``tenant`` attributes the request to a
    gateway tenant ("" = single-tenant serving)."""

    rid: str
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0
    priority: int = 0
    deadline: Optional[float] = None
    tenant: str = ""

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"{self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"{self.rid}: max_new_tokens must be >= 1")
        if self.deadline is not None and self.deadline < self.arrival:
            raise ValueError(f"{self.rid}: deadline {self.deadline} before "
                             f"arrival {self.arrival}")


class RequestQueue:
    """Two-stage intake: a time heap for not-yet-arrived requests and a
    priority heap for ready ones.

    ``pop_ready(now)`` first promotes every request whose arrival has
    passed, then pops the highest-priority ready request; equal priorities
    break ties by admission order (stable FIFO - the seed behavior when
    every priority is 0). ``requeue`` returns a popped-but-unadmitted
    request to the FRONT of its priority class so smaller peers can never
    leapfrog it forever.

    ``max_pending`` bounds the TOTAL queued count: an overflowing push
    evicts the lowest-priority / newest request (possibly the incoming one)
    and RETURNS it instead of silently dropping, incrementing ``n_shed`` -
    the gateway mirrors that into its ``gateway_shed_total`` counter."""

    def __init__(self, requests: Optional[List[Request]] = None,
                 max_pending: Optional[int] = None):
        self._arrivals: list = []  # (arrival, seq, req)
        self._ready: list = []     # (-priority, seq, req)
        self._seq = 0
        self._front = -1
        self.max_pending = max_pending
        self.n_shed = 0
        for r in requests or []:
            self.push(r)

    def push(self, req: Request) -> Optional[Request]:
        """Queue a request; returns the request SHED by an overflowing
        push (None when everything fits)."""
        shed = None
        if self.max_pending is not None and len(self) >= self.max_pending:
            shed = self._evict_for(req)
            if shed is req:
                self.n_shed += 1
                return shed
        heapq.heappush(self._arrivals, (req.arrival, self._seq, req))
        self._seq += 1
        if shed is not None:
            self.n_shed += 1
        return shed

    def _evict_for(self, incoming: Request) -> Request:
        """Pick the overflow victim: lowest priority first, newest within a
        priority class (front-of-cohort requeues carry negative seq and are
        therefore the oldest, i.e. the most protected)."""
        victim_key, victim = (incoming.priority, -self._seq), incoming
        for heap in (self._arrivals, self._ready):
            for _, seq, req in heap:
                key = (req.priority, -seq)
                if key < victim_key:
                    victim_key, victim = key, req
        if victim is not incoming:
            for heap in (self._arrivals, self._ready):
                for i, entry in enumerate(heap):
                    if entry[2] is victim:
                        heap[i] = heap[-1]
                        heap.pop()
                        heapq.heapify(heap)
                        return victim
        return victim

    def requeue(self, req: Request) -> None:
        """Return a popped-but-unadmitted request to the FRONT of its
        priority class (a plain push would hand it a fresh sequence number
        and let smaller same-arrival peers leapfrog it forever)."""
        heapq.heappush(self._ready, (-req.priority, self._front, req))
        self._front -= 1

    def _promote(self, now: float) -> None:
        while self._arrivals and self._arrivals[0][0] <= now:
            _, seq, req = heapq.heappop(self._arrivals)
            heapq.heappush(self._ready, (-req.priority, seq, req))

    def pop_ready(self, now: float) -> Optional[Request]:
        self._promote(now)
        if self._ready:
            return heapq.heappop(self._ready)[2]
        return None

    def next_arrival(self) -> Optional[float]:
        """Earliest instant at which SOME request is (or was) ready."""
        vals = []
        if self._arrivals:
            vals.append(self._arrivals[0][0])
        if self._ready:
            vals.append(min(t[2].arrival for t in self._ready))
        return min(vals) if vals else None

    def __len__(self) -> int:
        return len(self._arrivals) + len(self._ready)


@dataclasses.dataclass
class Slot:
    """Per-lane decode state while a request occupies a batch slot."""

    req: Request
    pos: int  # next KV write position == current sequence length
    next_token: int  # pending input token (last sampled)
    out: List[int]
    t_admit: float
    token_times: List[float]
    queue_wait_s: float = 0.0  # admission minus arrival (TTFT's queue share)
    prefix_tokens: int = 0  # prompt tokens adopted from the prefix cache

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.max_new_tokens

    @property
    def worst_positions(self) -> int:
        """KV positions this request can ever occupy (for reservation)."""
        return len(self.req.prompt) + self.req.max_new_tokens


class PagedKVCache:
    """Block-pooled KV storage for the dense/moe/vlm attention cache.

    pool_k / pool_v: (tiers, n_blocks, L, block_size, KV, dh). Per-slot
    block tables map logical block i -> physical block id. ``gather``
    produces the contiguous (L, B, Sv, KV, dh) view a decode step attends
    over - sized by the deepest ACTIVE slot, not by the engine's max length.

    ``tiers`` > 1 keeps SEVERAL KV pools behind ONE block layout: every
    tier shares the block tables, free list and accounting, so positions
    line up exactly across tiers. This is how speculative serving keeps a
    draft-tier cache next to the target-tier cache without duplicating any
    allocation state (tier 0 = target, tier 1 = draft).
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, n_blocks: int,
                 block_size: int, dtype=None, mesh: Optional[Mesh] = None,
                 tiers: int = 1, debug_poison: bool = False):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        if tiers < 1:
            raise ValueError("need >= 1 KV tier")
        self.cfg = cfg
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.tiers = tiers
        # macro-cluster serving: gathered views are sharded heads-wise over
        # the mesh when KV heads divide it, so each device attends only its
        # resident heads (and holds only 1/N of every block)
        self.mesh = mesh
        spec = None if mesh is None else kv_view_spec(cfg, mesh)
        self._view_sharding = (None if spec is None
                               else NamedSharding(mesh, spec))
        shape = (tiers, n_blocks, cfg.n_layers, block_size,
                 cfg.n_kv_heads_eff, cfg.dh)
        # host numpy, written IN PLACE: a functional .at[].set would copy
        # the whole pool per token, re-creating the max-len-copy cost the
        # paged layout exists to avoid
        np_dtype = np.dtype(dtype or cfg.param_dtype)
        self.pool_k = np.zeros(shape, np_dtype)
        self.pool_v = np.zeros(shape, np_dtype)
        # LIFO free list => a freed block is the first one re-granted
        self._free: List[int] = list(range(1, n_blocks))
        self.tables: List[List[int]] = [[] for _ in range(n_slots)]
        # per-block refcount: 0 = free (or scratch), 1 = exclusively owned,
        # >1 = shared (appears in several tables and/or the prefix trie)
        self.refcnt = np.zeros(n_blocks, np.int32)
        # scrub freed blocks with NaN instead of 0 (float pools only): a
        # live gather that wrongly references a freed block then poisons
        # its attention output instead of silently reading zeros
        self.debug_poison = debug_poison
        # stats
        self._ever_used: set = set()
        self.n_alloc = 0
        self.n_reused = 0
        self.n_cow = 0
        self.peak_blocks = 0

    # -- accounting ---------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        """PHYSICAL live blocks (refcount > 0): a block shared by several
        tables and/or the prefix trie counts once, never per reference."""
        return int((self.refcnt[1:] > 0).sum())

    def blocks_for(self, n_pos: int) -> int:
        return -(-n_pos // self.block_size)

    def stats(self) -> dict:
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "kv_tiers": self.tiers,
            "allocations": self.n_alloc,
            "reused_blocks": self.n_reused,
            "cow_copies": self.n_cow,
            "peak_blocks": self.peak_blocks,
            "kv_heads_sharded": self._view_sharding is not None,
        }

    # -- allocation ---------------------------------------------------------

    def _alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                "paged KV pool exhausted - admission control should have "
                "reserved worst-case blocks; raise n_blocks")
        b = self._free.pop()
        if b in self._ever_used:
            self.n_reused += 1
        self._ever_used.add(b)
        self.n_alloc += 1
        self.refcnt[b] = 1
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return b

    def retain(self, block: int) -> None:
        """Add a reference to a LIVE block (sharing it into another table
        or the prefix trie)."""
        if block <= 0 or block >= self.n_blocks or self.refcnt[block] < 1:
            raise ValueError(f"retain: block {block} is not a live block")
        self.refcnt[block] += 1

    def release(self, block: int) -> None:
        """Drop one reference; the last release scrubs the block and
        returns it to the LIFO free list."""
        if block <= 0 or block >= self.n_blocks or self.refcnt[block] < 1:
            raise ValueError(f"release: block {block} is not a live block")
        self.refcnt[block] -= 1
        if self.refcnt[block] == 0:
            self._scrub(block)
            self._free.append(block)

    def _scrub(self, block: int) -> None:
        fill = (np.nan if self.debug_poison
                and np.issubdtype(self.pool_k.dtype, np.floating) else 0)
        self.pool_k[:, block] = fill
        self.pool_v[:, block] = fill

    def adopt(self, slot: int, blocks: List[int]) -> None:
        """Append already-live shared blocks to ``slot``'s table (a
        prefix-cache hit adopting a matched chain), retaining each. Must
        precede any ``ensure`` growth so logical positions line up."""
        t = self.tables[slot]
        for b in blocks:
            self.retain(b)
            t.append(b)

    def ensure(self, slot: int, n_pos: int) -> None:
        """Grow ``slot``'s table until positions [0, n_pos) fit.

        All-or-nothing: if the pool cannot cover the WHOLE growth the call
        raises without appending anything, so a caller that catches the
        exhaustion and requeues the request leaks no blocks."""
        t = self.tables[slot]
        need = self.blocks_for(n_pos) - len(t)
        if need > len(self._free):
            raise RuntimeError(
                "paged KV pool exhausted - admission control should have "
                "reserved worst-case blocks; raise n_blocks")
        for _ in range(need):
            t.append(self._alloc())

    def free_slot(self, slot: int) -> None:
        # reversed so the slot's FIRST block lands last on the LIFO free
        # list and is therefore the first one re-granted (blocks shared
        # with other tables/the trie stay live - only this reference drops)
        for b in reversed(self.tables[slot]):
            self.release(b)
        self.tables[slot] = []

    def _ensure_owned(self, slot: int, block_idx: int) -> int:
        """Copy-on-write: make ``slot``'s logical block ``block_idx``
        exclusively owned before a write. Shared blocks are copied (every
        tier - the tiers share one refcount ledger) into a fresh
        allocation and only the writer's table entry is repointed."""
        pb = self.tables[slot][block_idx]
        if self.refcnt[pb] == 1:
            return pb
        nb = self._alloc()  # raises on exhaustion BEFORE any state moves
        self.pool_k[:, nb] = self.pool_k[:, pb]
        self.pool_v[:, nb] = self.pool_v[:, pb]
        self.tables[slot][block_idx] = nb
        self.release(pb)
        self.n_cow += 1
        return nb

    # -- data movement ------------------------------------------------------

    def write_prefill(self, slot: int, k: jnp.ndarray, v: jnp.ndarray,
                      true_len: int, tier: int = 0, start: int = 0) -> None:
        """Scatter a prefill cache (L, S_pad, KV, dh) into ``slot``'s blocks
        covering positions ``start .. start+true_len-1`` (``start`` must be
        block-aligned - the suffix-prefill path after a prefix-cache hit).
        Only the covered blocks are allocated; pad positions inside the last
        block carry garbage that decode overwrites before its mask ever
        reaches them."""
        bs = self.block_size
        if start % bs:
            raise ValueError(f"write_prefill start={start} must be a "
                             f"multiple of block_size={bs}")
        self.ensure(slot, start + true_len)
        k, v = np.asarray(k), np.asarray(v)
        b0 = start // bs
        for i in range(self.blocks_for(true_len)):
            pb = self._ensure_owned(slot, b0 + i)
            self.pool_k[tier, pb] = k[:, i * bs:(i + 1) * bs]
            self.pool_v[tier, pb] = v[:, i * bs:(i + 1) * bs]

    def view_tables(self, n_view: int,
                    slots: Optional[List[int]] = None) -> np.ndarray:
        """(len(slots), n_view) physical ids (all slots by default);
        short/idle slots pad with the scratch block (masked out by per-row
        positions)."""
        sl = list(range(self.n_slots)) if slots is None else slots
        tbl = np.zeros((len(sl), n_view), np.int32)
        for r, s in enumerate(sl):
            t = self.tables[s]
            n = min(len(t), n_view)
            tbl[r, :n] = t[:n]
        return tbl

    def gather(self, n_view: int, tier: int = 0,
               slots: Optional[List[int]] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(L, B, n_view*block_size, KV, dh) contiguous K/V views; ``slots``
        restricts B to those lanes (a cache-hit suffix pass gathers ONE)."""
        tbl = self.view_tables(n_view, slots)
        L = self.cfg.n_layers
        bs, kvh, dh = self.block_size, self.cfg.n_kv_heads_eff, self.cfg.dh

        def _g(pool):
            g = pool[tier][tbl]  # (B, n_view, L, bs, KV, dh)
            g = g.transpose(2, 0, 1, 3, 4, 5)
            out = jnp.asarray(
                g.reshape(L, tbl.shape[0], n_view * bs, kvh, dh))
            if self._view_sharding is not None:
                out = jax.device_put(out, self._view_sharding)
            return out

        return _g(self.pool_k), _g(self.pool_v)

    def write_coords(self, positions: List[Optional[int]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Physical (block, offset) per lane for a decode-step write; idle
        lanes (None) target the scratch block. Copy-on-write fires here:
        the coords returned always point at exclusively-owned blocks."""
        pb = np.zeros((self.n_slots,), np.int32)
        off = np.zeros((self.n_slots,), np.int32)
        for s, pos in enumerate(positions):
            if pos is None:
                continue
            pb[s] = self._ensure_owned(s, pos // self.block_size)
            off[s] = pos % self.block_size
        return pb, off

    def write_token(self, pb: np.ndarray, off: np.ndarray,
                    k_new: jnp.ndarray, v_new: jnp.ndarray,
                    tier: int = 0) -> None:
        """Write one decode step's K/V (L, B, KV, dh) into the pool (in
        place - only the touched (block, offset) rows move)."""
        kt = np.asarray(k_new).transpose(1, 0, 2, 3)  # (B, L, KV, dh)
        vt = np.asarray(v_new).transpose(1, 0, 2, 3)
        self.pool_k[tier][pb, :, off] = kt
        self.pool_v[tier][pb, :, off] = vt

    def write_run(self, slot: int, start: int, k_run: np.ndarray,
                  v_run: np.ndarray, tier: int = 0) -> None:
        """Commit a variable-length run of K/V entries (L, T, KV, dh) for
        ONE slot at positions ``start .. start+T-1``.

        This is the speculative accept path: the verify/draft passes
        compute k+1 candidate entries but only the accepted prefix is ever
        passed here - rejected draft KV is rolled back by simply never
        reaching the pool (the gathered views the rejects were written
        into are throwaways)."""
        bs = self.block_size
        k_run, v_run = np.asarray(k_run), np.asarray(v_run)
        n = k_run.shape[1]
        if n == 0:
            return
        for bi in range(start // bs, (start + n - 1) // bs + 1):
            self._ensure_owned(slot, bi)
        t = self.tables[slot]
        for i in range(n):
            pb = t[(start + i) // bs]
            off = (start + i) % bs
            self.pool_k[tier][pb, :, off] = k_run[:, i]
            self.pool_v[tier][pb, :, off] = v_run[:, i]
