"""Request-level batching state: queue, slots, and a paged KV cache.

The pieces the continuous-batching server composes:

  * :class:`Request` / :class:`RequestQueue` - arrival-time-ordered intake.
  * :class:`Slot` - one occupied batch lane: position, pending input token,
    output buffer, timing marks (TTFT / per-token latency).
  * :class:`PagedKVCache` - a block pool with a free list. KV for every
    slot lives in fixed-size blocks indexed by a per-slot block table, so a
    mixed-length batch holds exactly the blocks its sequences need instead
    of ``n_slots * max_len`` of padding, and blocks freed by a finished
    request are immediately reusable by the next admission. This is the
    serving-side analogue of the macro free-list the MARS allocator manages:
    storage is granted at a fixed quantum and recycled wave by wave.

Physical block 0 is reserved as scratch: idle batch lanes read and write it
so every decode step keeps a fixed shape, and its contents are never
attended by a live slot.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.cim_bsr_matmul import MACRO_AXIS
from ..models.config import ModelConfig


def kv_view_spec(cfg: ModelConfig, mesh: Mesh) -> Optional[P]:
    """PartitionSpec for the gathered paged-KV views (L, B, Sv, KV, dh):
    heads over the ``macro`` axis when the KV-head count divides it, else
    None (serve replicated - correctness first). The single source of truth
    for whether macro serving shards KV."""
    if MACRO_AXIS not in mesh.axis_names:
        return None
    n_dev = int(mesh.shape[MACRO_AXIS])
    if n_dev > 1 and cfg.n_kv_heads_eff % n_dev == 0:
        return P(None, None, None, MACRO_AXIS, None)
    return None


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is seconds relative to the start
    of the serve loop (0 = already waiting)."""

    rid: str
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"{self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"{self.rid}: max_new_tokens must be >= 1")


class RequestQueue:
    """Min-heap on (arrival, admission order)."""

    def __init__(self, requests: Optional[List[Request]] = None):
        self._heap: list = []
        self._seq = 0
        self._front = -1
        for r in requests or []:
            self.push(r)

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.arrival, self._seq, req))
        self._seq += 1

    def requeue(self, req: Request) -> None:
        """Return a popped-but-unadmitted request to the FRONT of its
        arrival cohort (a plain push would hand it a fresh sequence number
        and let smaller same-arrival peers leapfrog it forever)."""
        heapq.heappush(self._heap, (req.arrival, self._front, req))
        self._front -= 1

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._heap and self._heap[0][0] <= now:
            return heapq.heappop(self._heap)[2]
        return None

    def next_arrival(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)


@dataclasses.dataclass
class Slot:
    """Per-lane decode state while a request occupies a batch slot."""

    req: Request
    pos: int  # next KV write position == current sequence length
    next_token: int  # pending input token (last sampled)
    out: List[int]
    t_admit: float
    token_times: List[float]
    queue_wait_s: float = 0.0  # admission minus arrival (TTFT's queue share)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.max_new_tokens

    @property
    def worst_positions(self) -> int:
        """KV positions this request can ever occupy (for reservation)."""
        return len(self.req.prompt) + self.req.max_new_tokens


class PagedKVCache:
    """Block-pooled KV storage for the dense/moe/vlm attention cache.

    pool_k / pool_v: (tiers, n_blocks, L, block_size, KV, dh). Per-slot
    block tables map logical block i -> physical block id. ``gather``
    produces the contiguous (L, B, Sv, KV, dh) view a decode step attends
    over - sized by the deepest ACTIVE slot, not by the engine's max length.

    ``tiers`` > 1 keeps SEVERAL KV pools behind ONE block layout: every
    tier shares the block tables, free list and accounting, so positions
    line up exactly across tiers. This is how speculative serving keeps a
    draft-tier cache next to the target-tier cache without duplicating any
    allocation state (tier 0 = target, tier 1 = draft).
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, n_blocks: int,
                 block_size: int, dtype=None, mesh: Optional[Mesh] = None,
                 tiers: int = 1):
        if n_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        if tiers < 1:
            raise ValueError("need >= 1 KV tier")
        self.cfg = cfg
        self.n_slots = n_slots
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.tiers = tiers
        # macro-cluster serving: gathered views are sharded heads-wise over
        # the mesh when KV heads divide it, so each device attends only its
        # resident heads (and holds only 1/N of every block)
        self.mesh = mesh
        spec = None if mesh is None else kv_view_spec(cfg, mesh)
        self._view_sharding = (None if spec is None
                               else NamedSharding(mesh, spec))
        shape = (tiers, n_blocks, cfg.n_layers, block_size,
                 cfg.n_kv_heads_eff, cfg.dh)
        # host numpy, written IN PLACE: a functional .at[].set would copy
        # the whole pool per token, re-creating the max-len-copy cost the
        # paged layout exists to avoid
        np_dtype = np.dtype(dtype or cfg.param_dtype)
        self.pool_k = np.zeros(shape, np_dtype)
        self.pool_v = np.zeros(shape, np_dtype)
        # LIFO free list => a freed block is the first one re-granted
        self._free: List[int] = list(range(1, n_blocks))
        self.tables: List[List[int]] = [[] for _ in range(n_slots)]
        # stats
        self._ever_used: set = set()
        self.n_alloc = 0
        self.n_reused = 0
        self.peak_blocks = 0

    # -- accounting ---------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return sum(len(t) for t in self.tables)

    def blocks_for(self, n_pos: int) -> int:
        return -(-n_pos // self.block_size)

    def stats(self) -> dict:
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "kv_tiers": self.tiers,
            "allocations": self.n_alloc,
            "reused_blocks": self.n_reused,
            "peak_blocks": self.peak_blocks,
            "kv_heads_sharded": self._view_sharding is not None,
        }

    # -- allocation ---------------------------------------------------------

    def _alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                "paged KV pool exhausted - admission control should have "
                "reserved worst-case blocks; raise n_blocks")
        b = self._free.pop()
        if b in self._ever_used:
            self.n_reused += 1
        self._ever_used.add(b)
        self.n_alloc += 1
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use + 1)
        return b

    def ensure(self, slot: int, n_pos: int) -> None:
        """Grow ``slot``'s table until positions [0, n_pos) fit."""
        t = self.tables[slot]
        while len(t) * self.block_size < n_pos:
            t.append(self._alloc())

    def free_slot(self, slot: int) -> None:
        self._free.extend(reversed(self.tables[slot]))
        self.tables[slot] = []

    # -- data movement ------------------------------------------------------

    def write_prefill(self, slot: int, k: jnp.ndarray, v: jnp.ndarray,
                      true_len: int, tier: int = 0) -> None:
        """Scatter a prefill cache (L, S_pad, KV, dh) into ``slot``'s blocks.
        Only ceil(true_len / block_size) blocks are allocated; pad positions
        inside the last block carry garbage that decode overwrites before
        its mask ever reaches them."""
        bs = self.block_size
        self.ensure(slot, true_len)
        k, v = np.asarray(k), np.asarray(v)
        for i, pb in enumerate(self.tables[slot]):
            self.pool_k[tier, pb] = k[:, i * bs:(i + 1) * bs]
            self.pool_v[tier, pb] = v[:, i * bs:(i + 1) * bs]

    def view_tables(self, n_view: int) -> np.ndarray:
        """(n_slots, n_view) physical ids; short/idle slots pad with the
        scratch block (masked out by per-row positions)."""
        tbl = np.zeros((self.n_slots, n_view), np.int32)
        for s, t in enumerate(self.tables):
            n = min(len(t), n_view)
            tbl[s, :n] = t[:n]
        return tbl

    def gather(self, n_view: int, tier: int = 0
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(L, B, n_view*block_size, KV, dh) contiguous K/V views."""
        tbl = self.view_tables(n_view)
        L = self.cfg.n_layers
        bs, kvh, dh = self.block_size, self.cfg.n_kv_heads_eff, self.cfg.dh

        def _g(pool):
            g = pool[tier][tbl]  # (B, n_view, L, bs, KV, dh)
            g = g.transpose(2, 0, 1, 3, 4, 5)
            out = jnp.asarray(g.reshape(L, self.n_slots, n_view * bs, kvh, dh))
            if self._view_sharding is not None:
                out = jax.device_put(out, self._view_sharding)
            return out

        return _g(self.pool_k), _g(self.pool_v)

    def write_coords(self, positions: List[Optional[int]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Physical (block, offset) per lane for a decode-step write; idle
        lanes (None) target the scratch block."""
        pb = np.zeros((self.n_slots,), np.int32)
        off = np.zeros((self.n_slots,), np.int32)
        for s, pos in enumerate(positions):
            if pos is None:
                continue
            pb[s] = self.tables[s][pos // self.block_size]
            off[s] = pos % self.block_size
        return pb, off

    def write_token(self, pb: np.ndarray, off: np.ndarray,
                    k_new: jnp.ndarray, v_new: jnp.ndarray,
                    tier: int = 0) -> None:
        """Write one decode step's K/V (L, B, KV, dh) into the pool (in
        place - only the touched (block, offset) rows move)."""
        kt = np.asarray(k_new).transpose(1, 0, 2, 3)  # (B, L, KV, dh)
        vt = np.asarray(v_new).transpose(1, 0, 2, 3)
        self.pool_k[tier][pb, :, off] = kt
        self.pool_v[tier][pb, :, off] = vt

    def write_run(self, slot: int, start: int, k_run: np.ndarray,
                  v_run: np.ndarray, tier: int = 0) -> None:
        """Commit a variable-length run of K/V entries (L, T, KV, dh) for
        ONE slot at positions ``start .. start+T-1``.

        This is the speculative accept path: the verify/draft passes
        compute k+1 candidate entries but only the accepted prefix is ever
        passed here - rejected draft KV is rolled back by simply never
        reaching the pool (the gathered views the rejects were written
        into are throwaways)."""
        t, bs = self.tables[slot], self.block_size
        k_run, v_run = np.asarray(k_run), np.asarray(v_run)
        for i in range(k_run.shape[1]):
            pb = t[(start + i) // bs]
            off = (start + i) % bs
            self.pool_k[tier][pb, :, off] = k_run[:, i]
            self.pool_v[tier][pb, :, off] = v_run[:, i]
