"""Analytic MARS accelerator model (paper §III, §V.A - Table I, Figs. 10-11).

The container has no 28 nm silicon, so - like the paper itself, whose Table I
numbers are "estimated value[s]" referring to the macro measurements of [18]
- MARS system performance is modeled analytically from the architecture:

  * 4 CIM cores x 2 macros x 8 partitions; a core computes one group-set
    (16 inputs x alpha=16 kernels) per CIM cycle -> 256 MACs/core/cycle.
  * 4-bit-native macro ([18]): 8-bit weights cost 2 cell-columns
    (w_pass=2), 8-bit activations cost 2 input passes (a_pass=2).
  * CIM @ 100 MHz, top-level system @ 400 MHz, shunter gives each core one
    FM-SRAM access per CIM cycle.
  * Zero group-sets are skipped in compute, storage and IFM fetch (§III.B).
  * Macro capacity 2 x 64 Kb/core: layers larger than residency reload.

Cycle model per conv layer (P = output pixels):
  compute = P * NNZ_groupsets * a_pass * w_pass / cores
  fm      = (ifm_reads + ofm_writes) / cores   (1 access/core/CIM-cycle)
  reload  = stored_bits / (RELOAD_BITS_PER_CYCLE * cores)
  cycles  = max(compute, fm) + reload + CTRL_OVERHEAD * P

The dense baseline (Fig. 10's "baseline") uses the same pipeline with
NNZ = all group-sets and full weight storage.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .mapping import CORES, GROUP, MACRO_BITS, MACROS_PER_CORE

ALPHA = 16
CIM_FREQ = 100e6
SYS_FREQ = 400e6
RELOAD_BITS_PER_CYCLE = 256  # weight-SRAM -> macro write port, per core
CTRL_OVERHEAD = 0.25  # controller/APW cycles per output pixel (calibrated)
# Extra-pass cost factor: the 2nd 4-bit pass (8-bit weights/activations)
# reuses resident weights + SAS addresses, so only the MAC phase repeats.
# 0.35 calibrated against Table I's w8a4 vs w8a8 FPS ratio (1.32x).
PASS_OVERLAP = 0.35
MACRO_POWER_W = 1.9e-3  # [18]: 1.9~2.7 mW @ 100 MHz; we take the low end
N_MACROS = CORES * MACROS_PER_CORE


@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    """The MARS fabric as data - shared hardware description for this
    analytic model and the event-driven simulator (``repro.sched``)."""

    cores: int = CORES
    macros_per_core: int = MACROS_PER_CORE
    partitions: int = 8  # per macro ([18])
    macro_bits: int = MACRO_BITS
    group: int = GROUP  # weights per weight-group (input direction)
    alpha: int = ALPHA  # kernels per group-set (output direction)
    cim_freq: float = CIM_FREQ
    sys_freq: float = SYS_FREQ
    reload_bits_per_cycle: int = RELOAD_BITS_PER_CYCLE
    ctrl_overhead: float = CTRL_OVERHEAD
    pass_overlap: float = PASS_OVERLAP
    macro_power_w: float = MACRO_POWER_W
    # Macro-mesh interconnect (serving path): ring all-gather at every
    # column-sharded projection boundary. Calibration knobs, not silicon:
    # bytes one device moves per CIM cycle and the per-hop launch latency.
    interconnect_bytes_per_cycle: float = 64.0
    collective_latency_cycles: float = 400.0

    @property
    def n_macros(self) -> int:
        return self.cores * self.macros_per_core

    def pass_factor(self, w_bits: int, a_bits: int) -> float:
        """Cycle multiplier for multi-pass >4-bit operands on the 4-bit macro."""
        a_pass = max(1, -(-a_bits // 4))
        w_pass = max(1, -(-w_bits // 4))
        return (1 + self.pass_overlap * (a_pass - 1)) * (
            1 + self.pass_overlap * (w_pass - 1))

    def capacity_groupsets(self, w_bits: int = 8, group: int | None = None,
                           alpha: int | None = None, macros: int = 1) -> int:
        """Group-sets resident in ``macros`` macro buffers of one core."""
        g = self.group if group is None else group
        a = self.alpha if alpha is None else alpha
        return max(1, (self.macro_bits * macros) // (g * a * w_bits))

    def reload_cycles(self, groupsets: int, w_bits: int = 8,
                      group: int | None = None, alpha: int | None = None) -> float:
        """Cycles for one core's write port to fill ``groupsets`` group-sets."""
        g = self.group if group is None else group
        a = self.alpha if alpha is None else alpha
        return groupsets * g * a * w_bits / self.reload_bits_per_cycle

    def allgather_cycles(self, n_bytes: float, n_devices: int) -> float:
        """Ring all-gather cost: each device ships its 1/n shard around the
        ring in (n-1) hops, each hop paying launch latency + wire time."""
        if n_devices <= 1 or n_bytes <= 0:
            return 0.0
        chunk = n_bytes / n_devices
        per_hop = chunk / self.interconnect_bytes_per_cycle
        return (n_devices - 1) * (per_hop + self.collective_latency_cycles)


DEFAULT_HW = HardwareConfig()


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """One conv layer: kernel (kh, kw), cin -> cout, output h x w."""

    kh: int
    kw: int
    cin: int
    cout: int
    out_h: int
    out_w: int
    sparsity_gs: float = 0.0  # fraction of group-sets that are all-zero

    @property
    def out_pixels(self) -> int:
        return self.out_h * self.out_w

    @property
    def groupsets(self) -> int:
        return self.groupsets_for(GROUP, ALPHA)

    @property
    def nnz_groupsets(self) -> int:
        return self.nnz_for(GROUP, ALPHA)

    def groupsets_for(self, group: int, alpha: int) -> int:
        """Group-set count under an alternative (group x alpha) tiling."""
        wg_per_kernel = self.kh * self.kw * -(-self.cin // group)
        return wg_per_kernel * -(-self.cout // alpha)

    def zero_fraction_for(self, group: int, alpha: int) -> float:
        """Zero-group-set fraction rescaled from the (GROUP x ALPHA) profile.

        ``sparsity_gs`` is measured at the paper's 16x16 tiles; a coarser
        tile is zero only when all covered 16x16 tiles are, a finer one is
        zero at least as often - modeled as p**(area ratio) (independent
        tiles), the same scaling CIM-Tuner-style searches assume.
        """
        if self.sparsity_gs <= 0.0:
            return 0.0
        ratio = (group * alpha) / float(GROUP * ALPHA)
        return min(1.0, float(self.sparsity_gs) ** ratio)

    def nnz_for(self, group: int, alpha: int) -> int:
        total = self.groupsets_for(group, alpha)
        keep = 1.0 - self.zero_fraction_for(group, alpha)
        return max(1, int(round(total * keep)))

    @property
    def macs(self) -> int:
        return self.out_pixels * self.kh * self.kw * self.cin * self.cout


@dataclasses.dataclass
class LayerPerf:
    name: str
    cycles_dense: float
    cycles_mars: float
    fm_access_dense: float
    fm_access_mars: float

    @property
    def speedup(self) -> float:
        return self.cycles_dense / max(self.cycles_mars, 1e-9)

    @property
    def fm_reduction(self) -> float:
        return self.fm_access_dense / max(self.fm_access_mars, 1e-9)


def _phase_cycles(l: ConvLayer, nnz: int, total_gs: int, w_bits: int,
                  a_bits: int, sparse_fetch: bool,
                  hw: HardwareConfig = DEFAULT_HW) -> dict:
    """Per-phase cycle components of one layer - the model's side of the
    sim-vs-measured comparison (``repro.obs.gap``)."""
    pass_f = hw.pass_factor(w_bits, a_bits)
    compute = l.out_pixels * nnz * pass_f / hw.cores
    # IFM: one group-wide fetch per (pixel, surviving group-set); OFM: one
    # partial-sum write per (pixel, kernel-group) - zero rows still skipped
    # only on the sparse path.
    fetch_gs = nnz if sparse_fetch else total_gs
    ifm = l.out_pixels * fetch_gs
    ofm = l.out_pixels * -(-l.cout // hw.alpha)
    fm_cycles = (ifm + ofm) / hw.cores
    stored_bits = fetch_gs * hw.group * hw.alpha * w_bits
    reload = stored_bits / (hw.reload_bits_per_cycle * hw.cores)
    ctrl = hw.ctrl_overhead * l.out_pixels
    return {"compute": compute, "fm": fm_cycles, "reload": reload,
            "ctrl": ctrl, "fm_access": ifm + ofm,
            "cycles": max(compute, fm_cycles) + reload + ctrl}


def _layer_cycles(l: ConvLayer, nnz: int, total_gs: int, w_bits: int,
                  a_bits: int, sparse_fetch: bool,
                  hw: HardwareConfig = DEFAULT_HW) -> tuple[float, float]:
    p = _phase_cycles(l, nnz, total_gs, w_bits, a_bits, sparse_fetch, hw=hw)
    return p["cycles"], p["fm_access"]


def layer_phase_cycles(l: ConvLayer, w_bits: int = 8, a_bits: int = 4,
                       sparse: bool = True,
                       hw: HardwareConfig = DEFAULT_HW) -> dict:
    """{compute, fm, reload, ctrl} cycles of one layer under ``hw``'s
    tiling (MARS sparse path by default, ``sparse=False`` for the dense
    baseline)."""
    total = l.groupsets_for(hw.group, hw.alpha)
    nnz = l.nnz_for(hw.group, hw.alpha) if sparse else total
    p = _phase_cycles(l, nnz, total, w_bits, a_bits, sparse_fetch=sparse,
                      hw=hw)
    return {k: p[k] for k in ("compute", "fm", "reload", "ctrl")}


def network_phase_breakdown(layers: Sequence[ConvLayer], w_bits: int = 8,
                            a_bits: int = 4, sparse: bool = True,
                            hw: HardwareConfig = DEFAULT_HW) -> dict:
    """Network-total per-phase cycles - what the measured per-phase wall
    times from the tracer are compared against (``repro.obs.gap``)."""
    out = {"compute": 0.0, "fm": 0.0, "reload": 0.0, "ctrl": 0.0}
    for l in layers:
        for k, v in layer_phase_cycles(l, w_bits, a_bits, sparse, hw).items():
            out[k] += v
    return out


def evaluate_network(
    layers: Sequence[ConvLayer], w_bits: int = 8, a_bits: int = 4,
    hw: HardwareConfig = DEFAULT_HW,
) -> List[LayerPerf]:
    out = []
    for i, l in enumerate(layers):
        # group-set counts follow the hw tiling, so a HardwareConfig with a
        # non-default (group, alpha) stays internally consistent
        total = l.groupsets_for(hw.group, hw.alpha)
        nnz = l.nnz_for(hw.group, hw.alpha)
        cd, fmd = _layer_cycles(l, total, total, w_bits, a_bits,
                                sparse_fetch=False, hw=hw)
        cm, fmm = _layer_cycles(l, nnz, total, w_bits, a_bits,
                                sparse_fetch=True, hw=hw)
        out.append(LayerPerf(f"L{i}_{l.kh}x{l.kw}x{l.cin}x{l.cout}", cd, cm, fmd, fmm))
    return out


@dataclasses.dataclass
class NetworkPerf:
    fps: float
    fps_dense: float
    speedup: float
    avg_gops: float  # dense-equivalent ops/s (sparse-accelerator convention)
    macro_tops_w: float
    peak_macro_tops_w: float
    layers: List[LayerPerf]


def summarize(layers: Sequence[ConvLayer], w_bits: int = 8, a_bits: int = 4,
              hw: HardwareConfig = DEFAULT_HW) -> NetworkPerf:
    perf = evaluate_network(layers, w_bits, a_bits, hw=hw)
    cyc_m = sum(p.cycles_mars for p in perf)
    cyc_d = sum(p.cycles_dense for p in perf)
    fps = hw.cim_freq / cyc_m
    fps_dense = hw.cim_freq / cyc_d
    total_ops = 2.0 * sum(l.macs for l in layers)  # MAC = 2 OPS
    avg_gops = fps * total_ops / 1e9
    # Macro-level efficiency: ops attributed to macros / macro power. The
    # paper reports dense-equivalent ops (skipped zeros count), as is
    # standard for sparse accelerators.
    macro_tops_w = (fps * total_ops) / (hw.n_macros * hw.macro_power_w) / 1e12
    pass_f = hw.pass_factor(w_bits, a_bits)
    peak_dense_ops = 2 * hw.group * hw.alpha * hw.cores * hw.cim_freq / pass_f
    best_density = min(max(1e-3, 1.0 - l.sparsity_gs) for l in layers)
    peak = peak_dense_ops / best_density / (hw.n_macros * hw.macro_power_w) / 1e12
    return NetworkPerf(fps, fps_dense, cyc_d / cyc_m, avg_gops, macro_tops_w, peak, perf)


# ---------------------------------------------------------------------------
# Cost-constant re-fit: least-squares calibration against measured timings
# ---------------------------------------------------------------------------

# Per-phase cost coefficients the re-fit solves for, in order: seconds per
# MAC-path cycle (the max(compute, fm) critical path), per reload cycle, and
# per control cycle.
REFIT_COEFFS = ("mac", "reload", "ctrl")


def phase_features(phases: Dict[str, float]) -> List[float]:
    """Cycle-count feature vector of one measured sample, matching
    REFIT_COEFFS: the model says seconds = features . theta."""
    compute = float(phases.get("compute", 0.0))
    fm = float(phases.get("fm", 0.0))
    return [max(compute, fm), float(phases.get("reload", 0.0)),
            float(phases.get("ctrl", 0.0))]


@dataclasses.dataclass(frozen=True)
class RefitResult:
    """Outcome of ``fit_cycle_constants``.

    ``hw`` is the input HardwareConfig with cim_freq, reload_bits_per_cycle
    and ctrl_overhead re-derived so the analytic model reproduces the fitted
    seconds-per-cycle coefficients exactly; ``residual`` is the relative RMS
    error of the fit over its own samples - the post-refit gap floor."""

    hw: HardwareConfig
    seconds_per_cycle: Dict[str, float]
    residual: float
    n_samples: int

    def predict_seconds(self, phases: Dict[str, float]) -> float:
        f = phase_features(phases)
        return sum(c * t for c, t in zip(f, (
            self.seconds_per_cycle[k] for k in REFIT_COEFFS)))

    def to_json(self) -> dict:
        return {"seconds_per_cycle": dict(self.seconds_per_cycle),
                "residual": self.residual, "n_samples": self.n_samples,
                "cim_freq": self.hw.cim_freq,
                "reload_bits_per_cycle": self.hw.reload_bits_per_cycle,
                "ctrl_overhead": self.hw.ctrl_overhead}


def _uniform_scale_fit(A: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Degenerate fallback: one scale factor applied to every phase."""
    denom = float(A.sum())
    scale = float(y.sum()) / denom if denom > 0 else 1.0 / CIM_FREQ
    return np.full(A.shape[1], max(scale, 1e-18))


def fit_cycle_constants(samples: Sequence[Tuple[Dict[str, float], float]],
                        hw: HardwareConfig = DEFAULT_HW) -> RefitResult:
    """Least-squares re-fit of the cycle constants from measured timings.

    ``samples`` pairs a ``layer_phase_cycles``-style phase dict with the
    measured wall-clock seconds of that workload on the machine at hand.
    Solves ``seconds = max(compute, fm) * t_mac + reload * t_reload +
    ctrl * t_ctrl`` for nonnegative thetas; with fewer than 3 usable
    samples, or a singular/degenerate system, falls back to a single
    uniform scale factor so the result is always well-defined."""
    rows = [(phase_features(p), float(m)) for p, m in samples
            if np.isfinite(float(m)) and float(m) > 0
            and all(np.isfinite(v) and v >= 0 for v in phase_features(p))]
    if not rows:
        raise ValueError("fit_cycle_constants: no finite positive samples")
    A = np.asarray([f for f, _ in rows], dtype=np.float64)
    y = np.asarray([m for _, m in rows], dtype=np.float64)

    theta = None
    if len(rows) >= 3:
        active = [j for j in range(A.shape[1]) if A[:, j].max() > 0]
        # Nonnegative fit by iterative clamping: drop any coefficient the
        # unconstrained solve drives negative and re-solve the rest.
        while active:
            sol, *_ = np.linalg.lstsq(A[:, active], y, rcond=None)
            neg = [active[i] for i, c in enumerate(sol) if c < 0]
            if not neg:
                theta = np.zeros(A.shape[1])
                for i, j in enumerate(active):
                    theta[j] = sol[i]
                break
            active = [j for j in active if j not in neg]
    if theta is None or theta[0] <= 0:
        theta = _uniform_scale_fit(A, y)

    pred = A @ theta
    residual = float(np.sqrt(np.mean((pred - y) ** 2)) / max(y.mean(), 1e-18))
    t_mac, t_reload, t_ctrl = (float(t) for t in theta)
    # Fold the coefficients back into a HardwareConfig: cycles/cim_freq must
    # equal cycles * theta per phase, so frequency absorbs t_mac and the
    # other two constants are rescaled relative to it.
    hw_fit = dataclasses.replace(
        hw,
        cim_freq=1.0 / t_mac,
        reload_bits_per_cycle=(hw.reload_bits_per_cycle * t_mac / t_reload
                               if t_reload > 0 else hw.reload_bits_per_cycle),
        ctrl_overhead=hw.ctrl_overhead * t_ctrl / t_mac,
    )
    coeffs = dict(zip(REFIT_COEFFS, (t_mac, t_reload, t_ctrl)))
    return RefitResult(hw_fit, coeffs, residual, len(rows))


# ---------------------------------------------------------------------------
# Speculative decoding: two-tier (draft + target) cost model
# ---------------------------------------------------------------------------


def expected_spec_tokens(k: int, accept: float) -> float:
    """Expected tokens emitted per draft-k-verify round.

    Under per-token-independent acceptance probability ``accept``, the
    round emits the longest accepted draft prefix plus the target's
    correction token: E = sum_{t=0..k} accept^t = (1-a^{k+1})/(1-a)."""
    a = min(max(accept, 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def speculative_summary(c_draft_step: float, c_verify: float, k: int,
                        accept: float,
                        draft_steps: int | None = None) -> dict:
    """Throughput model for one speculative round on the CIM fabric.

    ``c_draft_step`` is the simulated cycle cost of ONE draft-tier decode
    step (its reload + compute at the draft sparsity, or the kept-sublayer
    fraction of a target step for the layer-skip family); ``c_verify`` the
    cost of one (k+1)-token target pass. ``draft_steps`` is how many draft
    steps a round runs: the reprune default is k+1 (k proposals + the
    trailing KV-fill step that keeps its separate draft cache in lockstep);
    the layer-skip family passes k - it has no draft cache to fill.
    ``accept`` is the modeled per-token acceptance probability - a
    calibration input, NOT simulated; the serve benchmark reports the
    measured rate to calibrate against (``sched.search.SpecCalibration``)."""
    if draft_steps is None:
        draft_steps = k + 1
    tokens = expected_spec_tokens(k, accept)
    cycles = draft_steps * c_draft_step + c_verify
    return {
        "k": k,
        "accept": round(min(max(accept, 0.0), 1.0), 4),
        "draft_steps": draft_steps,
        "tokens_per_round": round(tokens, 4),
        "cycles_per_round": round(cycles, 1),
        "tokens_per_kcycle": round(1e3 * tokens / max(cycles, 1e-9), 5),
    }


# ---------------------------------------------------------------------------
# Paper networks on CIFAR (32x32): layer tables for Table I / Figs. 10-11
# ---------------------------------------------------------------------------


def vgg16_cifar_layers(sparsity_per_layer: Sequence[float] | None = None) -> List[ConvLayer]:
    cfg = [  # (cin, cout, out_hw) - 2x2 maxpool after blocks
        (3, 64, 32), (64, 64, 32),
        (64, 128, 16), (128, 128, 16),
        (128, 256, 8), (256, 256, 8), (256, 256, 8),
        (256, 512, 4), (512, 512, 4), (512, 512, 4),
        (512, 512, 2), (512, 512, 2), (512, 512, 2),
    ]
    if sparsity_per_layer is None:
        # Table IV group-set compression rates measured by the paper
        sparsity_per_layer = [0.05, 0.05, 0.50, 0.566, 0.616, 0.932, 0.932,
                              0.978, 0.987, 0.987, 0.987, 0.987, 0.987]
    return [
        ConvLayer(3, 3, ci, co, hw, hw, s)
        for (ci, co, hw), s in zip(cfg, sparsity_per_layer)
    ]


def resnet18_cifar_layers(sparsity_per_layer: Sequence[float] | None = None) -> List[ConvLayer]:
    cfg = [(3, 64, 32)] + [(64, 64, 32)] * 4 + [(64, 128, 16)] + [(128, 128, 16)] * 3 \
        + [(128, 256, 8)] + [(256, 256, 8)] * 3 + [(256, 512, 4)] + [(512, 512, 4)] * 3
    if sparsity_per_layer is None:
        # per-layer rates are not published for ResNet18; this profile is
        # consistent with Table II's 95% overall weight sparsity (weights
        # concentrate in deep layers) and Table I's FPS
        sparsity_per_layer = [0.3] + [0.5] * 4 + [0.7] * 4 + [0.9] * 4 + [0.97] * 4
    return [
        ConvLayer(3, 3, ci, co, hw, hw, s)
        for (ci, co, hw), s in zip(cfg, sparsity_per_layer)
    ]
