"""MARS CIM-aware structured sparsity (paper §IV.A-B, eqs. 1-4).

The macro constraint: a group-set (16 weight-groups at the same relative
position across alpha=16 kernels) can be skipped only when ALL of its weights
are zero. Eq. 3 group-lassos [alpha output filters] per (channel, spatial)
position; eq. 4 additionally ties N consecutive channels so one index code
serves N group-sets (index-aware pruning).

For 2-D weights (d_in, d_out) - every linear layer in the LM zoo - the same
structure is an (N x alpha) tile: N input features x alpha output features.
Conv weights are HWIO and are handled by flattening (H, W) into positions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    alpha: int = 16  # output filters tied per group-set (BLs on per cycle)
    n: int = 16  # channels sharing one index code (eq. 4)
    lambda_g: float = 1e-4  # group-lasso strength
    lambda_l2: float = 0.0  # non-structured R(w) in eq. 1/2
    target_sparsity: float = 0.95  # pruning threshold selection


def _pad_to_multiple(w: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = w.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return w
    pads = [(0, 0)] * w.ndim
    pads[axis] = (0, pad)
    return jnp.pad(w, pads)


def tile_view(w2d: jnp.ndarray, n: int, alpha: int) -> jnp.ndarray:
    """(d_in, d_out) -> (d_in/n, d_out/alpha, n, alpha) tile view (padded)."""
    w2d = _pad_to_multiple(_pad_to_multiple(w2d, 0, n), 1, alpha)
    di, do = w2d.shape
    return w2d.reshape(di // n, n, do // alpha, alpha).transpose(0, 2, 1, 3)


def tile_norms(w2d: jnp.ndarray, n: int, alpha: int) -> jnp.ndarray:
    """L2 norm of every (n x alpha) tile -> (d_in/n, d_out/alpha)."""
    t = tile_view(w2d, n, alpha)
    return jnp.sqrt(jnp.sum(t * t, axis=(-2, -1)) + 1e-24)


def group_lasso_2d(w2d: jnp.ndarray, n: int, alpha: int) -> jnp.ndarray:
    """eq. 4 regularizer for a 2-D weight: sum of tile L2 norms.

    With n=1 this degenerates to eq. 3 (no channel sharing).
    """
    return jnp.sum(tile_norms(w2d, n, alpha))


def group_lasso_conv(w_hwio: jnp.ndarray, n: int, alpha: int) -> jnp.ndarray:
    """eq. 4 for a conv weight (H, W, I, O): groups are (N channels x alpha
    filters) at each spatial position (m, k)."""
    h, w, i, o = w_hwio.shape
    flat = w_hwio.reshape(h * w, i, o)
    norms = jax.vmap(lambda m: tile_norms(m, n, alpha))(flat)
    return jnp.sum(norms)


def regularization(params_tree, cfg: SparsityConfig) -> jnp.ndarray:
    """E(w) regularization terms of eq. 2 over a pytree of CIM weights.

    Leaves named by convention: any array with ndim==2 is treated as linear
    (d_in, d_out); ndim==4 as conv HWIO. Other leaves are skipped.
    """
    total = jnp.zeros((), jnp.float32)
    for leaf in jax.tree.leaves(params_tree):
        if not isinstance(leaf, jnp.ndarray) or not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        lf = leaf.astype(jnp.float32)
        if leaf.ndim == 2:
            total = total + cfg.lambda_g / 2.0 * group_lasso_2d(lf, cfg.n, cfg.alpha)
        elif leaf.ndim == 4:
            total = total + cfg.lambda_g / 2.0 * group_lasso_conv(lf, cfg.n, cfg.alpha)
        elif leaf.ndim == 3:  # stacked per-layer weights (scan over layers)
            total = total + cfg.lambda_g / 2.0 * jnp.sum(
                jax.vmap(lambda m: jnp.sum(tile_norms(m, cfg.n, cfg.alpha)))(lf)
            )
        if cfg.lambda_l2 > 0.0:
            total = total + cfg.lambda_l2 / 2.0 * jnp.sum(lf * lf)
    return total


# ---------------------------------------------------------------------------
# Pruning: tile-norm thresholding to the CIM-skippable structure
# ---------------------------------------------------------------------------


def prune_mask_2d(
    w2d: jnp.ndarray, n: int, alpha: int, target_sparsity: float
) -> jnp.ndarray:
    """Binary mask (same shape as w2d, un-padded) zeroing the lowest-norm
    (n x alpha) tiles until >= target_sparsity of tiles are zero.

    target_sparsity <= 0 keeps every tile (the strict ``>`` threshold would
    otherwise always drop the minimum-norm tile, making "no pruning"
    unreachable - which matters for deploy-vs-dense parity checks)."""
    if target_sparsity <= 0.0:
        return jnp.ones_like(w2d)
    norms = tile_norms(w2d, n, alpha)
    thresh = jnp.quantile(norms.reshape(-1), target_sparsity)
    keep = norms > thresh  # (di/n, do/alpha)
    mask = jnp.repeat(jnp.repeat(keep, n, axis=0), alpha, axis=1)
    return mask[: w2d.shape[0], : w2d.shape[1]].astype(w2d.dtype)


def prune_mask_conv(
    w_hwio: jnp.ndarray, n: int, alpha: int, target_sparsity: float
) -> jnp.ndarray:
    """Conv version: global threshold over all (position, tile) norms."""
    if target_sparsity <= 0.0:
        return jnp.ones_like(w_hwio)
    h, w, i, o = w_hwio.shape
    flat = w_hwio.reshape(h * w, i, o)
    norms = jax.vmap(lambda m: tile_norms(m, n, alpha))(flat)  # (hw, i/n, o/a)
    thresh = jnp.quantile(norms.reshape(-1), target_sparsity)
    keep = norms > thresh
    mask = jnp.repeat(jnp.repeat(keep, n, axis=1), alpha, axis=2)
    mask = mask[:, :i, :o].reshape(h, w, i, o)
    return mask.astype(w_hwio.dtype)


# ---------------------------------------------------------------------------
# Statistics the paper reports
# ---------------------------------------------------------------------------


def sparsity_ratio(mask: jnp.ndarray) -> jnp.ndarray:
    """Fraction of zero weights."""
    return 1.0 - jnp.mean(mask.astype(jnp.float32))


def zero_groupset_proportion(mask2d: jnp.ndarray, group: int, alpha: int) -> jnp.ndarray:
    """Fraction of (group x alpha) group-sets that are entirely zero - the
    rows the CIM macro never stores or computes ("zero-row proportion")."""
    t = tile_view(mask2d, group, alpha)
    alive = jnp.any(t > 0, axis=(-2, -1))
    return 1.0 - jnp.mean(alive.astype(jnp.float32))


def compression_rate(sparsity: float, w_bits: int) -> float:
    """Paper's Table II metric: (32 / w_bits) / (1 - sparsity)."""
    return (32.0 / float(w_bits)) / max(1.0 - float(sparsity), 1e-9)


def index_storage_bits(mask2d: jnp.ndarray, group: int, alpha: int) -> jnp.ndarray:
    """16-bit index code per surviving group-set (Fig. 6 / Table IV)."""
    t = tile_view(mask2d, group, alpha)
    alive = jnp.any(t > 0, axis=(-2, -1))
    return jnp.sum(alive.astype(jnp.int32)) * 16


def weight_storage_bits(mask2d: jnp.ndarray, group: int, alpha: int, w_bits: int):
    """Bits to store the surviving group-sets (whole tiles are kept)."""
    t = tile_view(mask2d, group, alpha)
    alive = jnp.any(t > 0, axis=(-2, -1))
    return jnp.sum(alive.astype(jnp.int32)) * group * alpha * w_bits


def apply_mask(params_tree, mask_tree):
    """Elementwise multiply; masks of None pass through."""
    return jax.tree.map(
        lambda p, m: p if m is None else p * m.astype(p.dtype),
        params_tree,
        mask_tree,
        is_leaf=lambda x: x is None,
    )
