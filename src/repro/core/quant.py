"""MARS quantization algorithms (paper §IV.C, eqs. 5-8) + DoReFa baseline.

Everything is a pure function on jnp arrays so it composes with jit/grad/
pjit. Straight-through estimators are implemented with stop_gradient.

Paper equations
---------------
eq.5  activation:  A_q = round(clamp(A, 0, 1) * (2^bA - 1)) / 2^bA
eq.6  per-group tanh normalization:  W_hat = tanh(W) / max|tanh(W)| (per group)
eq.7  BN fusion:  W_bar = clamp(gamma * W_hat / sqrt(var + eps), -1, 1)
eq.8  symmetric weight quant:  W_q = round(W_bar * (2^{b-1} - 1)) / 2^{b-1}
      (b=4 -> levels {-7..7}/8, exactly implementable on the 4-bit macro)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Bit-widths and switches for the MARS quantizer.

    w_bits/a_bits of 32 mean "leave in float" (the paper's 32/32 rows).
    ``groups`` is G in §IV.C step 1 - the number of weight groups determined
    by how many bit-lines turn on per cycle; tanh normalization (eq. 6) is
    applied per group along the *input* dimension.
    """

    w_bits: int = 8
    a_bits: int = 8
    group_size: int = 16  # G in §IV.C: BLs on per cycle (alpha of the macro)
    bn_fuse: bool = True
    a_signed: bool = False  # LM adaptation: SiLU/GELU activations are signed
    eps: float = 1e-5

    @property
    def enabled(self) -> bool:
        return self.w_bits < 32 or self.a_bits < 32


def round_ste(x: jnp.ndarray) -> jnp.ndarray:
    """round() with a straight-through gradient (identity backward)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def quantize_activation(a: jnp.ndarray, bits: int, signed: bool = False) -> jnp.ndarray:
    """eq. 5 - clamp to [0,1] then uniform quantization, STE backward.

    ``signed=True`` is the LM adaptation (SiLU/GELU produce negatives):
    clamp to [-1,1] with symmetric levels, same hardware datapath as eq. 8.
    """
    if bits >= 32:
        return a
    if signed:
        qmax = 2.0 ** (bits - 1) - 1.0
        return round_ste(jnp.clip(a, -1.0, 1.0) * qmax) / (2.0 ** (bits - 1))
    levels = 2.0**bits - 1.0
    a = jnp.clip(a, 0.0, 1.0)
    return round_ste(a * levels) / (2.0**bits)


def tanh_normalize(w: jnp.ndarray, group_size: int = 0) -> jnp.ndarray:
    """eq. 6 - per-group tanh normalization to [-1, 1].

    ``w`` has shape (..., d_in, d_out). Groups are slabs of ``group_size``
    output columns - the bit-lines that turn on together in one macro cycle
    (G in §IV.C step 1). group_size=0 normalizes globally.
    """
    t = jnp.tanh(w)
    d_out = w.shape[-1]
    if group_size <= 0 or d_out % group_size != 0 or d_out == group_size:
        denom = jnp.max(jnp.abs(t)) + 1e-12
        return t / denom
    lead = w.shape[:-1]
    tg = t.reshape(lead + (d_out // group_size, group_size))
    axes = tuple(range(len(lead))) + (len(lead) + 1,)
    denom = jnp.max(jnp.abs(tg), axis=axes, keepdims=True) + 1e-12
    return (tg / denom).reshape(w.shape)


def fuse_bn_scale(
    w_hat: jnp.ndarray,
    gamma: Optional[jnp.ndarray],
    var: Optional[jnp.ndarray],
    eps: float = 1e-5,
) -> jnp.ndarray:
    """eq. 7 - fold the BN scale gamma/sqrt(var+eps) into the weights.

    gamma/var are per-output-channel (last axis of w_hat). Passing None for
    either skips fusion (e.g. RMSNorm-folded LM layers fold their scale on
    the *input* axis instead - see fold_input_scale).
    """
    if gamma is None or var is None:
        return jnp.clip(w_hat, -1.0, 1.0)
    scale = gamma / jnp.sqrt(var + eps)
    return jnp.clip(w_hat * scale, -1.0, 1.0)


def fold_input_scale(w_hat: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Beyond-paper adaptation: fold an RMSNorm/LayerNorm gain (per input
    feature) into the weight the same way eq. 7 folds BN - so LM serving
    needs no separate high-precision elementwise multiply either."""
    return jnp.clip(w_hat * scale[..., :, None], -1.0, 1.0)


def quantize_weight_symmetric(w_bar: jnp.ndarray, bits: int) -> jnp.ndarray:
    """eq. 8 - symmetric quantization with STE. b=4 -> {-7..7}/8."""
    if bits >= 32:
        return w_bar
    qmax = 2.0 ** (bits - 1) - 1.0
    return round_ste(w_bar * qmax) / (2.0 ** (bits - 1))


def weight_int_levels(w_q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Map eq.8 output back to the integer codes the macro actually stores."""
    return jnp.round(w_q * (2.0 ** (bits - 1))).astype(jnp.int8)


def mars_weight_quant(
    w: jnp.ndarray,
    bits: int,
    group_size: int = 16,
    gamma: Optional[jnp.ndarray] = None,
    var: Optional[jnp.ndarray] = None,
    eps: float = 1e-5,
) -> jnp.ndarray:
    """Full MARS weight pipeline: eq.6 -> eq.7 -> eq.8."""
    if bits >= 32 and gamma is None:
        return w
    w_hat = tanh_normalize(w, group_size)
    w_bar = fuse_bn_scale(w_hat, gamma, var, eps)
    return quantize_weight_symmetric(w_bar, bits)


# ---------------------------------------------------------------------------
# DoReFa baseline (the paper's Table III comparison; ref [25])
# ---------------------------------------------------------------------------


def dorefa_quantize_weight(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """DoReFa-Net weight quantizer: w_q = 2*Q_k(tanh(w)/(2 max|tanh|) + 0.5) - 1."""
    if bits >= 32:
        return w
    t = jnp.tanh(w)
    t = t / (2.0 * jnp.max(jnp.abs(t)) + 1e-12) + 0.5
    levels = 2.0**bits - 1.0
    q = round_ste(t * levels) / levels
    return 2.0 * q - 1.0


def dorefa_quantize_activation(a: jnp.ndarray, bits: int) -> jnp.ndarray:
    """DoReFa activation quantizer: Q_k(clamp(a, 0, 1))."""
    if bits >= 32:
        return a
    levels = 2.0**bits - 1.0
    return round_ste(jnp.clip(a, 0.0, 1.0) * levels) / levels


# ---------------------------------------------------------------------------
# Batch-norm statistics helpers (EMA update used by eq. 7 during QAT)
# ---------------------------------------------------------------------------


def ema_update(old: jnp.ndarray, batch: jnp.ndarray, momentum: float = 0.9):
    return momentum * old + (1.0 - momentum) * batch


def batch_stats(pre_activation: jnp.ndarray):
    """Per-channel (last axis) mean/var of the conv/linear output."""
    axes = tuple(range(pre_activation.ndim - 1))
    mean = jnp.mean(pre_activation, axis=axes)
    var = jnp.var(pre_activation, axis=axes)
    return mean, var
