"""Weight -> CIM macro mapping and index-code compression (paper §III.B).

Two packers live here:

1. ``pack_groupsets`` - the paper-faithful mapping: nonzero group-sets are
   packed densely into the 64 Kb macros (Fig. 5b) and each gets a 16-bit
   index code (Fig. 6). Used by the CNN repro + the analytic perf model.

2. ``pack_bsr`` - the TPU-native adaptation: the same zero-tile-skipping
   expressed as a padded block-sparse (ELL-style) format that the Pallas
   kernel consumes - ``row_idx`` plays the role of the Index SRAM + SAS.

All functions here are host-side (numpy) - packing happens at deployment
time, not inside jit.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

# --- the adopted macro ([18], ISSCC'20 6T 64Kb): 8 partitions x 64 groups
# of 16 weights x 8b. Two macros/core -> alpha=16 kernels per cycle. ---
MACRO_BITS = 64 * 1024
PARTITIONS = 8
WLGROUPS = 64
GROUP = 16  # weights per weight-group (input/channel direction)
MACROS_PER_CORE = 2
CORES = 4


# ---------------------------------------------------------------------------
# Fig. 6 index code: [15] first-group flag | [14:9] #nonzero groups in kernel
#                    | [8:5] spatial position | [4:0] channel-group position
# ---------------------------------------------------------------------------


def encode_index(first: int, total: int, spatial: int, channel: int) -> int:
    assert 0 <= first <= 1 and 0 <= total < 64 and 0 <= spatial < 16 and 0 <= channel < 32
    return (first << 15) | (total << 9) | (spatial << 5) | channel


def decode_index(code: int) -> Tuple[int, int, int, int]:
    return (code >> 15) & 1, (code >> 9) & 0x3F, (code >> 5) & 0xF, code & 0x1F


@dataclasses.dataclass
class GroupsetPacking:
    """Result of packing one layer into the macros."""

    blocks: np.ndarray  # (nnz, GROUP, alpha) surviving group-sets
    codes: np.ndarray  # (nnz,) uint16 index codes
    spatial_pos: np.ndarray  # (nnz,) position in kernel order
    channel_pos: np.ndarray  # (nnz,) channel-group order
    n_total_groupsets: int
    capacity_groupsets: int  # how many group-sets fit in one core's macros
    reloads: int  # macro refills needed for the layer

    @property
    def nnz(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def index_bits(self) -> int:
        return self.nnz * 16

    @property
    def weight_bits_8b(self) -> int:
        return self.nnz * GROUP * self.blocks.shape[2] * 8


def pack_groupsets(w: np.ndarray, alpha: int = 16, group: int = GROUP) -> GroupsetPacking:
    """Pack a (d_in, d_out) (or HWIO conv reshaped by caller) weight.

    d_in is split into weight-groups of ``group``; d_out into kernel-groups
    of ``alpha``. A group-set = (group x alpha) tile; all-zero tiles are
    dropped (Fig. 5b) and survivors get Fig. 6 index codes.
    """
    d_in, d_out = w.shape
    gi = -(-d_in // group)
    go = -(-d_out // alpha)
    wp = np.zeros((gi * group, go * alpha), dtype=w.dtype)
    wp[:d_in, :d_out] = w
    tiles = wp.reshape(gi, group, go, alpha).transpose(0, 2, 1, 3)  # gi,go,g,a

    blocks, codes, spos, cpos = [], [], [], []
    for j in range(go):  # kernel-group = 16 kernels mapped across partitions
        alive = [i for i in range(gi) if np.any(tiles[i, j])]
        for rank, i in enumerate(alive):
            blocks.append(tiles[i, j])
            # Fig. 6 fields: spatial = position within the 3x3 kernel order,
            # channel = channel-group order. For 2-D weights spatial=0.
            codes.append(
                encode_index(int(rank == 0), min(len(alive), 63), (i // 32) % 16, i % 32)
            )
            spos.append(i)
            cpos.append(j)

    nnz = len(blocks)
    blocks_arr = (
        np.stack(blocks) if nnz else np.zeros((0, group, alpha), dtype=w.dtype)
    )
    capacity = (MACRO_BITS * MACROS_PER_CORE) // (group * alpha * 8)  # 8b weights
    reloads = max(1, -(-nnz // max(capacity, 1)))
    return GroupsetPacking(
        blocks=blocks_arr,
        codes=np.asarray(codes, dtype=np.uint16),
        spatial_pos=np.asarray(spos, dtype=np.int32),
        channel_pos=np.asarray(cpos, dtype=np.int32),
        n_total_groupsets=gi * go,
        capacity_groupsets=capacity,
        reloads=reloads,
    )


def unpack_groupsets(p: GroupsetPacking, d_in: int, d_out: int, alpha: int = 16,
                     group: int = GROUP) -> np.ndarray:
    """Inverse of pack_groupsets (for round-trip tests)."""
    gi = -(-d_in // group)
    go = -(-d_out // alpha)
    w = np.zeros((gi * group, go * alpha), dtype=p.blocks.dtype)
    for b, i, j in zip(p.blocks, p.spatial_pos, p.channel_pos):
        w[i * group : (i + 1) * group, j * alpha : (j + 1) * alpha] = b
    return w[:d_in, :d_out]


# ---------------------------------------------------------------------------
# TPU path: padded ELL/BSR format for the Pallas kernel
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BsrWeight:
    """Column-major ELL blocks: for each output block-column j, the nonzero
    input block rows (padded with 0 -> a zero block, mathematically inert).

    blocks:  (n_col_blocks, nnz_max, bk, bn)
    row_idx: (n_col_blocks, nnz_max) int32, padding entries = 0
    nnz:     (n_col_blocks,) true counts (for stats / perf model)
    """

    blocks: np.ndarray
    row_idx: np.ndarray
    nnz: np.ndarray
    bk: int
    bn: int
    d_in: int
    d_out: int

    @property
    def density(self) -> float:
        total = (self.d_in // self.bk) * (self.d_out // self.bn)
        return float(self.nnz.sum()) / max(total, 1)


def pack_bsr(w: np.ndarray, bk: int, bn: int, nnz_max: int | None = None) -> BsrWeight:
    """Pack (d_in, d_out) into the padded BSR format. d_in % bk == 0 and
    d_out % bn == 0 are required (the kernel's BlockSpecs assume it)."""
    d_in, d_out = w.shape
    assert d_in % bk == 0 and d_out % bn == 0, (d_in, bk, d_out, bn)
    gi, go = d_in // bk, d_out // bn
    tiles = w.reshape(gi, bk, go, bn).transpose(2, 0, 1, 3)  # go, gi, bk, bn
    alive = np.any(tiles.reshape(go, gi, -1) != 0, axis=-1)  # go, gi
    counts = alive.sum(axis=1)
    if nnz_max is None:
        nnz_max = max(int(counts.max(initial=0)), 1)
    blocks = np.zeros((go, nnz_max, bk, bn), dtype=w.dtype)
    row_idx = np.zeros((go, nnz_max), dtype=np.int32)
    for j in range(go):
        rows = np.nonzero(alive[j])[0][:nnz_max]
        blocks[j, : len(rows)] = tiles[j, rows]
        row_idx[j, : len(rows)] = rows
    return BsrWeight(blocks, row_idx, counts.astype(np.int32), bk, bn, d_in, d_out)


def bsr_to_dense(bw: BsrWeight) -> np.ndarray:
    w = np.zeros((bw.d_in, bw.d_out), dtype=bw.blocks.dtype)
    go = bw.d_out // bw.bn
    nnz_max = bw.row_idx.shape[1]
    for j in range(go):
        # nnz holds TRUE counts, which exceed the stored slots when the
        # packing was truncated with an explicit nnz_max
        for s in range(min(int(bw.nnz[j]), nnz_max)):
            i = int(bw.row_idx[j, s])
            w[i * bw.bk : (i + 1) * bw.bk, j * bw.bn : (j + 1) * bw.bn] = bw.blocks[j, s]
    return w
