"""Deployment: trained QAT weights -> CIM-packed serving artifacts.

The paper's inference flow (§III): after QAT + pruning, only nonzero
group-sets are stored (with index codes) and computed. Here the LM
equivalent: every CIM-mapped projection is quantized to int levels
(eqs. 6-8), pruned at the TPU tile granularity, and packed for the
``cim_bsr_matmul`` kernel. ``deployed_matmul`` is the drop-in serving
replacement for ``cim_matmul``.

Uniform envelope: :func:`stack_deployed` folds L per-layer
:class:`DeployedWeight` packings of one projection into a single
:class:`StackedWeight` whose slot axis is padded to the per-projection
``nnz_max`` maximum (zero blocks AND zero scales, so padding is inert even
past a truncated layer's guard) while the per-layer ``nnz``/``row_idx``
stay exact - padding blocks are never computed. ``stacked_matmul`` then
serves any layer of the stack through ONE compiled layer-indexed kernel,
which is what lets the serving runtime ``lax.scan`` over layers instead of
dispatching L separate kernels per decode step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops
from ..kernels.cim_bsr_matmul import MACRO_AXIS
from . import quant as Q
from . import sparsity as S
from .cim_layer import CIMConfig


@dataclasses.dataclass
class DeployedWeight:
    """One projection packed for the kernel (per layer of a stack).

    Registered as a jax pytree so a whole model of packed projections can be
    passed through ``jit`` (the serving engines do exactly that); the block
    arrays are the leaves, the geometry is static aux data.

    ``mesh`` is None for single-device serving. After ``shard_weight`` it
    holds the macro-cluster mesh: each packed dict's block-column axis is
    then permuted into device order (equal-cardinality LPT shards), laid
    out over the mesh's ``macro`` axis, and carries a ``col_inv`` index
    that restores the original column order after the sharded kernel's
    all-gather.
    """

    packed: List[dict]  # one kernel dict per stacked layer
    d_in: int
    d_out: int
    bits: int
    mesh: Optional[Mesh] = None

    @property
    def density(self) -> float:
        return float(np.mean([p["density"] for p in self.packed]))

    @property
    def tile(self) -> tuple:
        """(bk, bn) block shape the projection was packed with."""
        b = self.packed[0]["blocks"]
        return (int(b.shape[2]), int(b.shape[3]))

    def astype(self, dtype):
        """No-op for call-site compatibility with raw weight arrays (the
        model code writes ``p["wq"].astype(x.dtype)``); the kernel's int8
        blocks + f32 scales are the only at-rest representation."""
        return self


jax.tree_util.register_pytree_node(
    DeployedWeight,
    lambda dw: ((dw.packed,), (dw.d_in, dw.d_out, dw.bits, dw.mesh)),
    lambda aux, ch: DeployedWeight(ch[0], *aux),
)


@dataclasses.dataclass
class StackedWeight:
    """L layers of one projection in a single uniform packing envelope.

    Every layer shares the (go, bk, bn) geometry; the slot axis is padded to
    the per-projection ``nnz_max`` maximum with zero blocks and zero scales,
    and the per-layer ``nnz``/``row_idx`` stay exact, so a padded slot is
    never a numeric participant. One layer-indexed kernel serves the whole
    stack - the compiled decode step never dispatches per layer.

    ``col_inv`` is None for single-device stacks. After stacking macro-
    sharded layers it holds the per-layer un-permute index ((L, go),
    replicated) that restores logical column order after the sharded
    kernel's all-gather - each layer keeps its own LPT column placement.
    """

    blocks: jnp.ndarray   # (L, go, nnz_max, bk, bn) int8
    scales: jnp.ndarray   # (L, go, nnz_max) f32 (0 in padding slots)
    row_idx: jnp.ndarray  # (L, go, nnz_max) int32
    nnz: jnp.ndarray      # (L, go) int32 true per-layer slot counts
    d_in: int
    d_out: int
    bits: int
    col_inv: Optional[jnp.ndarray] = None  # (L, go) int32 when sharded
    mesh: Optional[Mesh] = None

    @property
    def n_layers(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def tile(self) -> tuple:
        b = self.blocks
        return (int(b.shape[3]), int(b.shape[4]))

    @property
    def density(self) -> float:
        total = (self.d_in // self.tile[0]) * (self.d_out // self.tile[1])
        return float(np.asarray(self.nnz).sum()) / max(
            total * self.n_layers, 1)

    def layer(self, i: int) -> DeployedWeight:
        """Materialize layer ``i`` as a standalone single-layer
        DeployedWeight (host-side; for tests and storage accounting)."""
        go = int(self.nnz.shape[1])
        p = {k: np.asarray(getattr(self, k)[i])
             for k in ("blocks", "scales", "row_idx", "nnz")}
        gi = self.d_in // self.tile[0]
        p["density"] = float(p["nnz"].sum()) / max(gi * go, 1)
        if self.col_inv is not None:
            p["col_inv"] = np.asarray(self.col_inv[i])
        return DeployedWeight([p], self.d_in, self.d_out, self.bits,
                              mesh=self.mesh)

    def astype(self, dtype):
        """No-op (call-site compatibility with raw weight arrays)."""
        return self


jax.tree_util.register_pytree_node(
    StackedWeight,
    lambda sw: ((sw.blocks, sw.scales, sw.row_idx, sw.nnz, sw.col_inv),
                (sw.d_in, sw.d_out, sw.bits, sw.mesh)),
    lambda aux, ch: StackedWeight(*ch[:4], aux[0], aux[1], aux[2],
                                  col_inv=ch[4], mesh=aux[3]),
)


class StackedLayerView:
    """One layer of a :class:`StackedWeight`, as seen from inside a traced
    scan body: ``layer`` is the (traced) scan index. ``cim_matmul``
    dispatches this to :func:`stacked_matmul`, so the standard model code
    (attention / MLP bodies) runs over a layer stack unchanged. Never
    crosses a jit boundary - it is built fresh each scan step."""

    __slots__ = ("sw", "layer")

    def __init__(self, sw: StackedWeight, layer):
        self.sw = sw
        self.layer = layer

    def astype(self, dtype):
        return self


def stack_deployed(dws: Sequence[DeployedWeight]) -> StackedWeight:
    """Stack per-layer packings of ONE projection into a uniform envelope.

    Every entry must share (d_in, d_out, bits, tile, go) - the uniform-tile
    contract; only ``nnz_max`` may differ, and it is padded up to the
    per-projection maximum with zero blocks/scales (``nnz`` keeps the exact
    per-layer counts, so padding is never fetched by the guard). Accepts
    single-layer weights or multi-layer ones (their packed lists are
    concatenated in order). Macro-sharded inputs must all carry the same
    mesh; their per-layer ``col_inv`` indices stack alongside.
    """
    if isinstance(dws, DeployedWeight):
        dws = [dws]
    dws = list(dws)
    if not dws:
        raise ValueError("stack_deployed needs at least one DeployedWeight")
    ref = dws[0]
    for dw in dws[1:]:
        if (dw.d_in, dw.d_out, dw.bits) != (ref.d_in, ref.d_out, ref.bits):
            raise ValueError(
                "stack_deployed: mixed projection geometry "
                f"{(dw.d_in, dw.d_out, dw.bits)} vs "
                f"{(ref.d_in, ref.d_out, ref.bits)} - stack one projection "
                "at a time")
        if dw.mesh is not ref.mesh:
            raise ValueError("stack_deployed: mixed meshes across layers")
    packed = [p for dw in dws for p in dw.packed]
    shapes = {tuple(np.asarray(p["blocks"]).shape[i] for i in (0, 2, 3))
              for p in packed}
    if len(shapes) != 1:
        raise ValueError(
            f"stack_deployed: non-uniform (go, bk, bn) across layers "
            f"{sorted(shapes)} - repack with a uniform tile "
            "(sched.search uniform mode / compress(uniform=True))")
    sharded = ref.mesh is not None
    if sharded and not all("col_inv" in p for p in packed):
        raise ValueError("stack_deployed: sharded stack missing col_inv")
    nnz_max = max(int(np.asarray(p["row_idx"]).shape[1]) for p in packed)

    def pad(a, width):
        a = np.asarray(a)
        if a.shape[1] == width:
            return a
        pads = [(0, 0)] * a.ndim
        pads[1] = (0, width - a.shape[1])
        return np.pad(a, pads)  # zero blocks, zero scales, row_idx 0

    blocks = np.stack([pad(p["blocks"], nnz_max) for p in packed])
    scales = np.stack([pad(p["scales"], nnz_max) for p in packed])
    row_idx = np.stack([pad(p["row_idx"], nnz_max) for p in packed])
    nnz = np.stack([np.asarray(p["nnz"]) for p in packed])
    col_inv = (np.stack([np.asarray(p["col_inv"]) for p in packed])
               if sharded else None)
    if sharded:
        specs = deployed_weight_specs()
        stacked_specs = {
            k: P(*((None,) + tuple(specs[k])))
            for k in ("blocks", "scales", "row_idx", "nnz", "col_inv")}
        put = lambda k, v: jax.device_put(
            jnp.asarray(v), NamedSharding(ref.mesh, stacked_specs[k]))
        return StackedWeight(put("blocks", blocks), put("scales", scales),
                             put("row_idx", row_idx), put("nnz", nnz),
                             ref.d_in, ref.d_out, ref.bits,
                             col_inv=put("col_inv", col_inv), mesh=ref.mesh)
    return StackedWeight(jnp.asarray(blocks), jnp.asarray(scales),
                         jnp.asarray(row_idx), jnp.asarray(nnz),
                         ref.d_in, ref.d_out, ref.bits)


def fit_tile(d_in: int, d_out: int, bk: int, bn: int) -> tuple:
    """Largest (bk, bn) at most the requested tile that exactly divides
    (d_in, d_out) - ``pack_bsr`` requires exact tiling."""
    return (_largest_divisor(d_in, bk), _largest_divisor(d_out, bn))


def _largest_divisor(n: int, at_most: int) -> int:
    for d in range(min(at_most, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def deploy_weight(w, cim: CIMConfig, bk: int = 128, bn: int = 128,
                  target_sparsity: Optional[float] = None) -> DeployedWeight:
    """Quantize + prune + pack a (d_in, d_out) or stacked (L, d_in, d_out)
    master weight for serving."""
    w = jnp.asarray(w)
    stacked = w if w.ndim == 3 else w[None]
    bits = cim.quant.w_bits
    ts = (cim.sparsity.target_sparsity if target_sparsity is None
          else target_sparsity)
    packed = []
    for wl in stacked:
        mask = S.prune_mask_2d(wl, bk, bn, ts)
        wq = Q.mars_weight_quant(wl * mask, bits, cim.quant.group_size)
        packed.append(ops.pack_for_kernel(np.asarray(wq), bits=bits,
                                          bk=bk, bn=bn))
    return DeployedWeight(packed, stacked.shape[-2], stacked.shape[-1], bits)


def shardable_columns(dw: DeployedWeight, n_devices: int) -> bool:
    """True when every stacked layer's block-column count splits evenly
    over ``n_devices`` - the precondition for equal-shaped macro shards."""
    return all(int(p["blocks"].shape[0]) % n_devices == 0 for p in dw.packed)


def deployed_weight_specs(axis: str = MACRO_AXIS) -> Dict[str, P]:
    """PartitionSpecs for one macro-sharded packed projection dict - the
    layout contract ``shard_weight`` applies: block-column axis over the
    macro cluster, the un-permute index replicated."""
    return {
        "blocks": P(axis, None, None, None),
        "scales": P(axis, None),
        "row_idx": P(axis, None),
        "nnz": P(axis),
        "col_inv": P(),
        "density": P(),
    }


def shard_weight(dw: DeployedWeight, mesh: Mesh, axis: str = MACRO_AXIS,
                 assign: Optional[Callable] = None) -> DeployedWeight:
    """Column-shard a packed projection over the serving macro cluster.

    ``assign(nnz_counts, n_devices) -> (go,) device ids`` chooses which
    block columns live on which device (``sched.allocate.device_assignment``
    is the LPT policy; None = contiguous split). The packed arrays are
    permuted into device order on the column axis, ``device_put`` with the
    leading axis over ``mesh[axis]``, and a replicated ``col_inv`` records
    how to restore the original column order after the kernel's all-gather.
    Non-divisible projections are returned unchanged (served replicated) -
    sharding must never change which weights exist, only where they live.
    """
    n_dev = int(mesh.shape[axis])
    if dw.mesh is not None or n_dev <= 1 or not shardable_columns(dw, n_dev):
        return dw
    specs = deployed_weight_specs(axis)
    packed = []
    for p in dw.packed:
        counts = np.asarray(p["nnz"])
        go = counts.shape[0]
        if assign is None:
            dev = np.repeat(np.arange(n_dev), go // n_dev)
        else:
            dev = np.asarray(assign(counts, n_dev))
        perm = np.concatenate([np.flatnonzero(dev == d) for d in range(n_dev)])
        inv = np.argsort(perm)
        q = {k: np.asarray(p[k])[perm]
             for k in ("blocks", "scales", "row_idx", "nnz")}
        q["col_inv"] = np.asarray(inv, np.int32)
        packed.append({
            **{k: jax.device_put(jnp.asarray(v),
                                 NamedSharding(mesh, specs[k]))
               for k, v in q.items()},
            "density": p["density"],
        })
    return DeployedWeight(packed, dw.d_in, dw.d_out, dw.bits, mesh=mesh)


def bm_for_rows(rows: int) -> int:
    """Kernel row-tile for an activation row count: the next power of two in
    [8, 128]. A fixed bucket ladder instead of the raw row count means a
    changing active-batch / padded-prompt size maps to O(log) compiled
    kernels, not one per size - batch-server admission can't trigger a
    recompile cascade - and every tile is MXU-aligned."""
    b = 8
    while b < rows and b < 128:
        b *= 2
    return b


def deployed_matmul(x: jnp.ndarray, dw: DeployedWeight, layer: int = 0,
                    a_bits: int = 0, interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """Serving-path matmul: eq.5 activation quant + BSR kernel.

    x: (..., d_in). The zero blocks dropped at packing are never fetched
    or multiplied - MARS §III.B on the MXU. When ``dw`` is macro-sharded,
    each device runs the kernel on its resident block columns only and the
    all-gathered output is un-permuted back to the logical column order.
    """
    if a_bits:
        x = Q.quantize_activation(x.astype(jnp.float32), a_bits, signed=True)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, dw.d_in)
    bm = bm_for_rows(x2.shape[0])
    if dw.mesh is not None:
        p = dw.packed[layer]
        go, _, _, bn = p["blocks"].shape
        y = ops.bsr_matmul_sharded(x2, p, dw.mesh, bm=bm, interpret=interpret)
        y = jnp.take(y.reshape(-1, go, bn), p["col_inv"], axis=1)
        y = y.reshape(-1, dw.d_out)
    else:
        y = ops.bsr_matmul(x2, dw.packed[layer], bm=bm, interpret=interpret)
    return y.reshape(*lead, dw.d_out).astype(x.dtype)


def stacked_matmul(x: jnp.ndarray, sw: StackedWeight, layer,
                   a_bits: int = 0, interpret: Optional[bool] = None
                   ) -> jnp.ndarray:
    """Serving-path matmul against layer ``layer`` of a uniform envelope.

    ``layer`` may be a traced int32 (the scan index): the kernel is layer-
    indexed through the scalar-prefetch channel, so every layer runs the
    same compiled program. Numerics are bit-identical to
    ``deployed_matmul(x, dw_layer)`` - envelope padding contributes nothing.
    """
    if a_bits:
        x = Q.quantize_activation(x.astype(jnp.float32), a_bits, signed=True)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, sw.d_in)
    bm = bm_for_rows(x2.shape[0])
    if sw.mesh is not None:
        go, bn = int(sw.nnz.shape[1]), sw.tile[1]
        y = ops.bsr_matmul_stacked_sharded(
            x2, sw.blocks, sw.scales, sw.row_idx, sw.nnz, layer, sw.mesh,
            bm=bm, interpret=interpret)
        inv = jax.lax.dynamic_index_in_dim(
            sw.col_inv, jnp.asarray(layer, jnp.int32), axis=0, keepdims=False)
        y = jnp.take(y.reshape(-1, go, bn), inv, axis=1)
        y = y.reshape(-1, sw.d_out)
    else:
        y = ops.bsr_matmul_stacked(x2, sw.blocks, sw.scales, sw.row_idx,
                                   sw.nnz, layer, bm=bm, interpret=interpret)
    return y.reshape(*lead, sw.d_out).astype(x.dtype)


def unshard_weight(dw: DeployedWeight) -> DeployedWeight:
    """Undo ``shard_weight``: restore logical column order (via ``col_inv``)
    and drop the placement. This is the serialization form - artifacts store
    placement-free packings and are re-sharded at load onto whatever mesh
    the serving host has (host-side, like all packing)."""
    if dw.mesh is None:
        return dw
    packed = []
    for p in dw.packed:
        inv = np.asarray(p["col_inv"])
        packed.append({
            **{k: jnp.asarray(np.asarray(p[k])[inv])
               for k in ("blocks", "scales", "row_idx", "nnz")},
            "density": p["density"],
        })
    return DeployedWeight(packed, dw.d_in, dw.d_out, dw.bits)


def uniform_fit_tile(shapes: Sequence[tuple], bk: int, bn: int) -> tuple:
    """One (bk, bn) for a whole network: the largest tile at most the
    requested one that exactly divides EVERY (d_in, d_out) in ``shapes`` -
    the CIM-Tuner-style network-wide mapping constraint that makes every
    projection's packing share a hardware-feasible envelope."""
    if not shapes:
        return (bk, bn)
    gk = 0
    gn = 0
    for d_in, d_out in shapes:
        gk = int(np.gcd(gk, int(d_in)))
        gn = int(np.gcd(gn, int(d_out)))
    return (_largest_divisor(gk, bk), _largest_divisor(gn, bn))


def reference_matmul(x: jnp.ndarray, w, cim: CIMConfig,
                     target_sparsity: Optional[float] = None,
                     bk: int = 128, bn: int = 128) -> jnp.ndarray:
    """QAT-simulation oracle for deployed_matmul (same quant + mask path,
    dense math)."""
    ts = (cim.sparsity.target_sparsity if target_sparsity is None
          else target_sparsity)
    mask = S.prune_mask_2d(w, bk, bn, ts)
    wq = Q.mars_weight_quant(w * mask, cim.quant.w_bits, cim.quant.group_size)
    xq = Q.quantize_activation(x.astype(jnp.float32), cim.quant.a_bits,
                               signed=True)
    return (xq @ wq.astype(jnp.float32)).astype(x.dtype)


def deployment_report(deployed: Dict[str, DeployedWeight]) -> dict:
    """Storage accounting across all deployed projections (Table IV-style)."""
    total_dense_bits = total_weight_bits = total_index_bits = 0
    for name, dw in deployed.items():
        for p in dw.packed:
            nnz_blocks = int(np.asarray(p["nnz"]).sum())
            bk, bn = p["blocks"].shape[2], p["blocks"].shape[3]
            total_weight_bits += nnz_blocks * bk * bn * dw.bits
            total_index_bits += nnz_blocks * 32  # int32 row index per block
        total_dense_bits += dw.d_in * dw.d_out * len(dw.packed) * 32
    return {
        "dense_Mb": total_dense_bits / 2**20,
        "weight_Mb": total_weight_bits / 2**20,
        "index_Kb": total_index_bits / 2**10,
        "compression_x": total_dense_bits / max(total_weight_bits
                                                + total_index_bits, 1),
    }
