"""Deployment: trained QAT weights -> CIM-packed serving artifacts.

The paper's inference flow (§III): after QAT + pruning, only nonzero
group-sets are stored (with index codes) and computed. Here the LM
equivalent: every CIM-mapped projection is quantized to int levels
(eqs. 6-8), pruned at the TPU tile granularity, and packed for the
``cim_bsr_matmul`` kernel. ``deployed_matmul`` is the drop-in serving
replacement for ``cim_matmul``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops
from ..kernels.cim_bsr_matmul import MACRO_AXIS
from . import quant as Q
from . import sparsity as S
from .cim_layer import CIMConfig


@dataclasses.dataclass
class DeployedWeight:
    """One projection packed for the kernel (per layer of a stack).

    Registered as a jax pytree so a whole model of packed projections can be
    passed through ``jit`` (the serving engines do exactly that); the block
    arrays are the leaves, the geometry is static aux data.

    ``mesh`` is None for single-device serving. After ``shard_weight`` it
    holds the macro-cluster mesh: each packed dict's block-column axis is
    then permuted into device order (equal-cardinality LPT shards), laid
    out over the mesh's ``macro`` axis, and carries a ``col_inv`` index
    that restores the original column order after the sharded kernel's
    all-gather.
    """

    packed: List[dict]  # one kernel dict per stacked layer
    d_in: int
    d_out: int
    bits: int
    mesh: Optional[Mesh] = None

    @property
    def density(self) -> float:
        return float(np.mean([p["density"] for p in self.packed]))

    @property
    def tile(self) -> tuple:
        """(bk, bn) block shape the projection was packed with."""
        b = self.packed[0]["blocks"]
        return (int(b.shape[2]), int(b.shape[3]))

    def astype(self, dtype):
        """No-op for call-site compatibility with raw weight arrays (the
        model code writes ``p["wq"].astype(x.dtype)``); the kernel's int8
        blocks + f32 scales are the only at-rest representation."""
        return self


jax.tree_util.register_pytree_node(
    DeployedWeight,
    lambda dw: ((dw.packed,), (dw.d_in, dw.d_out, dw.bits, dw.mesh)),
    lambda aux, ch: DeployedWeight(ch[0], *aux),
)


def fit_tile(d_in: int, d_out: int, bk: int, bn: int) -> tuple:
    """Largest (bk, bn) at most the requested tile that exactly divides
    (d_in, d_out) - ``pack_bsr`` requires exact tiling."""
    return (_largest_divisor(d_in, bk), _largest_divisor(d_out, bn))


def _largest_divisor(n: int, at_most: int) -> int:
    for d in range(min(at_most, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def deploy_weight(w, cim: CIMConfig, bk: int = 128, bn: int = 128,
                  target_sparsity: Optional[float] = None) -> DeployedWeight:
    """Quantize + prune + pack a (d_in, d_out) or stacked (L, d_in, d_out)
    master weight for serving."""
    w = jnp.asarray(w)
    stacked = w if w.ndim == 3 else w[None]
    bits = cim.quant.w_bits
    ts = (cim.sparsity.target_sparsity if target_sparsity is None
          else target_sparsity)
    packed = []
    for wl in stacked:
        mask = S.prune_mask_2d(wl, bk, bn, ts)
        wq = Q.mars_weight_quant(wl * mask, bits, cim.quant.group_size)
        packed.append(ops.pack_for_kernel(np.asarray(wq), bits=bits,
                                          bk=bk, bn=bn))
    return DeployedWeight(packed, stacked.shape[-2], stacked.shape[-1], bits)


def shardable_columns(dw: DeployedWeight, n_devices: int) -> bool:
    """True when every stacked layer's block-column count splits evenly
    over ``n_devices`` - the precondition for equal-shaped macro shards."""
    return all(int(p["blocks"].shape[0]) % n_devices == 0 for p in dw.packed)


def deployed_weight_specs(axis: str = MACRO_AXIS) -> Dict[str, P]:
    """PartitionSpecs for one macro-sharded packed projection dict - the
    layout contract ``shard_weight`` applies: block-column axis over the
    macro cluster, the un-permute index replicated."""
    return {
        "blocks": P(axis, None, None, None),
        "scales": P(axis, None),
        "row_idx": P(axis, None),
        "nnz": P(axis),
        "col_inv": P(),
        "density": P(),
    }


def shard_weight(dw: DeployedWeight, mesh: Mesh, axis: str = MACRO_AXIS,
                 assign: Optional[Callable] = None) -> DeployedWeight:
    """Column-shard a packed projection over the serving macro cluster.

    ``assign(nnz_counts, n_devices) -> (go,) device ids`` chooses which
    block columns live on which device (``sched.allocate.device_assignment``
    is the LPT policy; None = contiguous split). The packed arrays are
    permuted into device order on the column axis, ``device_put`` with the
    leading axis over ``mesh[axis]``, and a replicated ``col_inv`` records
    how to restore the original column order after the kernel's all-gather.
    Non-divisible projections are returned unchanged (served replicated) -
    sharding must never change which weights exist, only where they live.
    """
    n_dev = int(mesh.shape[axis])
    if dw.mesh is not None or n_dev <= 1 or not shardable_columns(dw, n_dev):
        return dw
    specs = deployed_weight_specs(axis)
    packed = []
    for p in dw.packed:
        counts = np.asarray(p["nnz"])
        go = counts.shape[0]
        if assign is None:
            dev = np.repeat(np.arange(n_dev), go // n_dev)
        else:
            dev = np.asarray(assign(counts, n_dev))
        perm = np.concatenate([np.flatnonzero(dev == d) for d in range(n_dev)])
        inv = np.argsort(perm)
        q = {k: np.asarray(p[k])[perm]
             for k in ("blocks", "scales", "row_idx", "nnz")}
        q["col_inv"] = np.asarray(inv, np.int32)
        packed.append({
            **{k: jax.device_put(jnp.asarray(v),
                                 NamedSharding(mesh, specs[k]))
               for k, v in q.items()},
            "density": p["density"],
        })
    return DeployedWeight(packed, dw.d_in, dw.d_out, dw.bits, mesh=mesh)


def deployed_matmul(x: jnp.ndarray, dw: DeployedWeight, layer: int = 0,
                    a_bits: int = 0, interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """Serving-path matmul: eq.5 activation quant + BSR kernel.

    x: (..., d_in). The zero blocks dropped at packing are never fetched
    or multiplied - MARS §III.B on the MXU. When ``dw`` is macro-sharded,
    each device runs the kernel on its resident block columns only and the
    all-gathered output is un-permuted back to the logical column order.
    """
    if a_bits:
        x = Q.quantize_activation(x.astype(jnp.float32), a_bits, signed=True)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, dw.d_in)
    bm = max(8, min(128, x2.shape[0]))
    if dw.mesh is not None:
        p = dw.packed[layer]
        go, _, _, bn = p["blocks"].shape
        y = ops.bsr_matmul_sharded(x2, p, dw.mesh, bm=bm, interpret=interpret)
        y = jnp.take(y.reshape(-1, go, bn), p["col_inv"], axis=1)
        y = y.reshape(-1, dw.d_out)
    else:
        y = ops.bsr_matmul(x2, dw.packed[layer], bm=bm, interpret=interpret)
    return y.reshape(*lead, dw.d_out).astype(x.dtype)


def reference_matmul(x: jnp.ndarray, w, cim: CIMConfig,
                     target_sparsity: Optional[float] = None,
                     bk: int = 128, bn: int = 128) -> jnp.ndarray:
    """QAT-simulation oracle for deployed_matmul (same quant + mask path,
    dense math)."""
    ts = (cim.sparsity.target_sparsity if target_sparsity is None
          else target_sparsity)
    mask = S.prune_mask_2d(w, bk, bn, ts)
    wq = Q.mars_weight_quant(w * mask, cim.quant.w_bits, cim.quant.group_size)
    xq = Q.quantize_activation(x.astype(jnp.float32), cim.quant.a_bits,
                               signed=True)
    return (xq @ wq.astype(jnp.float32)).astype(x.dtype)


def deployment_report(deployed: Dict[str, DeployedWeight]) -> dict:
    """Storage accounting across all deployed projections (Table IV-style)."""
    total_dense_bits = total_weight_bits = total_index_bits = 0
    for name, dw in deployed.items():
        for p in dw.packed:
            nnz_blocks = int(np.asarray(p["nnz"]).sum())
            bk, bn = p["blocks"].shape[2], p["blocks"].shape[3]
            total_weight_bits += nnz_blocks * bk * bn * dw.bits
            total_index_bits += nnz_blocks * 32  # int32 row index per block
        total_dense_bits += dw.d_in * dw.d_out * len(dw.packed) * 32
    return {
        "dense_Mb": total_dense_bits / 2**20,
        "weight_Mb": total_weight_bits / 2**20,
        "index_Kb": total_index_bits / 2**10,
        "compression_x": total_dense_bits / max(total_weight_bits
                                                + total_index_bits, 1),
    }
