"""MARS core: CIM-aware compression + accelerator model (the paper's contribution)."""
from . import cim_layer, mapping, perf_model, quant, sparsity  # noqa: F401
from .cim_layer import CIMConfig, DENSE  # noqa: F401
from .quant import QuantConfig  # noqa: F401
from .sparsity import SparsityConfig  # noqa: F401
