"""CIMLinear / CIMConv2D - MARS's technique as a composable JAX module.

Functional, pytree-based (no flax): ``init`` returns a params dict,
``apply`` is a pure function usable under jit/grad/pjit/scan. Three
execution modes, selected by CIMConfig:

  * dense  - plain float matmul/conv (the paper's 32/32 rows).
  * qat    - quantization-aware training: eq.5 activations, eqs.6-8
             weights (with BN/RMSNorm fusion), optional pruning mask,
             group-lasso regularization collected by ``regularizer``.
  * deploy - weights pre-quantized to int levels and BSR-packed; the
             Pallas kernels consume the packed form (serving path).

The same module serves the paper's CNNs (CIMConv2D with BN fusion) and the
LM zoo (CIMLinear on QKV/O, MLP, MoE experts, SSM projections).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import quant as Q
from . import sparsity as S


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    quant: Q.QuantConfig = dataclasses.field(default_factory=Q.QuantConfig)
    sparsity: S.SparsityConfig = dataclasses.field(default_factory=S.SparsityConfig)
    mode: str = "dense"  # dense | qat | deploy
    bn_momentum: float = 0.9

    def with_mode(self, mode: str) -> "CIMConfig":
        return dataclasses.replace(self, mode=mode)


DENSE = CIMConfig(mode="dense")


# ---------------------------------------------------------------------------
# CIMLinear
# ---------------------------------------------------------------------------


def linear_init(key, d_in: int, d_out: int, cfg: CIMConfig = DENSE,
                dtype=jnp.float32, bias: bool = False) -> dict:
    scale = 1.0 / (d_in**0.5)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    if cfg.mode == "qat":
        p["mask"] = jnp.ones((d_in, d_out), jnp.float32)
    return p


def effective_weight(params: dict, cfg: CIMConfig,
                     norm_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """The weight actually multiplied: masked, tanh-normalized, scale-fused,
    quantized (eqs. 6-8). For dense mode it is just params['w']."""
    w = params["w"]
    if cfg.mode == "dense":
        return w
    if "mask" in params:
        # masks are structural, never trainable: without stop_gradient the
        # optimizer would drift them off {0,1} during masked retraining
        w = w * jax.lax.stop_gradient(params["mask"]).astype(w.dtype)
    qc = cfg.quant
    if not qc.enabled and norm_scale is None:
        return w
    w_hat = Q.tanh_normalize(w.astype(jnp.float32), qc.group_size)
    if norm_scale is not None:  # RMSNorm gain folded on the input axis
        w_hat = Q.fold_input_scale(w_hat, norm_scale.astype(jnp.float32))
    else:
        w_hat = jnp.clip(w_hat, -1.0, 1.0)
    return Q.quantize_weight_symmetric(w_hat, qc.w_bits).astype(w.dtype)


def linear_apply(params: dict, x: jnp.ndarray, cfg: CIMConfig = DENSE,
                 norm_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """y = quant(x) @ quant(w) + b."""
    if cfg.mode != "dense" and cfg.quant.enabled:
        x = Q.quantize_activation(x.astype(jnp.float32), cfg.quant.a_bits,
                                  cfg.quant.a_signed).astype(x.dtype)
    w = effective_weight(params, cfg, norm_scale)
    y = x @ w.astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def linear_regularizer(params: dict, cfg: CIMConfig) -> jnp.ndarray:
    """Group-lasso (eq. 4) + L2 (eq. 1) for this layer's master weight."""
    sc = cfg.sparsity
    w = params["w"].astype(jnp.float32)
    if w.ndim == 3:  # stacked layers under scan
        r = jnp.sum(jax.vmap(lambda m: S.group_lasso_2d(m, sc.n, sc.alpha))(w))
    else:
        r = S.group_lasso_2d(w, sc.n, sc.alpha)
    total = sc.lambda_g / 2.0 * r
    if sc.lambda_l2 > 0:
        total = total + sc.lambda_l2 / 2.0 * jnp.sum(w * w)
    return total


def linear_prune(params: dict, cfg: CIMConfig) -> dict:
    """Recompute the pruning mask from tile norms (post-regularized weights)."""
    sc = cfg.sparsity
    w = params["w"]
    if w.ndim == 3:
        mask = jax.vmap(lambda m: S.prune_mask_2d(m, sc.n, sc.alpha, sc.target_sparsity))(w)
    else:
        mask = S.prune_mask_2d(w, sc.n, sc.alpha, sc.target_sparsity)
    out = dict(params)
    out["mask"] = mask.astype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# CIMConv2D (NHWC / HWIO) with BN fusion - the paper's CNN building block
# ---------------------------------------------------------------------------


def conv_init(key, kh: int, kw: int, cin: int, cout: int, cfg: CIMConfig = DENSE,
              dtype=jnp.float32, with_bn: bool = True) -> Tuple[dict, dict]:
    k1, _ = jax.random.split(key)
    fan_in = kh * kw * cin
    params = {"w": jax.random.normal(k1, (kh, kw, cin, cout), dtype) * (2.0 / fan_in) ** 0.5}
    state = {}
    if with_bn:
        params["gamma"] = jnp.ones((cout,), jnp.float32)
        params["beta"] = jnp.zeros((cout,), jnp.float32)
        state = {"mean": jnp.zeros((cout,), jnp.float32),
                 "var": jnp.ones((cout,), jnp.float32)}
    if cfg.mode == "qat":
        params["mask"] = jnp.ones((kh, kw, cin, cout), jnp.float32)
    return params, state


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def conv_apply(params: dict, state: dict, x: jnp.ndarray, cfg: CIMConfig = DENSE,
               stride: int = 1, padding: str = "SAME",
               train: bool = False) -> Tuple[jnp.ndarray, dict]:
    """Forward. Returns (y, new_state). In qat mode the BN scale is fused
    into the quantized weight (eq. 7) and the remaining BN shift becomes a
    cheap per-channel bias (APW-block add); EMA stats update in train mode."""
    w = params["w"]
    has_bn = "gamma" in params
    if cfg.mode == "dense":
        y = _conv(x, w, stride, padding)
        if has_bn:
            if train:
                mean, var = Q.batch_stats(y)
                state = {
                    "mean": Q.ema_update(state["mean"], mean, cfg.bn_momentum),
                    "var": Q.ema_update(state["var"], var, cfg.bn_momentum),
                }
            else:
                mean, var = state["mean"], state["var"]
            inv = jax.lax.rsqrt(var + cfg.quant.eps)
            y = (y - mean) * inv * params["gamma"] + params["beta"]
        return y, state

    # --- qat: eqs. 5-8 ---
    qc = cfg.quant
    if "mask" in params:
        w = w * jax.lax.stop_gradient(params["mask"]).astype(w.dtype)
    xq = Q.quantize_activation(x.astype(jnp.float32), qc.a_bits, qc.a_signed)
    kh, kw, cin, cout = w.shape
    w2d = w.reshape(kh * kw * cin, cout).astype(jnp.float32)
    w_hat = Q.tanh_normalize(w2d, qc.group_size)
    if has_bn and qc.bn_fuse:
        if train:
            # batch stats of the pre-BN output computed with the normalized
            # (un-fused) weight; gradient does not flow through the stats.
            u = _conv(xq, jax.lax.stop_gradient(w_hat).reshape(kh, kw, cin, cout),
                      stride, padding)
            mean_b, var_b = Q.batch_stats(u)
            state = {
                "mean": Q.ema_update(state["mean"], mean_b, cfg.bn_momentum),
                "var": Q.ema_update(state["var"], var_b, cfg.bn_momentum),
            }
            mean, var = mean_b, var_b
        else:
            mean, var = state["mean"], state["var"]
        w_bar = Q.fuse_bn_scale(w_hat, params["gamma"], var, qc.eps)
        scale = params["gamma"] * jax.lax.rsqrt(var + qc.eps)
        bias = params["beta"] - scale * mean
    else:
        w_bar = jnp.clip(w_hat, -1.0, 1.0)
        bias = None
    w_q = Q.quantize_weight_symmetric(w_bar, qc.w_bits)
    y = _conv(xq, w_q.reshape(kh, kw, cin, cout), stride, padding)
    if bias is not None:
        y = y + bias
    return y, state


def conv_regularizer(params: dict, cfg: CIMConfig) -> jnp.ndarray:
    sc = cfg.sparsity
    w = params["w"].astype(jnp.float32)
    total = sc.lambda_g / 2.0 * S.group_lasso_conv(w, sc.n, sc.alpha)
    if sc.lambda_l2 > 0:
        total = total + sc.lambda_l2 / 2.0 * jnp.sum(w * w)
    return total


def conv_prune(params: dict, cfg: CIMConfig) -> dict:
    sc = cfg.sparsity
    mask = S.prune_mask_conv(params["w"], sc.n, sc.alpha, sc.target_sparsity)
    out = dict(params)
    out["mask"] = mask.astype(jnp.float32)
    return out
