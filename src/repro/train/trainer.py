"""Train-step construction: loss + MARS group-lasso regularization (eq. 2),
microbatch gradient accumulation, optimizer update, metrics.

The regularizer is path-filtered: it applies to the weights that map onto
CIM macros (attention/MLP/MoE/SSM projections), not to norms, embeddings,
routers or biases - mirroring the paper, which prunes conv layers only.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.cim_layer import CIMConfig
from ..core.sparsity import group_lasso_2d
from ..models import registry
from ..models.config import ModelConfig
from . import optimizer as opt

WEIGHT_KEYS = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
               "in_proj", "out_proj", "mm_proj"}


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", "")))


def lm_regularization(params, cim: CIMConfig) -> jnp.ndarray:
    """Group lasso (eq. 4) over every CIM-mapped weight in the LM tree.
    Handles stacked shapes: (d,f), (L,d,f), (L,E,d,f)."""
    sc = cim.sparsity
    total = jnp.zeros((), jnp.float32)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        if _leaf_name(path) not in WEIGHT_KEYS or not hasattr(leaf, "ndim"):
            continue
        w = leaf.astype(jnp.float32)
        fn = lambda m: group_lasso_2d(m, sc.n, sc.alpha)
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)

        total = total + jnp.sum(fn(w))
    return sc.lambda_g / 2.0 * total


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt.OptConfig = dataclasses.field(default_factory=opt.OptConfig)
    grad_accum: int = 1
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 100
    ckpt_keep: int = 3
    log_every: int = 10


def init_train_state(model_cfg: ModelConfig, tcfg: TrainConfig, key) -> dict:
    fns = registry.model_fns(model_cfg)
    params = fns.init_params(model_cfg, key)
    return {
        "params": params,
        "opt": opt.init_state(tcfg.opt, params),
        "step": jnp.zeros((), jnp.int32),
    }


def make_loss_fn(model_cfg: ModelConfig) -> Callable:
    fns = registry.model_fns(model_cfg)

    def loss_fn(params, batch):
        ce = fns.train_loss(params, batch, model_cfg)
        total = ce
        if model_cfg.cim_mode == "qat" and model_cfg.lambda_g > 0:
            total = total + lm_regularization(params, model_cfg.cim)
        return total, ce

    return loss_fn


def make_train_step(model_cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). Pure function:
    jit (and pjit via in/out shardings) is applied by the caller."""
    loss_fn = make_loss_fn(model_cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.grad_accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (tot, ce), g = grad_fn(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b, gsum, g)
                return (gsum, lsum + ce), None

            mbs = jax.tree.map(
                lambda x: x.reshape((tcfg.grad_accum, -1) + x.shape[1:]), batch
            )
            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, ce_sum), _ = jax.lax.scan(micro, (zero_g, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            ce = ce_sum / tcfg.grad_accum
        else:
            (total, ce), grads = grad_fn(params, batch)
        new_params, new_opt, metrics = opt.apply_updates(
            tcfg.opt, params, state["opt"], grads, state["step"]
        )
        metrics = dict(metrics)
        metrics["loss"] = ce
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, metrics

    return train_step
