"""Int8 error-feedback gradient compression over the data-parallel axis.

MARS's own quantizer (eq. 8's symmetric integer grid) applied to the DP
gradient all-reduce - the distributed-optimization trick that carries the
paper's insight to the communication layer: gradients cross the ICI/DCN as
int8 levels + one f32 scale per tensor, an ~3.5x wire-volume reduction,
with error feedback keeping SGD unbiased in the long run.

Implemented with shard_map so the collective is explicit (psum of int
levels), composing with a pure-DP mesh axis. Error-feedback state lives in
the train state and is checkpointed like everything else.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map

from ..models.config import ModelConfig
from . import optimizer as opt
from .trainer import TrainConfig, make_loss_fn


def _compress_psum_mean(g: jnp.ndarray, err: jnp.ndarray, axis: str):
    """Quantize g+err to int8 levels with a pmax-shared scale, psum, and
    return (mean gradient, new error)."""
    g32 = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127)
    new_err = g32 - q * scale
    n = jax.lax.psum(jnp.ones(()), axis)
    mean = jax.lax.psum(q, axis) * (scale / n)
    return mean.astype(g.dtype), new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_dp_train_step(model_cfg: ModelConfig, tcfg: TrainConfig,
                                  mesh: Mesh, axis: str = "data") -> Callable:
    """Pure data parallelism with explicit compressed gradient psum.

    state (params/opt/err) is replicated across ``axis``; batch is sharded
    on its leading dim. Returns a jit-ready function (already shard_mapped).
    """
    loss_fn = make_loss_fn(model_cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def local_step(state, batch):
        (total, ce), grads = grad_fn(state["params"], batch)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(state["err"])
        out = [
            _compress_psum_mean(g, e, axis) for g, e in zip(flat_g, flat_e)
        ]
        grads = tdef.unflatten([o[0] for o in out])
        new_err = tdef.unflatten([o[1] for o in out])
        ce = jax.lax.pmean(ce, axis)
        new_params, new_opt, metrics = opt.apply_updates(
            tcfg.opt, state["params"], state["opt"], grads, state["step"]
        )
        metrics = dict(metrics)
        metrics["loss"] = ce
        new_state = {"params": new_params, "opt": new_opt, "err": new_err,
                     "step": state["step"] + 1}
        return new_state, metrics

    state_spec = {"params": P(), "opt": P(), "err": P(), "step": P()}
    # batch sharded over the DP axis; metrics replicated
    step = shard_map(
        local_step, mesh=mesh,
        in_specs=(state_spec, P(axis)),
        out_specs=(state_spec, P()),
        check_vma=False,
    )
    return step
