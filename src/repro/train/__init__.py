from . import checkpoint, compression, optimizer, pipeline, trainer  # noqa: F401
from .optimizer import OptConfig  # noqa: F401
from .trainer import TrainConfig, init_train_state, make_train_step  # noqa: F401
