"""Hand-rolled optimizers (no optax in this container): SGD+momentum and
AdamW, pytree-based, with global-norm clipping and LR schedules.

Optimizer states carry their own sharding story: under pjit the caller
passes opt-state shardings from launch.shardings.zero1_specs (ZeRO-1:
moments sharded over the data axis on top of the param sharding - without
it grok-1's 314B x 8B of AdamW moments cannot fit).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | sgdm
    lr: float = 1e-3
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | step | const  (paper: step /10)
    step_decay_every: int = 0
    step_decay_rate: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def schedule_lr(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps) /
                     max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        base = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "step":
        k = jnp.floor(step / max(cfg.step_decay_every, 1))
        base = cfg.step_decay_rate**k
    else:
        base = jnp.ones(())
    return cfg.lr * warm * base


def init_state(cfg: OptConfig, params) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    if cfg.kind == "adamw":
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}
    return {"m": jax.tree.map(zeros, params)}


def _clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def apply_updates(cfg: OptConfig, params, opt_state: dict, grads, step):
    """Returns (new_params, new_opt_state, metrics)."""
    lr = schedule_lr(cfg, step)
    if cfg.clip_norm > 0:
        grads, gnorm = _clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = jnp.zeros(())

    if cfg.kind == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        t = step.astype(jnp.float32) + 1.0
        corr1 = 1.0 - b1**t
        corr2 = 1.0 - b2**t

        def upd(p, g, m, v):
            g32 = g.astype(m.dtype)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mh = m / corr1
            vh = v / corr2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if cfg.weight_decay > 0:
                delta = delta + cfg.weight_decay * p.astype(m.dtype)
            return (p.astype(m.dtype) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(opt_state["m"])
        flat_v = tdef.flatten_up_to(opt_state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}, {"lr": lr, "grad_norm": gnorm}

    # SGD + momentum
    def upd(p, g, m):
        g32 = g.astype(m.dtype)
        if cfg.weight_decay > 0:
            g32 = g32 + cfg.weight_decay * p.astype(m.dtype)
        m = cfg.momentum * m + g32
        return (p.astype(m.dtype) - lr * m).astype(p.dtype), m

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    return new_p, {"m": new_m}, {"lr": lr, "grad_norm": gnorm}
