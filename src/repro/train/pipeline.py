"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Stages are laid out over a mesh axis; microbatches stream through the
classic (M + S - 1)-tick schedule. Differentiable end-to-end (ppermute has
a transpose), so the same construct serves training. This is the PP option
of the parallelism suite (DP/TP/EP/SP live in launch.shardings via GSPMD;
PP is explicit because GSPMD cannot infer a schedule).

The dry-run production mesh keeps TP on the "model" axis - PP is most
useful when a pod boundary (the "pod" axis) has thin interconnect; see
README §Parallelism for when to prefer which.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def pipeline_apply(stage_fn: Callable, n_stages: int, mesh: Mesh,
                   axis: str = "pipe") -> Callable:
    """Build pipelined_fn(stage_params, x_microbatches) -> outputs.

    stage_params leaves: (n_stages, ...) - sharded one stage per device
    along ``axis``. x_microbatches: (M, mb, ...) - replicated in, outputs
    (M, mb, ...) replicated out.
    """

    def per_device(params_local, x_all):
        # params_local leaves: (1, ...) local stage slice
        params_stage = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        m = x_all.shape[0]
        n_ticks = m + n_stages - 1
        mb_shape = x_all.shape[1:]
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            state = carry  # activation arriving from the previous stage
            feed = jax.lax.dynamic_index_in_dim(
                x_all, jnp.minimum(t, m - 1), axis=0, keepdims=False
            )
            inp = jnp.where(sid == 0, feed, state)
            out = stage_fn(params_stage, inp)
            nxt = jax.lax.ppermute(out, axis, perm)
            emit = jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out))
            return nxt, emit

        zeros = jnp.zeros(mb_shape, x_all.dtype)
        _, emits = jax.lax.scan(tick, zeros, jnp.arange(n_ticks))
        # outputs for microbatch j leave the last stage at tick j+n_stages-1
        outs = jax.lax.dynamic_slice_in_dim(emits, n_stages - 1, m, axis=0)
        # replicate to every device so the loss is computable anywhere
        outs = jax.lax.psum(outs, axis)
        return outs

    return shard_map(
        per_device, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )


def stack_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""
    def r(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])

    return jax.tree.map(r, layer_params)


def scan_stage(layer_fn: Callable) -> Callable:
    """stage_fn that scans layer_fn over the stage's layer slice."""

    def stage(params_stage, x):
        def body(x, p):
            return layer_fn(p, x), None

        x, _ = jax.lax.scan(body, x, params_stage)
        return x

    return stage
