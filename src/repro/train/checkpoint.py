"""Atomic, elastic checkpointing.

Fault-tolerance story (1000+-node posture, documented in README):
  * atomic: write to <dir>/.tmp-<step> then os.replace -> a crash mid-save
    never corrupts the latest checkpoint.
  * restartable: manifest carries step + data-pipeline state + RNG key, so
    `--resume` continues the exact stream.
  * elastic: arrays are saved as full host arrays (device_get of the
    addressable global array); restore re-shards onto ANY mesh via
    device_put with the target shardings - pods can come back smaller or
    larger (ZeRO/TP layout changes are re-derived, not stored).
  * latest-k retention GC.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": int(step), "extra": extra or {}, "n_arrays": len(arrays)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``template``. If ``shardings`` (a
    matching pytree of jax.sharding.Sharding) is given, arrays are placed
    sharded - this is the elastic-remesh path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    loaded = np.load(os.path.join(d, "arrays.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    sflat = None
    if shardings is not None:
        sflat = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = loaded[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if sflat is not None:
            leaves.append(jax.device_put(arr, sflat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest
