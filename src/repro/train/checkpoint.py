"""Atomic, elastic checkpointing.

Fault-tolerance story (1000+-node posture, documented in README):
  * atomic: write to <dir>/.tmp-<step> then os.replace -> a crash mid-save
    never corrupts the latest checkpoint.
  * restartable: manifest carries step + data-pipeline state + RNG key, so
    `--resume` continues the exact stream.
  * elastic: arrays are saved as full host arrays (device_get of the
    addressable global array); restore re-shards onto ANY mesh via
    device_put with the target shardings - pods can come back smaller or
    larger (ZeRO/TP layout changes are re-derived, not stored).
  * latest-k retention GC.

Two restore paths:
  * ``save``/``restore`` - template-driven (training state: the caller owns
    the structure).
  * ``save_pytree``/``load_pytree`` - template-FREE: the tree structure is
    serialized as a JSON spec next to the arrays, so serving artifacts
    (``serve.deployed.save_artifact``) boot with no model code run first.
    Leaf dtypes round-trip exactly (int8 kernel blocks stay int8 - npz is
    the at-rest format, no float detour), and the deployment dataclasses
    (``DeployedWeight`` / ``StackedWeight`` / ``ServingParams``) serialize
    their static geometry into the spec - EXCEPT the mesh, which is a
    placement decision of the loading host, never of the artifact.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": int(step), "extra": extra or {}, "n_arrays": len(arrays)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


# ---------------------------------------------------------------------------
# Template-free pytrees: JSON structure spec + ordered array payload
# ---------------------------------------------------------------------------


def _deploy_mod():
    from ..core import deploy as D  # local: keep train importable standalone
    return D


def _serving_cls():
    from ..serve.deployed import ServingParams
    return ServingParams


def tree_spec(tree: Any, leaves: List[np.ndarray],
              _memo: Optional[dict] = None) -> Any:
    """Recursively describe ``tree`` as JSON, appending array leaves (host
    numpy, dtype preserved - int8 stays int8) to ``leaves`` in order.

    Leaves that are the SAME object (by identity) are stored once and
    referenced by the same index - a two-tier serving artifact whose draft
    shares the target's dense leaves by reference pays for them once."""
    D = _deploy_mod()
    if _memo is None:
        _memo = {}
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, D.DeployedWeight):
        if tree.mesh is not None:
            raise ValueError(
                "serialize placement-free packings: unshard_weight() first "
                "(mesh is excluded from artifact aux by design)")
        return {"t": "deployed", "d_in": tree.d_in, "d_out": tree.d_out,
                "bits": tree.bits,
                "packed": [tree_spec(p, leaves, _memo)
                           for p in tree.packed]}
    if isinstance(tree, D.StackedWeight):
        if tree.mesh is not None:
            raise ValueError(
                "serialize placement-free stacks (mesh excluded from "
                "artifact aux); restack on the serving host's mesh")
        return {"t": "stacked", "d_in": tree.d_in, "d_out": tree.d_out,
                "bits": tree.bits,
                "arrays": [tree_spec(getattr(tree, k), leaves, _memo)
                           for k in ("blocks", "scales", "row_idx", "nnz",
                                     "col_inv")]}
    if isinstance(tree, _serving_cls()):
        return {"t": "serving_params",
                "fields": [tree_spec(getattr(tree, k), leaves, _memo)
                           for k in ("embed", "final_ln", "layers", "head",
                                     "mm_proj", "head_t")]}
    if isinstance(tree, dict):
        return {"t": "dict", "items": [[str(k), tree_spec(v, leaves, _memo)]
                                       for k, v in tree.items()]}
    if isinstance(tree, (list, tuple)):
        return {"t": "list" if isinstance(tree, list) else "tuple",
                "items": [tree_spec(v, leaves, _memo) for v in tree]}
    if isinstance(tree, (bool, int, float, str)):
        return {"t": "py", "v": tree}
    if id(tree) in _memo:
        i = _memo[id(tree)]
        arr = leaves[i]
    else:
        arr = np.asarray(jax.device_get(tree))
        leaves.append(arr)
        i = len(leaves) - 1
        _memo[id(tree)] = i
    return {"t": "arr", "i": i, "dtype": str(arr.dtype),
            "shape": list(arr.shape)}


def tree_from_spec(spec: Any, leaves: List[np.ndarray],
                   device: bool = True) -> Any:
    """Inverse of :func:`tree_spec`."""
    D = _deploy_mod()
    t = spec["t"]
    if t == "none":
        return None
    if t == "arr":
        arr = np.asarray(leaves[spec["i"]])
        if str(arr.dtype) != spec["dtype"]:
            arr = arr.astype(spec["dtype"])
        return jax.numpy.asarray(arr) if device else arr
    if t == "py":
        return spec["v"]
    if t == "dict":
        return {k: tree_from_spec(v, leaves, device)
                for k, v in spec["items"]}
    if t in ("list", "tuple"):
        out = [tree_from_spec(v, leaves, device) for v in spec["items"]]
        return out if t == "list" else tuple(out)
    if t == "deployed":
        return D.DeployedWeight(
            [tree_from_spec(p, leaves, device) for p in spec["packed"]],
            spec["d_in"], spec["d_out"], spec["bits"])
    if t == "stacked":
        blocks, scales, row_idx, nnz, col_inv = (
            tree_from_spec(a, leaves, device) for a in spec["arrays"])
        return D.StackedWeight(blocks, scales, row_idx, nnz, spec["d_in"],
                               spec["d_out"], spec["bits"], col_inv=col_inv)
    if t == "serving_params":
        return _serving_cls()(*(tree_from_spec(f, leaves, device)
                                for f in spec["fields"]))
    raise ValueError(f"unknown tree-spec node type {t!r}")


def save_pytree(ckpt_dir: str, tree: Any, extra: Optional[dict] = None,
                step: int = 0) -> str:
    """Atomic template-free save: structure into the manifest, array leaves
    (dtype-exact) into the npz."""
    leaves: List[np.ndarray] = []
    spec = tree_spec(tree, leaves)
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i:06d}": a for i, a in enumerate(leaves)})
    manifest = {"step": int(step), "extra": extra or {}, "spec": spec,
                "n_arrays": len(leaves)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_pytree(ckpt_dir: str, step: Optional[int] = None
                ) -> Tuple[Any, dict]:
    """Load a :func:`save_pytree` directory with no template. Returns
    (tree, manifest)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if "spec" not in manifest:
        raise ValueError(
            f"{d} was written by save() (template-driven) - use restore()")
    loaded = np.load(os.path.join(d, "arrays.npz"))
    leaves = [loaded[f"leaf_{i:06d}"] for i in range(manifest["n_arrays"])]
    return tree_from_spec(manifest["spec"], leaves), manifest


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``template``. If ``shardings`` (a
    matching pytree of jax.sharding.Sharding) is given, arrays are placed
    sharded - this is the elastic-remesh path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    loaded = np.load(os.path.join(d, "arrays.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    sflat = None
    if shardings is not None:
        sflat = [s for _, s in jax.tree_util.tree_flatten_with_path(shardings)[0]]
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = loaded[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if sflat is not None:
            leaves.append(jax.device_put(arr, sflat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest
