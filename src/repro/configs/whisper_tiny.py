"""whisper-tiny - exact assigned config [arXiv:2212.04356; enc-dec, conv frontend stubbed]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865, enc_layers=4, enc_seq=1500, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-tiny-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, enc_layers=2, enc_seq=32, tie_embeddings=True, remat="none",
)
