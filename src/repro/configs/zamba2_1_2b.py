"""zamba2-1.2b - exact assigned config [arXiv:2411.15242; mamba2 + shared attn blocks]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_expand=2, ssm_chunk=256,
    attn_every=6, window=4096,  # windowed shared-attn KV for long-context serving
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, ssm_state=16, ssm_expand=2, ssm_chunk=16,
    attn_every=2, window=64, remat="none",
)
