"""stablelm-12b - exact assigned config [hf:stabilityai/stablelm-2-12b]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352, head_dim=160,
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, remat="none",
)
