"""VGG16 on CIFAR - the paper's own test network (§V)."""
from repro.core.cim_layer import CIMConfig
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig
from repro.models.cnn import VGG16_CFG, VGG_SMALL_CFG

FULL_PLAN = VGG16_CFG
SMALL_PLAN = VGG_SMALL_CFG

def cim_config(w_bits=8, a_bits=4, alpha=16, n=16, lambda_g=1e-4, mode="qat"):
    """Paper settings: alpha=N=16 (§V.B.1)."""
    return CIMConfig(
        quant=QuantConfig(w_bits=w_bits, a_bits=a_bits, group_size=alpha),
        sparsity=SparsityConfig(alpha=alpha, n=n, lambda_g=lambda_g),
        mode=mode,
    )
