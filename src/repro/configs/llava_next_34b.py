"""llava-next-34b - exact assigned config [hf:llava-hf/llava-v1.6; vlm backbone, anyres frontend stubbed]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, head_dim=128, n_patches=576, rope_theta=5e6,
)

SMOKE = ModelConfig(
    name="llava-next-34b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, n_patches=8, remat="none",
)
