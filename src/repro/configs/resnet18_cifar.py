"""ResNet18 on CIFAR - the paper's own test network (§V)."""
from repro.core.cim_layer import CIMConfig
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig
from repro.models.cnn import RESNET18_STAGES, RESNET_SMALL_STAGES

FULL_STAGES = RESNET18_STAGES
SMALL_STAGES = RESNET_SMALL_STAGES

def cim_config(w_bits=8, a_bits=4, alpha=16, n=16, lambda_g=1e-4, mode="qat"):
    return CIMConfig(
        quant=QuantConfig(w_bits=w_bits, a_bits=a_bits, group_size=alpha),
        sparsity=SparsityConfig(alpha=alpha, n=n, lambda_g=lambda_g),
        mode=mode,
    )
