"""grok-1-314b - exact assigned config [hf:xai-org/grok-1; 8e top-2]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072, head_dim=128, n_experts=8, top_k=2,
    expert_split=2,  # 8 experts -> 16 sub-experts to match the 16-way mesh axis
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, n_experts=4, top_k=2, remat="none",
)
