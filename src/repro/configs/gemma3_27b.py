"""gemma3-27b - exact assigned config [hf:google/gemma-3-27b; 5:1 local:global, 128k]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
    vocab=262144, head_dim=128, window=1024, local_global_ratio=5,
    rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-27b-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, window=32, local_global_ratio=5,
    tie_embeddings=True, remat="none",
)
