"""yi-6b - exact assigned config [arXiv:2403.04652; llama-arch GQA]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab=64000, head_dim=128, rope_theta=5e6,
)

SMOKE = ModelConfig(
    name="yi-6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, remat="none",
)
