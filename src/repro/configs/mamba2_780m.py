"""mamba2-780m - exact assigned config [arXiv:2405.21060; SSD]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=256, ssm_state=16, ssm_expand=2, ssm_chunk=16, remat="none",
)
