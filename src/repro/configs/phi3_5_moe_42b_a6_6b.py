"""phi3.5-moe-42b-a6.6b - exact assigned config [hf:microsoft/Phi-3.5-MoE-instruct; 16e top-2]."""
from repro.models.config import ModelConfig


CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, head_dim=128, n_experts=16, top_k=2,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, head_dim=16, n_experts=4, top_k=2, remat="none",
)
