"""The multi-tenant serving gateway: one pool, many tenants.

One :class:`Gateway` fronts several :class:`~repro.gateway.tenant.
TenantRuntime`\\ s behind ONE shared :class:`~repro.serve.batching.
PagedKVCache` block pool - the serving analogue of MARS squeezing many
kernel-groups onto a fixed macro fabric. The loop composes three ideas:

  * **simulator-priced admission** - every request is priced by the PR 1
    event-driven simulator (PR 7 refit constants when given) before any
    kernel runs; the :class:`~repro.gateway.admission.AdmissionController`
    applies the documented deadline/quota/overload contract and sheds
    strictly lowest-priority-first.
  * **artifact hot-swap** - between steps a tenant's weights can be
    replaced; a matching uniform envelope swaps in-place with ZERO
    recompiles (jit cache hit, witnessed by the tenant's compile
    counter), anything else re-jits on a staged path with an explicit
    report line.
  * **disaggregated prefill/decode** - with ``prefill_chunk > 0`` long
    prompts are prefilled in fixed-size chunks (first chunk through the
    proven ``prefill_last`` path, continuations through the multi-token
    ``verify_step`` pass the prefix cache's suffix prefill already uses -
    the bit-exactness contract is the same), interleaved with decode
    rounds so an admission can never stall in-flight decodes for more
    than one chunk. ``prefill_device`` additionally pins the chunk
    dispatches to a dedicated device - the mesh-slice form of the same
    split.

Decode rounds are grouped per tenant and padded to the full slot width,
so jit shapes depend on the tenant and the view bucket - never on
occupancy - and stay warm across hot-swaps.

Greedy-only: temperatures > 0 are rejected at construction. Greedy decode
is row-independent (the established batching contract), which is what
makes every tenant's tokens bit-identical to a dedicated single-tenant
``BatchServer`` over the same requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..obs import NULL_METRICS, NULL_TRACER, ScopedMetrics, phase_scope
from ..serve import deployed
from ..serve.batching import PagedKVCache, Request, RequestQueue, Slot
from ..serve.engine import ServeConfig, sample_tokens
from ..serve.prefix import PrefixTrie
from ..serve.server import ServeReport, _percentiles
from .admission import DEFER, SHED, AdmissionController
from .tenant import TenantRegistry, TenantRuntime


@dataclasses.dataclass
class GatewayConfig:
    """Pool + step-loop knobs (the multi-tenant BatchConfig analogue)."""

    n_slots: int = 4
    block_size: int = 8
    n_blocks: int = 96
    view_bucket: int = 2
    idle_wait_s: float = 0.002
    # per-tenant radix-tree prefix KV reuse over the SHARED pool; tries
    # are strictly per tenant - one tenant's prompts never match another's
    prefix_cache: bool = True
    # tokens of pending prefill advanced per gateway step (0 = whole
    # prompt at admission, the BatchServer behavior). With a budget, a
    # long prompt costs each step at most one chunk-sized dispatch while
    # decode rounds keep running every step.
    prefill_chunk: int = 0
    # device index the chunked-prefill dispatches are pinned to (None =
    # default device): the mesh-slice form of prefill/decode
    # disaggregation when >1 device is visible
    prefill_device: Optional[int] = None
    # admission: predicted-backlog ceiling (seconds) and queue bound
    max_backlog_s: float = float("inf")
    max_pending: Optional[int] = None


@dataclasses.dataclass
class SwapEvent:
    """A scheduled mid-run hot-swap: at the top of step ``at_step``,
    tenant ``tenant`` swaps to ``sp`` (and ``cfg`` when given)."""

    at_step: int
    tenant: str
    sp: deployed.ServingParams
    cfg: Optional[ModelConfig] = None


@dataclasses.dataclass
class GatewayReport:
    """Per-tenant ServeReports + gateway-level admission/swap evidence."""

    wall_s: float
    n_steps: int
    per_tenant: Dict[str, ServeReport]
    tenant_meta: Dict[str, dict]  # priority/slo/attainment/goodput/compiles
    shed: List[dict]
    swaps: List[dict]
    admission: dict
    kv_stats: dict
    metrics: Optional[dict] = None

    def to_json(self) -> dict:
        """Grouped BY TENANT: each tenant's ServeReport json merged with
        its SLO/attainment/goodput/compile evidence."""
        tenants = {}
        for name, rep in self.per_tenant.items():
            tenants[name] = {**rep.to_json(), **self.tenant_meta[name]}
        out = {
            "wall_s": round(self.wall_s, 4),
            "n_steps": self.n_steps,
            "tenants": tenants,
            "shed_events": self.shed,
            "n_shed": len(self.shed),
            "swaps": self.swaps,
            "admission": self.admission,
            "kv": self.kv_stats,
        }
        if self.metrics is not None:
            out["metrics"] = self.metrics
        return out


class _TenantAcc:
    """Per-tenant completion accumulators for the final ServeReport."""

    __slots__ = ("outputs", "ttft", "tpot", "queue_wait", "rounds")

    def __init__(self):
        self.outputs: Dict[str, np.ndarray] = {}
        self.ttft: List[float] = []
        self.tpot: List[float] = []
        self.queue_wait: List[float] = []
        self.rounds = 0


class Gateway:
    """Multi-tenant continuous-batching loop over one shared block pool."""

    def __init__(self, tenants, gcfg: Optional[GatewayConfig] = None,
                 scfg: Optional[ServeConfig] = None,
                 controller: Optional[AdmissionController] = None,
                 pricer=None, tracer=None, metrics=None):
        self.tenants = (tenants if isinstance(tenants, TenantRegistry)
                        else TenantRegistry(list(tenants)))
        self.gcfg = gcfg if gcfg is not None else GatewayConfig()
        self.scfg = scfg if scfg is not None else ServeConfig()
        if self.scfg.temperature > 0.0:
            raise ValueError(
                "the gateway is greedy-only (temperature=0): per-tenant "
                "bit-parity with dedicated servers rests on greedy decode "
                "being row-independent")
        if controller is not None and pricer is not None:
            raise ValueError("pass controller OR pricer, not both")
        self.controller = controller if controller is not None else \
            AdmissionController(pricer=pricer,
                                max_backlog_s=self.gcfg.max_backlog_s)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else NULL_METRICS
        self._obs = bool(self.tracer.recording or self.metrics.recording)
        self._tm = {t.name: ScopedMetrics(self.metrics, tenant=t.name)
                    for t in self.tenants}
        # pool geometry: validated equal across tenants by the registry
        self._pool_cfg = next(iter(self.tenants)).cfg
        self._prefill_dev = None
        if self.gcfg.prefill_device is not None:
            devs = jax.devices()
            if self.gcfg.prefill_device >= len(devs):
                raise ValueError(
                    f"prefill_device={self.gcfg.prefill_device} but only "
                    f"{len(devs)} device(s) visible")
            self._prefill_dev = devs[self.gcfg.prefill_device]

    # -- helpers -------------------------------------------------------------

    def _phase(self, name: str, **args):
        return phase_scope(self.tracer, self.metrics, name, **args)

    def _now(self) -> float:
        return time.monotonic() - self._t0

    def _greedy(self, logits) -> np.ndarray:
        return np.asarray(sample_tokens(logits, self._key, self.scfg),
                          np.int32)

    def _bucket_blocks(self, n_blocks: int) -> int:
        vb = self.gcfg.view_bucket
        return -(-max(1, n_blocks) // vb) * vb

    def _put_prefill(self, *arrays):
        """Pin chunked-prefill operands to the dedicated prefill device
        (committed inputs make jit dispatch there), or pass through."""
        if self._prefill_dev is None:
            return arrays
        return tuple(jax.device_put(a, self._prefill_dev) for a in arrays)

    # -- admission -----------------------------------------------------------

    def _worst_blocks(self, req: Request, kv: PagedKVCache) -> int:
        return kv.blocks_for(len(req.prompt) + req.max_new_tokens)

    def _reserved(self, slots: List[Optional[Slot]], kv: PagedKVCache) -> int:
        r = 0
        for i, s in enumerate(slots):
            if s is not None:
                r += max(0, kv.blocks_for(s.worst_positions)
                         - len(kv.tables[i]))
        return r

    def _record_shed(self, req: Request, reason: str, now: float) -> None:
        ev = self.controller.record_shed(req, reason, now)
        self.metrics.counter("gateway_shed_total", tenant=req.tenant,
                             reason=reason).inc()
        self._shed.append(ev.to_json())

    def _evict_tries(self, need: int, first: str) -> None:
        """Free cold cached prefixes: the admitting tenant's trie first,
        then the others (pool capacity is shared, so any tenant's cold
        prefixes are fair game - trie ISOLATION is about matching, not
        residency)."""
        order = [first] + [n for n in self._tries if n != first]
        for name in order:
            if need <= 0:
                return
            need -= self._tries[name].evict(need)

    def _admit(self, q: RequestQueue, slots: List[Optional[Slot]],
               kv: PagedKVCache, now: float) -> bool:
        progressed = False
        for i in range(self.gcfg.n_slots):
            if slots[i] is not None:
                continue
            while True:
                req = q.pop_ready(now)
                if req is None:
                    return progressed
                rt = self.tenants[req.tenant]
                price = self.controller.price(rt, req)
                verdict, reason = self.controller.decide(rt, req, now, price)
                if verdict == SHED:
                    self._record_shed(req, reason, now)
                    progressed = True
                    continue  # this slot tries the next queued request
                if verdict == DEFER:
                    self.controller.record_defer()
                    q.requeue(req)
                    return progressed  # retry next step (head-of-line)
                wb = self._worst_blocks(req, kv)
                if wb > kv.n_blocks - 1:
                    raise ValueError(
                        f"{req.rid} (tenant {req.tenant}): needs {wb} "
                        f"blocks, pool has {kv.n_blocks - 1} - raise "
                        "n_blocks/block_size")
                trie = self._tries.get(req.tenant)
                shared: List[int] = []
                if trie is not None:
                    shared = trie.match(req.prompt)
                    if shared:
                        kv.adopt(i, shared)
                    if self._obs:
                        tm = self._tm[req.tenant]
                        tm.counter("prefix_lookups").inc()
                        if shared:
                            tm.counter("prefix_hits").inc()
                need = wb - len(shared)
                avail = kv.free_blocks - self._reserved(slots, kv)
                if need > avail and self._tries:
                    self._evict_tries(need - avail, req.tenant)
                    avail = kv.free_blocks - self._reserved(slots, kv)
                if need > avail:
                    kv.free_slot(i)  # roll back adoption - leaks nothing
                    q.requeue(req)  # backpressure: wait for a drain
                    return progressed
                self._start_slot(i, rt, req, kv, slots, len(shared),
                                 queue_wait=max(0.0,
                                                now - max(req.arrival, 0.0)))
                self.controller.commit(rt, req, price)
                self._price[i] = price
                progressed = True
                break
        return progressed

    # -- prefill -------------------------------------------------------------

    def _start_slot(self, i: int, rt: TenantRuntime, req: Request,
                    kv: PagedKVCache, slots: List[Optional[Slot]],
                    n_shared: int, queue_wait: float) -> None:
        now = self._now()
        slots[i] = Slot(req=req, pos=len(req.prompt), next_token=-1, out=[],
                        t_admit=now, token_times=[], queue_wait_s=queue_wait,
                        prefix_tokens=n_shared * self.gcfg.block_size)
        self._pf[i] = n_shared * self.gcfg.block_size  # prefilled positions
        if self.gcfg.prefill_chunk <= 0:
            # unchunked: the whole prompt lands now (BatchServer behavior)
            with self._phase("prefill", rid=req.rid, tenant=rt.name,
                             slot=i, shared_blocks=n_shared):
                while i in self._pf:
                    self._advance_one(i, rt, kv, slots,
                                      len(req.prompt) - self._pf[i])

    def _advance_prefills(self, slots: List[Optional[Slot]],
                          kv: PagedKVCache) -> bool:
        """Spend this step's chunk budget on pending prefills, oldest
        first. Decode rounds run regardless - this is the interleaved
        form of the prefill/decode split."""
        budget = self.gcfg.prefill_chunk
        progressed = False
        for i in sorted(self._pf, key=lambda j: slots[j].t_admit):
            if budget <= 0:
                break
            rt = self.tenants[slots[i].req.tenant]
            with self._phase("prefill_chunk", rid=slots[i].req.rid,
                             tenant=rt.name, slot=i):
                budget -= self._advance_one(i, rt, kv, slots, budget)
            progressed = True
        return progressed

    def _advance_one(self, i: int, rt: TenantRuntime, kv: PagedKVCache,
                     slots: List[Optional[Slot]], budget: int) -> int:
        """Advance slot ``i``'s prefill by one chunk (<= budget tokens);
        returns tokens consumed. Completion emits the first token."""
        s = slots[i]
        prompt = s.req.prompt
        tlen = len(prompt)
        m = self._pf[i]
        chunk = self.gcfg.prefill_chunk if self.gcfg.prefill_chunk > 0 \
            else tlen
        take = min(budget, chunk, tlen - m)
        bs = self.gcfg.block_size
        cfg = rt.cfg
        if m == 0:
            # first chunk: the proven full-prefill path at a fixed pad
            # width (stable jit shapes across prompts)
            s_pad = -(-chunk // bs) * bs
            toks = np.pad(prompt[:take], (0, s_pad - take))[None]
            args = self._put_prefill(jnp.asarray(toks),
                                     jnp.asarray(take, jnp.int32))
            logits, k, v = rt._prefill(rt.params, *args, cfg=cfg)
            kv.write_prefill(i, k[:, 0], v[:, 0], take)
            last = logits  # (1, V) at position take-1
        else:
            # continuation: ONE multi-token verify pass over the gathered
            # views - the suffix-prefill path's bit-exactness contract
            t_pad = chunk
            kv.ensure(i, m + take)
            nv = self._bucket_blocks(kv.blocks_for(m + t_pad))
            toks = np.pad(prompt[m:m + take], (0, t_pad - take))[None]
            vk, vv = kv.gather(nv, tier=0, slots=[i])
            args = self._put_prefill(vk, vv, jnp.asarray([m], jnp.int32),
                                     jnp.asarray(toks))
            logits, ks, vs = rt._verify(rt.params, *args, cfg=cfg)
            ks, vs = np.asarray(ks), np.asarray(vs)
            kv.write_run(i, m, ks[:, 0, :take], vs[:, 0, :take])
            last = logits[:, take - 1]  # (1, V)
        m += take
        self._pf[i] = m
        if m >= tlen:
            del self._pf[i]
            if self._tries.get(rt.name) is not None:
                nf = tlen // bs
                if nf:
                    self._tries[rt.name].insert(prompt[: nf * bs],
                                                kv.tables[i][:nf])
            tok = int(self._greedy(last)[0])
            now = self._now()
            s.next_token = tok
            s.out.append(tok)
            s.token_times.append(now)
        return take

    # -- decode --------------------------------------------------------------

    def _decode_round(self, rt: TenantRuntime, grp: List[int],
                      slots: List[Optional[Slot]], kv: PagedKVCache) -> None:
        """One greedy decode step for ONE tenant's active slots, padded to
        the full slot width so jit shapes are occupancy-independent."""
        for i in grp:
            kv.ensure(i, slots[i].pos + 1)
        nv = self._bucket_blocks(max(len(kv.tables[i]) for i in grp))
        rows = grp + [grp[-1]] * (self.gcfg.n_slots - len(grp))
        vk, vv = kv.gather(nv, tier=0, slots=rows)
        pos = np.array([slots[i].pos for i in rows], np.int32)
        toks = np.array([[slots[i].next_token] for i in rows], np.int32)
        with self._phase("decode_round", tenant=rt.name, n_active=len(grp)):
            logits, k_new, v_new = rt._decode(
                rt.params, vk, vv, jnp.asarray(pos), jnp.asarray(toks),
                cfg=rt.cfg)
            sampled = self._greedy(logits)
        k_new, v_new = np.asarray(k_new), np.asarray(v_new)
        now = self._now()
        for j, i in enumerate(grp):
            s = slots[i]
            kv.write_run(i, s.pos, k_new[:, j:j + 1], v_new[:, j:j + 1])
            tok = int(sampled[j])
            s.pos += 1
            s.out.append(tok)
            s.token_times.append(now)
            s.next_token = tok
        self._acc[rt.name].rounds += 1
        if self._obs:
            self._tm[rt.name].counter("decode_steps").inc()
            self._tm[rt.name].gauge("slots_active").set(len(grp))

    # -- the loop ------------------------------------------------------------

    def run(self, requests: List[Request],
            swaps: Optional[List[SwapEvent]] = None) -> GatewayReport:
        gcfg = self.gcfg
        for r in requests:
            if r.tenant not in self.tenants:
                raise ValueError(
                    f"request {r.rid}: unknown tenant {r.tenant!r} - "
                    f"gateway serves {self.tenants.names}")
        q = RequestQueue(max_pending=gcfg.max_pending)
        self._t0 = time.monotonic()
        self._shed: List[dict] = []
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            dropped = q.push(r)
            if dropped is not None:
                self._record_shed(dropped, "queue_overflow", 0.0)
        kv = PagedKVCache(self._pool_cfg, gcfg.n_slots, gcfg.n_blocks,
                          gcfg.block_size)
        self._tries: Dict[str, PrefixTrie] = (
            {t.name: PrefixTrie(kv) for t in self.tenants}
            if gcfg.prefix_cache else {})
        slots: List[Optional[Slot]] = [None] * gcfg.n_slots
        self._pf: Dict[int, int] = {}  # slot -> prefilled positions
        self._price: Dict[int, object] = {}
        self._acc: Dict[str, _TenantAcc] = {t.name: _TenantAcc()
                                            for t in self.tenants}
        self._key = jax.random.PRNGKey(self.scfg.seed)
        pending_swaps = sorted(swaps or [], key=lambda e: e.at_step)
        swap_reports: List[dict] = []
        step = 0

        def finish(i: int) -> None:
            s = slots[i]
            acc = self._acc[s.req.tenant]
            acc.outputs[s.req.rid] = np.asarray(s.out, np.int32)
            acc.ttft.append(s.token_times[0] - max(s.req.arrival, 0.0))
            acc.queue_wait.append(s.queue_wait_s)
            acc.tpot.extend(np.diff(s.token_times).tolist())
            self._tm[s.req.tenant].counter("requests_finished").inc()
            price = self._price.pop(i, None)
            if price is not None:
                self.controller.release(price)
            kv.free_slot(i)
            slots[i] = None

        while len(q) or any(s is not None for s in slots):
            while pending_swaps and pending_swaps[0].at_step <= step:
                ev = pending_swaps.pop(0)
                rep = self.tenants.hot_swap(ev.tenant, ev.sp, ev.cfg)
                rep = {**rep, "at_step": step}
                swap_reports.append(rep)
                print(f"gateway: hot-swap tenant={rep['tenant']} "
                      f"mode={rep['mode']} tile={rep['tile']} "
                      f"at_step={step}")
            progressed = self._admit(q, slots, kv, self._now())
            # finished straight out of prefill (max_new=1 / instant EOS)
            for i, s in enumerate(slots):
                if s is not None and i not in self._pf and (
                        s.done or s.next_token == self.scfg.eos_id):
                    finish(i)
                    progressed = True
            if self._pf:
                progressed |= self._advance_prefills(slots, kv)
            groups: Dict[str, List[int]] = {}
            for i, s in enumerate(slots):
                if s is not None and i not in self._pf and s.token_times:
                    groups.setdefault(s.req.tenant, []).append(i)
            for name in sorted(groups):
                self._decode_round(self.tenants[name], groups[name],
                                   slots, kv)
                progressed = True
            if groups:
                step += 1
            if self._obs:
                self.metrics.gauge("kv_blocks_in_use").set(kv.blocks_in_use)
                self.metrics.gauge("gateway_backlog_s").set(
                    self.controller.backlog_s)
            for i, s in enumerate(slots):
                if s is not None and i not in self._pf and s.token_times \
                        and (s.done or s.next_token == self.scfg.eos_id):
                    finish(i)
            if not progressed and not groups:
                # nothing runnable: wait for the next arrival (or for
                # wall time to refill a quota window)
                nxt = q.next_arrival()
                wait = gcfg.idle_wait_s if nxt is None \
                    else max(nxt - self._now(), 0.0)
                time.sleep(min(max(wait, 1e-4), gcfg.idle_wait_s))

        wall = self._now()
        return self._report(wall, step, kv, swap_reports)

    # -- reporting -----------------------------------------------------------

    def _report(self, wall: float, n_steps: int, kv: PagedKVCache,
                swap_reports: List[dict]) -> GatewayReport:
        per_tenant: Dict[str, ServeReport] = {}
        meta: Dict[str, dict] = {}
        for t in self.tenants:
            acc = self._acc[t.name]
            total = sum(len(o) for o in acc.outputs.values())
            prefix = None
            if t.name in self._tries:
                prefix = {k: (round(v, 4) if isinstance(v, float) else v)
                          for k, v in self._tries[t.name].stats().items()}
            rep = ServeReport(
                n_requests=len(acc.outputs), total_tokens=total,
                wall_s=wall, n_decode_steps=acc.rounds, ttft_s=acc.ttft,
                tpot_s=acc.tpot, outputs=acc.outputs, kv_stats=kv.stats(),
                queue_wait_s=acc.queue_wait, prefix=prefix, tenant=t.name)
            rep._n_slots = self.gcfg.n_slots
            per_tenant[t.name] = rep
            meta[t.name] = self._tenant_meta(t, rep, wall)
        for srep in swap_reports:
            t = self.tenants[srep["tenant"]]
            srep["recompiles_after_swap"] = (int(t.compiles.n)
                                             - srep["compiles_at_swap"])
        snap = self.metrics.snapshot() or None if self._obs else None
        return GatewayReport(
            wall_s=wall, n_steps=n_steps, per_tenant=per_tenant,
            tenant_meta=meta, shed=self._shed, swaps=swap_reports,
            admission=self.controller.stats(), kv_stats=kv.stats(),
            metrics=snap)

    def _tenant_meta(self, t: TenantRuntime, rep: ServeReport,
                     wall: float) -> dict:
        """SLO attainment + goodput: the per-tenant evidence the bench row
        and the overload test read."""
        att: Dict[str, float] = {}
        good_tokens = rep.total_tokens
        if t.slo.ttft_ms is not None and rep.ttft_s:
            target = t.slo.ttft_ms / 1e3
            met = [x <= target for x in rep.ttft_s]
            att["ttft"] = round(sum(met) / len(met), 4)
            att["ttft_p50_ms"] = round(
                _percentiles(rep.ttft_s)["p50"] * 1e3, 3)
            # goodput counts only tokens of requests that met their TTFT
            good_tokens = sum(
                len(o) for ok, o in zip(met, rep.outputs.values()) if ok)
        if t.slo.tpot_ms is not None and rep.tpot_s:
            target = t.slo.tpot_ms / 1e3
            att["tpot"] = round(
                sum(x <= target for x in rep.tpot_s) / len(rep.tpot_s), 4)
        goodput = good_tokens / wall if wall > 0 else 0.0
        return {
            "priority": t.priority,
            "slo": t.slo.to_json() or None,
            "slo_attainment": att or None,
            "goodput_tokens_per_s": round(goodput, 2),
            "compiles": int(t.compiles.n),
        }
