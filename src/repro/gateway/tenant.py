"""Tenant bindings: an offline artifact compiled into a serving runtime.

A tenant is one model behind the gateway: a PR 4 serving artifact (uniform
BSR packing, optionally two-tier) bound to the compiled scan runtime
(``serve.stacked``), plus the policy the gateway prices it by - priority,
SLO targets, a token-rate quota, and the sparsity the admission simulator
prices its requests at.

Hot-swap contract (the "pack once, swap without recompiling" promise of
the artifact flow):

  * **in-place** - the incoming packing's stacked envelope has the SAME
    treedef and leaf shapes/dtypes as the serving one and the ModelConfig
    is equal. The new weights are handed to the SAME jitted callables;
    jax's jit cache is keyed on (treedef, shapes, dtypes, statics), so the
    next step is a cache hit - zero recompiles, verified by the tenant's
    :class:`CompileCounter`.
  * **staged** - anything else with the same KV geometry (e.g. a different
    uniform tile): the runtime re-stacks and re-jits; the next step traces
    fresh kernels, and the swap report says so explicitly.
  * **rejected** - a packing whose KV geometry (n_layers, KV heads, head
    dim, dtype) differs from the serving one can never share the
    gateway's block pool and raises instead of swapping.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from ..models.config import ModelConfig
from ..serve import deployed, stacked


@dataclasses.dataclass(frozen=True)
class TenantSLO:
    """Per-tenant service objectives the admission controller gates on.

    ``ttft_ms`` / ``tpot_ms`` are latency targets (p50, reported as
    attainment fractions); ``token_rate`` is an admission quota in
    tokens/s - a tenant over it has its requests DEFERRED (smoothed),
    never shed. All fields are optional: None means no target."""

    ttft_ms: Optional[float] = None
    tpot_ms: Optional[float] = None
    token_rate: Optional[float] = None

    def __post_init__(self):
        if self.token_rate is not None and self.token_rate <= 0:
            raise ValueError(f"token_rate must be > 0, got {self.token_rate}")

    def to_json(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    @classmethod
    def from_json(cls, obj: Optional[dict]) -> "TenantSLO":
        obj = obj or {}
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(obj) - known
        if bad:
            raise ValueError(f"unknown SLO field(s) {sorted(bad)} - "
                             f"expected {sorted(known)}")
        return cls(**obj)


class CompileCounter:
    """Counts TRACES of a tenant's jitted serving fns.

    The increment lives inside the traced function, so it runs only when
    jax actually traces (first call per shape/static combination) - a jit
    cache hit leaves it untouched. This is the evidence the hot-swap
    contract is judged by: an in-place swap followed by warm-shape steps
    must leave ``n`` unchanged."""

    __slots__ = ("n",)

    def __init__(self):
        self.n = 0


def _counted(fn, counter: CompileCounter):
    def wrapped(params, *args, cfg):
        counter.n += 1  # trace-time only: retraces are what we count
        return fn(params, *args, cfg=cfg)
    return wrapped


def envelope_signature(params) -> Tuple:
    """(treedef, ((shape, dtype), ...)) of a stacked envelope - equality
    of two signatures is exactly the jit-cache-hit condition for the
    weight argument."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return (treedef, tuple((getattr(l, "shape", ()),
                            str(getattr(l, "dtype", type(l).__name__)))
                           for l in leaves))


def kv_geometry(cfg: ModelConfig) -> Tuple:
    """The block-pool shape a config demands: every tenant behind one
    shared :class:`~repro.serve.batching.PagedKVCache` must agree on it."""
    return (cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh,
            str(np.dtype(cfg.param_dtype)))


class TenantRuntime:
    """One tenant's compiled serving state + swap machinery."""

    def __init__(self, name: str, cfg: ModelConfig,
                 sp: deployed.ServingParams, priority: int = 0,
                 slo: Optional[TenantSLO] = None, sparsity: float = 0.0,
                 artifact: str = ""):
        if not name:
            raise ValueError("tenant needs a non-empty name")
        deployed._check_family(cfg)
        self.name = name
        self.priority = int(priority)
        self.slo = slo if slo is not None else TenantSLO()
        self.sparsity = float(sparsity)
        self.artifact = artifact
        self.compiles = CompileCounter()
        self.swaps: List[dict] = []
        self._bind(cfg, sp)

    def _bind(self, cfg: ModelConfig, sp: deployed.ServingParams) -> None:
        tiles = deployed.packed_tiles(sp)
        if len(tiles) > 1:
            raise ValueError(
                f"tenant {self.name!r}: packing is non-uniform ({tiles}) - "
                "the gateway serves the stacked scan runtime, which needs "
                "one (bk, bn) for the whole network (pack with "
                "uniform=True)")
        self.cfg = cfg
        self.sp = sp
        self.tile = tiles[0] if tiles else None
        self.params = stacked.stack(sp)
        self._signature = envelope_signature(self.params)
        self._jit()

    def _jit(self) -> None:
        c = self.compiles
        self._prefill = jax.jit(_counted(stacked.prefill_last, c),
                                static_argnames=("cfg",))
        self._decode = jax.jit(_counted(stacked.decode_step_paged, c),
                               static_argnames=("cfg",))
        self._verify = jax.jit(_counted(stacked.verify_step, c),
                               static_argnames=("cfg",))

    @property
    def kv_geometry(self) -> Tuple:
        return kv_geometry(self.cfg)

    def hot_swap(self, sp_new: deployed.ServingParams,
                 cfg_new: Optional[ModelConfig] = None) -> dict:
        """Swap this tenant's weights; returns the swap report
        (mode=inplace|staged, tile, compile count at swap time).

        See the module docstring for the in-place / staged / rejected
        contract. The gateway applies swaps BETWEEN steps, so in-flight
        decode rounds always finish on the packing they started on."""
        cfg_new = cfg_new if cfg_new is not None else self.cfg
        if kv_geometry(cfg_new) != self.kv_geometry:
            raise ValueError(
                f"tenant {self.name!r}: hot-swap KV geometry mismatch - "
                f"serving {self.kv_geometry}, incoming "
                f"{kv_geometry(cfg_new)}; the shared block pool cannot be "
                "reshaped mid-run (boot a new gateway for this artifact)")
        params_new = stacked.stack(sp_new)
        inplace = (cfg_new == self.cfg
                   and envelope_signature(params_new) == self._signature)
        if inplace:
            # same treedef + shapes + statics: handing the new arrays to
            # the SAME jitted callables is a jit cache hit by construction
            self.sp = sp_new
            self.params = params_new
        else:
            self._bind(cfg_new, sp_new)
        report = {
            "tenant": self.name,
            "mode": "inplace" if inplace else "staged",
            "tile": list(self.tile) if self.tile else None,
            "compiles_at_swap": int(self.compiles.n),
        }
        self.swaps.append(report)
        return report


class TenantRegistry:
    """Ordered name -> :class:`TenantRuntime` map behind one gateway."""

    def __init__(self, tenants: List[TenantRuntime]):
        if not tenants:
            raise ValueError("gateway needs at least one tenant")
        self._tenants: Dict[str, TenantRuntime] = {}
        for t in tenants:
            if t.name in self._tenants:
                raise ValueError(f"duplicate tenant name {t.name!r}")
            self._tenants[t.name] = t
        geo = {t.name: t.kv_geometry for t in tenants}
        if len(set(geo.values())) > 1:
            raise ValueError(
                "tenants cannot share one KV block pool: geometries "
                "(n_layers, kv_heads, dh, dtype) differ - " +
                "; ".join(f"{n}={g}" for n, g in geo.items()))

    def __getitem__(self, name: str) -> TenantRuntime:
        try:
            return self._tenants[name]
        except KeyError:
            raise KeyError(
                f"unknown tenant {name!r} - gateway serves "
                f"{sorted(self._tenants)}") from None

    def __contains__(self, name: str) -> bool:
        return name in self._tenants

    def __iter__(self) -> Iterator[TenantRuntime]:
        return iter(self._tenants.values())

    def __len__(self) -> int:
        return len(self._tenants)

    @property
    def names(self) -> List[str]:
        return list(self._tenants)

    def hot_swap(self, name: str, sp_new: deployed.ServingParams,
                 cfg_new: Optional[ModelConfig] = None) -> dict:
        return self[name].hot_swap(sp_new, cfg_new)
