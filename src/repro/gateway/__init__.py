"""repro.gateway - multi-tenant serving over one shared KV block pool.

A :class:`Gateway` fronts several tenants - each a PR 4 offline artifact
bound into a :class:`TenantRuntime` - behind ONE shared
:class:`~repro.serve.batching.PagedKVCache`:

  * :mod:`tenant` - artifact binding, the hot-swap contract (in-place on
    matching envelope / staged re-jit otherwise / rejected on KV-geometry
    mismatch), the trace-counting evidence for "zero recompiles";
  * :mod:`admission` - the simulator-priced admission controller and the
    documented deadline / quota / overload shed contract;
  * :mod:`gateway` - the step loop: priority admission, per-tenant decode
    rounds (bit-identical to dedicated single-tenant servers under greedy
    decode), per-tenant prefix tries over the shared pool, chunked /
    device-pinned prefill so long prompts never stall in-flight decode.

See the README's "Multi-tenant gateway" section for the tenants.json
schema and ``python -m repro.launch.serve --gateway tenants.json``.
"""
from __future__ import annotations

from .admission import (ADMIT, DEFER, SHED,  # noqa: F401
                        AdmissionController, ShedEvent)
from .gateway import (Gateway, GatewayConfig,  # noqa: F401
                      GatewayReport, SwapEvent)
from .tenant import (CompileCounter, TenantRegistry,  # noqa: F401
                     TenantRuntime, TenantSLO, envelope_signature,
                     kv_geometry)

__all__ = [
    "ADMIT", "AdmissionController", "CompileCounter", "DEFER", "Gateway",
    "GatewayConfig", "GatewayReport", "SHED", "ShedEvent", "SwapEvent",
    "TenantRegistry", "TenantRuntime", "TenantSLO", "envelope_signature",
    "kv_geometry",
]
