"""Admission control: the event-driven simulator as the gateway's gate.

Every request is priced BEFORE any kernel runs - predicted prefill seconds
plus per-decode-token seconds at the tenant's (arch, sparsity), from
``sched.pricing.Pricer`` (the PR 1 simulator, calibrated by the PR 7
refit constants when available). The controller then applies the

**Overload contract** (checked in this order, nothing silently dropped):

  1. **deadline** - a request whose PREDICTED completion
     (now + prefill + max_new decode steps) misses its deadline is shed
     immediately (``reason="deadline"``): serving it would burn pool and
     steps on an answer nobody is waiting for.
  2. **quota** - a tenant over its ``token_rate`` quota (admitted tokens
     per elapsed second) has its requests DEFERRED: requeued at the front
     of their priority class and retried once the window refills. Quota
     never sheds - it smooths.
  3. **overload** - when the predicted backlog (sum of admitted-but-
     unfinished request prices) would exceed ``max_backlog_s``, the
     request is shed (``reason="overload"``). The request queue pops
     highest-priority-first, so under overload the surviving admissions
     are exactly the highest-priority prefix that fits the backlog
     budget - lower-priority work is shed STRICTLY before higher-priority
     work within every admission wave.
  4. otherwise - **admit**. Pool backpressure (not enough free KV blocks)
     is handled by the gateway after this verdict: the request is
     requeued, never shed, because blocks drain on their own.

Every shed increments ``gateway_shed_total{tenant=,reason=}`` and appends
a :class:`ShedEvent` to the report.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..sched.pricing import Pricer, RequestPrice
from ..serve.batching import Request
from .tenant import TenantRuntime

ADMIT = "admit"
DEFER = "defer"
SHED = "shed"


@dataclasses.dataclass(frozen=True)
class ShedEvent:
    """One shed request: who, why, when."""

    rid: str
    tenant: str
    priority: int
    reason: str  # "deadline" | "overload" | "queue_overflow"
    t: float

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["t"] = round(d["t"], 4)
        return d


class AdmissionController:
    """Simulator-priced admit/defer/shed decisions over a shared backlog."""

    def __init__(self, pricer: Optional[Pricer] = None,
                 max_backlog_s: float = float("inf")):
        self.pricer = pricer if pricer is not None else Pricer()
        self.max_backlog_s = float(max_backlog_s)
        self.backlog_s = 0.0  # predicted seconds of admitted, unfinished work
        self._admitted_tokens: Dict[str, float] = {}
        self.n_admitted = 0
        self.n_deferred = 0
        self.n_shed = 0
        self.shed_events: List[ShedEvent] = []

    def price(self, tenant: TenantRuntime, req: Request) -> RequestPrice:
        return self.pricer.price_request(
            tenant.cfg, len(req.prompt), req.max_new_tokens,
            sparsity_gs=tenant.sparsity)

    def decide(self, tenant: TenantRuntime, req: Request, now: float,
               price: RequestPrice) -> Tuple[str, str]:
        """(verdict, reason) per the overload contract. Pure decision -
        call :meth:`commit` once the gateway actually starts the request
        (pool backpressure may still requeue an ADMIT verdict)."""
        if req.deadline is not None and now + price.total_s > req.deadline:
            return SHED, "deadline"
        quota = tenant.slo.token_rate
        if quota is not None:
            # rate judged over max(elapsed, 1s): a tenant may burst one
            # second's quota up front instead of trickling in from t=0
            rate = ((self._admitted_tokens.get(tenant.name, 0.0)
                     + req.max_new_tokens) / max(now, 1.0))
            if rate > quota:
                return DEFER, "quota"
        if self.backlog_s + price.total_s > self.max_backlog_s:
            return SHED, "overload"
        return ADMIT, "ok"

    def commit(self, tenant: TenantRuntime, req: Request,
               price: RequestPrice) -> None:
        """Account an actually-started request into the backlog/quota."""
        self.backlog_s += price.total_s
        self._admitted_tokens[tenant.name] = (
            self._admitted_tokens.get(tenant.name, 0.0) + req.max_new_tokens)
        self.n_admitted += 1

    def release(self, price: RequestPrice) -> None:
        """A committed request finished: its predicted cost leaves the
        backlog (quota accounting is a rate and never unwinds)."""
        self.backlog_s = max(0.0, self.backlog_s - price.total_s)

    def record_defer(self) -> None:
        self.n_deferred += 1

    def record_shed(self, req: Request, reason: str, now: float) -> ShedEvent:
        ev = ShedEvent(rid=req.rid, tenant=req.tenant,
                       priority=req.priority, reason=reason, t=now)
        self.shed_events.append(ev)
        self.n_shed += 1
        return ev

    def stats(self) -> dict:
        return {
            "calibrated": self.pricer.calibrated,
            "max_backlog_s": (None if self.max_backlog_s == float("inf")
                              else self.max_backlog_s),
            "backlog_s": round(self.backlog_s, 6),
            "n_admitted": self.n_admitted,
            "n_deferred": self.n_deferred,
            "n_shed": self.n_shed,
        }
