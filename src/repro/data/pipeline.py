"""Deterministic, checkpointable synthetic data pipelines.

No datasets ship with this container, so the pipelines synthesize
structured data a model can genuinely learn (loss decreases):

  * TokenPipeline - order-2 Markov chains over the vocab with Zipfian
    transition tables; per-batch determinism keyed on (seed, step) so a
    restart from a checkpoint replays the exact stream (fault tolerance).
  * ImagePipeline - CIFAR-shaped class-conditional patterns + noise for
    the paper's CNN experiments.

State is just the step counter -> trivially serialized in checkpoints.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0
    order: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab, 512)  # active vocab (keeps tables small)
        probs = rng.zipf(1.5, size=(v, v)).astype(np.float64)
        self._table = probs / probs.sum(1, keepdims=True)
        self._cum = np.cumsum(self._table, axis=1)
        self._v = v

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])
        assert int(state["seed"]) == self.seed, "pipeline seed mismatch"

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        u = rng.random((self.batch, self.seq_len))
        toks = np.zeros((self.batch, self.seq_len), np.int64)
        toks[:, 0] = rng.integers(0, self._v, self.batch)
        for t in range(1, self.seq_len):
            toks[:, t] = np.argmax(
                u[:, t, None] < self._cum[toks[:, t - 1]], axis=1
            )
        self.step += 1
        return {"tokens": toks.astype(np.int32)}


@dataclasses.dataclass
class ImagePipeline:
    """Class-conditional frequency patterns: class c has energy at spatial
    frequency (c+1) - linearly separable enough to train, hard enough that
    pruning/quantization accuracy deltas are measurable."""

    n_classes: int = 10
    batch: int = 64
    hw: int = 32
    channels: int = 3
    seed: int = 0
    step: int = 0
    noise: float = 0.35

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        labels = rng.integers(0, self.n_classes, self.batch)
        xs = np.linspace(0, 2 * np.pi, self.hw, dtype=np.float32)
        xx, yy = np.meshgrid(xs, xs)
        imgs = np.zeros((self.batch, self.hw, self.hw, self.channels), np.float32)
        for i, c in enumerate(labels):
            phase = rng.random() * 2 * np.pi
            base = 0.5 + 0.5 * np.sin((c + 1) * xx + phase) * np.cos((c + 1) * yy)
            for ch in range(self.channels):
                imgs[i, :, :, ch] = base * (0.6 + 0.4 * ch / max(self.channels - 1, 1))
        imgs += rng.standard_normal(imgs.shape).astype(np.float32) * self.noise
        imgs = np.clip(imgs, 0.0, 1.0)
        self.step += 1
        return {"images": imgs, "labels": labels.astype(np.int32)}
