from .pipeline import ImagePipeline, TokenPipeline  # noqa: F401
