"""Self-speculative decoding over two-tier CIM compression, end to end:

  compress target -> re-prune a draft tier from the SAME packing
  -> draft-k-verify continuous batching -> the greedy exactness receipt

The draft tier is a second, higher-sparsity BSR packing of the same
weights: surviving blocks keep the target's exact int8 levels, the tiers
differ only in WHICH blocks exist. Speculation converts the compression
gap into decode throughput while greedy tokens stay bit-identical to
target-only decode - verified below against the compiled scan runtime.

  PYTHONPATH=src python examples/serve_spec.py
"""
import json

import jax
import numpy as np

from repro.models import registry
from repro.sched import search_spec
from repro.serve import BatchConfig, BatchServer, ServeConfig, SpecConfig
from repro.serve import deployed as DP
from repro.serve import spec as SP
from repro.launch.serve import synthetic_trace


def main():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))

    print("[1] target tier: uniform-tile BSR packing at paper sparsity")
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    print("    target:", json.dumps(sp.report()))

    print("[2] draft tier: re-prune the SAME packing at higher sparsity")
    spec_cfg = SpecConfig(k=3, draft_sparsity=0.85)
    draft = SP.draft_serving(cfg, sp, spec_cfg.draft_sparsity)
    print("    draft: ", json.dumps(draft.report()))

    print("[3] simulated operating-point search (reload+compute cost)")
    res = search_spec(cfg, target_sparsity=0.5,
                      draft_sparsities=(0.75, 0.85, 0.95), ks=(2, 3, 4))
    print("    best by modeled tokens/cycle:", json.dumps(res.best))

    print("[4] speculative continuous batching (draft-k-verify rounds)")
    bcfg = BatchConfig(n_slots=4, block_size=8, n_blocks=64)
    srv = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="spec",
                      draft=draft, spec=spec_cfg)
    trace = lambda: synthetic_trace(cfg, n_requests=8, max_prompt=16,
                                    max_new=24)
    srv.run(trace())  # compile
    rep = srv.run(trace())
    print("   ", json.dumps(rep.to_json()["spec"]))

    print("[5] exactness receipt: spec tokens == target-only scan tokens")
    ref = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="scan")
    ref.run(trace())
    want = ref.run(trace())
    for r in trace():
        assert np.array_equal(rep.outputs[r.rid], want.outputs[r.rid]), r.rid
    print(f"    all {len(trace())} request streams bit-identical ✓")
    print("OK")


if __name__ == "__main__":
    main()
