"""Quickstart: the MARS pipeline on one weight matrix in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. quantize with BN-fusion QAT math (eqs. 6-8)
2. structure the sparsity with the CIM-aware group lasso (eq. 4)
3. prune to the (N x alpha) macro tiles
4. pack nonzero group-sets + Fig. 6 index codes (the weight mapping)
5. run the TPU block-sparse kernel and check it against dense
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapping, quant, sparsity
from repro.kernels import ops

key = jax.random.PRNGKey(0)
d_in, d_out = 512, 512
w = jax.random.normal(key, (d_in, d_out)) * 0.1

# --- 1. MARS quantization (weights -> 4-bit symmetric levels) -------------
w_q = quant.mars_weight_quant(w, bits=4, group_size=16)
print(f"quantized: {np.unique(np.round(np.asarray(w_q) * 8)).size} levels, "
      f"|w|max={float(jnp.abs(w_q).max()):.4f}")

# --- 2-3. CIM-aware structured pruning (alpha=N=16 like the paper) --------
reg = sparsity.group_lasso_2d(w, n=16, alpha=16)
print(f"group-lasso regularizer: {float(reg):.2f} (add lambda_g/2 * this to the loss)")
mask = sparsity.prune_mask_2d(w, n=16, alpha=16, target_sparsity=0.75)
w_sparse = np.asarray(w_q * mask)
zg = sparsity.zero_groupset_proportion(mask, 16, 16)
print(f"pruned: {float(sparsity.sparsity_ratio(mask)):.1%} weights zero, "
      f"{float(zg):.1%} group-sets skippable, "
      f"compression {sparsity.compression_rate(float(zg), 4):.0f}x")

# --- 4. macro mapping + index codes (Fig. 5b / Fig. 6) --------------------
packed = mapping.pack_groupsets(w_sparse, alpha=16)
print(f"macro packing: {packed.nnz}/{packed.n_total_groupsets} group-sets stored, "
      f"{packed.index_bits / 1024:.2f} Kb index, {packed.reloads} macro reload(s)")
first, total, spatial, channel = mapping.decode_index(int(packed.codes[0]))
print(f"first index code -> first={first} total={total} "
      f"spatial={spatial} channel={channel}")

# --- 5. the TPU-native kernel (zero blocks never stored or computed) ------
# MXU-aligned tiles: re-prune at the TPU-native (128x128) granularity
mask128 = sparsity.prune_mask_2d(w, n=128, alpha=128, target_sparsity=0.75)
kern = ops.pack_for_kernel(np.asarray(w_q * mask128), bits=4, bk=128, bn=128)
x = jax.random.normal(jax.random.PRNGKey(1), (64, d_in))
y_kernel = ops.bsr_matmul(x, kern)
y_dense = x @ jnp.asarray(np.asarray(w_q * mask128))
err = float(jnp.max(jnp.abs(y_kernel - y_dense)))
print(f"BSR kernel vs dense: max|diff|={err:.2e} "
      f"(density {kern['density']:.2f} -> {1 - kern['density']:.0%} of weight "
      f"bytes never touch VMEM)")
assert err < 1e-3
print("OK")
