"""Walkthrough: schedule a compressed network onto the MARS fabric.

Five steps, mirroring the ``repro.sched`` pipeline:
  1. extract the layer DAG from the network definition;
  2. allocate each layer's surviving group-sets onto 4 cores x 2 macros;
  3. simulate the schedule event-by-event (vs the closed-form model);
  4. search the mapping space for a faster tiling;
  5. execute one scheduled layer on the real Pallas BSR kernel path and
     check the numerics never moved.

Run: PYTHONPATH=src python examples/schedule_network.py
"""
import dataclasses

import jax
import numpy as np

from repro import sched
from repro.core import perf_model as PM
from repro.core.cim_layer import CIMConfig
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig


def main():
    # 1. layer DAG for VGG16-CIFAR with the paper's Table IV sparsity
    graph = sched.vgg16_graph()
    order = graph.topo_order()
    print(f"[1] graph: {len(graph.nodes)} layers, "
          f"{sum(l.macs for l in graph.layers())/1e6:.0f} MMACs/frame")

    # 2. allocate the largest layer and inspect the placement
    name = order[-1]
    alloc = sched.allocate_node(graph.nodes[name])
    print(f"[2] {name}: {alloc.nnz_total} surviving group-sets -> "
          f"loads {[a.nnz for a in alloc.assignments]}, "
          f"{alloc.reload_waves} reload waves, "
          f"imbalance {alloc.imbalance:.2f} "
          f"(conserved: {sched.verify_conservation(alloc)})")

    # 3. event-driven simulation vs the closed-form model
    analytic = PM.summarize(PM.vgg16_cifar_layers())
    sim = sched.simulate(graph, pipeline=True)
    print(f"[3] analytic {analytic.fps:.0f} fps | simulated "
          f"{sim.fps:.0f} fps ({len(sim.events)} events, "
          f"{sim.core_utilization:.0%} core util)")

    # 4. mapping search over tile shapes
    result = sched.search_mapping(graph)
    best = result.best.candidate
    print(f"[4] search: best tile {best.group}x{best.alpha} -> "
          f"{result.best.fps:.0f} fps "
          f"({result.speedup_vs_default:.2f}x vs default mapping)")
    schedule = sched.schedule_from_search(graph, result)

    # 5. run one scheduled layer through deploy_weight -> deployed_matmul
    cim = CIMConfig(
        quant=QuantConfig(w_bits=8, a_bits=8, group_size=16, a_signed=True),
        sparsity=SparsityConfig(alpha=16, n=16, target_sparsity=0.5),
        mode="qat")
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (128, 64))) * 0.2
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (8, 128)))
    layer = dataclasses.replace(schedule.layers[0], name="demo_proj")
    err = sched.verify_layer(x, w, layer, cim, target_sparsity=0.5)
    print(f"[5] scheduled kernel execution matches the dense oracle "
          f"(max err {err:.2e})")


if __name__ == "__main__":
    main()
