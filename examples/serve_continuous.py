"""Continuous batching over compressed (BSR-deployed) weights, end to end:

  schedule search -> deploy_weight packing -> paged-KV continuous batching

and the honesty check that makes it trustworthy: at target_sparsity=0 the
compressed engine's greedy tokens equal the dense QAT engine's, token for
token.

  PYTHONPATH=src python examples/serve_continuous.py
"""
import json

import jax
import numpy as np

from repro.models import registry
from repro.serve import BatchConfig, BatchServer, Request, ServeConfig
from repro.serve import deployed as DP
from repro.launch.serve import synthetic_trace


def main():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))

    print("[1] mapping search over the LM projection graph")
    schedule = DP.default_schedule(cfg)
    print(f"    searched tile (group, alpha) = {schedule.candidate.tile} "
          f"-> serving (bk, bn)")

    print("[2] deploy: pack every CIM projection for the BSR kernel")
    sp = DP.compress(cfg, params, target_sparsity=0.5, schedule=schedule)
    print("   ", json.dumps(sp.report()))

    print("[3] continuous batching over a mixed-length trace")
    bcfg = BatchConfig(n_slots=4, block_size=8, n_blocks=64)
    srv = BatchServer(cfg, sp, ServeConfig(), bcfg, continuous=True)
    trace = lambda: synthetic_trace(cfg, n_requests=8, max_prompt=16,
                                    max_new=24)
    srv.run(trace())  # compile
    rep = srv.run(trace())
    print("   ", json.dumps(rep.to_json()))

    print("[4] honesty check: sparsity-0 compressed tokens == dense tokens")
    from repro.serve import Engine
    sp0 = DP.compress(cfg, params, target_sparsity=0.0, schedule=schedule)
    reqs = trace()[:3]
    srv0 = BatchServer(cfg, sp0, ServeConfig(),
                       BatchConfig(n_slots=2, block_size=8, n_blocks=32))
    rep0 = srv0.run([Request(r.rid, r.prompt, r.max_new_tokens) for r in reqs])
    for r in reqs:
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=r.max_new_tokens))
        want = eng.generate({"tokens": jax.numpy.asarray(r.prompt[None])})[0]
        assert np.array_equal(rep0.outputs[r.rid], want), r.rid
        print(f"    {r.rid}: {rep0.outputs[r.rid].tolist()} == dense ✓")
    print("OK")


if __name__ == "__main__":
    main()
