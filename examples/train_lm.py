"""End-to-end LM training driver (deliverable b): trains a transformer with
the MARS technique enabled (w8a8 QAT + CIM group lasso on every projection)
for a few hundred steps with checkpointing, then deploys one layer through
the block-sparse kernel.

Default is a ~5M-param model sized for this CPU container; --big selects a
updates~100M-param config (same code path - budget permitting).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import TokenPipeline
from repro.models import registry
from repro.models.config import ModelConfig
from repro.train import (OptConfig, TrainConfig, checkpoint,
                         init_train_state, make_train_step)

SMALL = ModelConfig(
    name="lm-5m", family="dense", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=1024, vocab=2048, head_dim=32, dtype="float32",
    remat="none", cim_mode="qat", w_bits=8, a_bits=8, lambda_g=1e-5,
    cim_alpha=16, cim_n=16,
)
BIG = dataclasses.replace(
    SMALL, name="lm-100m", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_ff=3072, vocab=8192, head_dim=64,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--big", action="store_true", help="~100M params")
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = BIG if args.big else SMALL
    fns = registry.model_fns(cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(
        jax.eval_shape(lambda: fns.init_params(cfg, jax.random.PRNGKey(0)))))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, MARS QAT "
          f"w{cfg.w_bits}a{cfg.a_bits} + group lasso (alpha={cfg.cim_alpha})")

    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20,
                                     total_steps=args.steps),
                       ckpt_dir=args.ckpt_dir, ckpt_every=100)
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)
    start = 0
    if args.resume and checkpoint.latest_step(args.ckpt_dir) is not None:
        state, man = checkpoint.restore(args.ckpt_dir, state)
        pipe.restore(man["extra"]["pipe"])
        start = man["step"]
        print(f"resumed at step {start}")

    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    t0 = time.time()
    losses = []
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 25 == 0:
            tps = args.batch * args.seq * (i + 1 - start) / (time.time() - t0)
            print(f"step {i+1}: loss={losses[-1]:.4f} ({tps:.0f} tok/s)")
        if (i + 1) % tcfg.ckpt_every == 0:
            checkpoint.save(tcfg.ckpt_dir, i + 1, state,
                            extra={"pipe": pipe.state()})
    print(f"loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"in {time.time()-t0:.0f}s")

    # deploy one trained projection through the CIM kernel path
    from repro.core import quant as Q, sparsity as S
    from repro.kernels import ops
    w = np.asarray(state["params"]["layers"]["w_up"][0])
    mask = np.asarray(S.prune_mask_2d(jnp.asarray(w), 16, 16, 0.5))
    wq = np.asarray(Q.mars_weight_quant(jnp.asarray(w * mask), cfg.w_bits, 16))
    packed = ops.pack_for_kernel(wq, bits=cfg.w_bits, bk=16, bn=16)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, w.shape[0]))
    err = float(jnp.max(jnp.abs(ops.bsr_matmul(x, packed, bm=8) - x @ jnp.asarray(wq))))
    print(f"deployed layer-0 w_up via BSR kernel: density={packed['density']:.2f}, "
          f"max|diff| vs dense = {err:.2e}")
    print("OK")


if __name__ == "__main__":
    main()
