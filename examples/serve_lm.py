"""Batched serving example (deliverable b): loads (or inits) a model,
serves a batch of requests with prefill + decode, reports tokens/s.

  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-1.2b --smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.serve import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = (registry.get_smoke_config(args.arch, dtype="float32") if args.smoke
           else registry.get_config(args.arch))
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)) * 0.02,
            cfg.param_dtype)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)) * 0.02,
            cfg.param_dtype)

    eng = Engine(cfg, params, ServeConfig(max_new_tokens=args.new_tokens,
                                          temperature=args.temperature))
    print(f"serving {cfg.name} ({cfg.family}): batch={args.batch}, "
          f"prompt={args.prompt_len}, new={args.new_tokens}")
    t0 = time.time()
    out = eng.generate(batch)
    dt = time.time() - t0
    print(f"generated {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s "
          f"({out.size/dt:.1f} tok/s incl. prefill+compile)")
    t0 = time.time()
    out = eng.generate(batch)  # warm
    dt = time.time() - t0
    print(f"warm: {out.size/dt:.1f} tok/s")
    for row in out[:2]:
        print("  sample:", row[:16].tolist())
    print("OK")


if __name__ == "__main__":
    main()
