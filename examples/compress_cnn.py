"""End-to-end MARS pipeline on a CNN (the paper's workflow, §IV-V):

  train with QAT + CIM-aware group lasso  ->  prune to group-sets
  ->  masked retraining                   ->  macro mapping + index codes
  ->  deploy conv1 through the TPU block-sparse kernel
  ->  analytic accelerator speedup for the resulting sparsity

  PYTHONPATH=src python examples/compress_cnn.py [--steps 80]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg16_cifar import SMALL_PLAN, cim_config
from repro.core import mapping, perf_model, sparsity
from repro.data import ImagePipeline
from repro.kernels import ops
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--w-bits", type=int, default=4)
    ap.add_argument("--a-bits", type=int, default=4)
    ap.add_argument("--target-sparsity", type=float, default=0.7)
    args = ap.parse_args()

    cim = cim_config(w_bits=args.w_bits, a_bits=args.a_bits, lambda_g=2e-3)
    params, state = cnn.vgg_init(jax.random.PRNGKey(0), cim, SMALL_PLAN, n_classes=4)
    pipe = ImagePipeline(n_classes=4, batch=16, hw=16)

    def loss_fn(p, st, batch):
        logits, st2 = cnn.vgg_apply(p, st, batch["images"], cim, SMALL_PLAN, train=True)
        ce = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["labels"][:, None], 1))
        return ce + cnn.regularization(p, cim), (ce, st2)

    @jax.jit
    def step(p, st, batch):
        (_, (ce, st2)), g = jax.value_and_grad(loss_fn, has_aux=True)(p, st, batch)
        return jax.tree.map(lambda a, b: a - 0.05 * b, p, g), st2, ce

    print(f"[1] QAT w{args.w_bits}a{args.a_bits} + group lasso (alpha=N=16) ...")
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, state, ce = step(params, state, b)
        if (i + 1) % 20 == 0:
            print(f"    step {i+1}: ce={float(ce):.3f}")

    print(f"[2] prune to {args.target_sparsity:.0%} of (16x16) group-set tiles")
    cim_p = dataclasses.replace(
        cim, sparsity=dataclasses.replace(cim.sparsity,
                                          target_sparsity=args.target_sparsity))
    params = cnn.prune_all(params, cim_p)

    print("[3] masked retraining ...")
    for i in range(args.steps // 3):
        b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, state, ce = step(params, state, b)
    print(f"    final ce={float(ce):.3f}")

    print("[4] macro mapping + index codes per conv layer:")
    sparsities = []
    for li, p in enumerate(cnn.iter_conv_params(params)):
        kh, kw, ci, co = p["w"].shape
        wq = np.asarray(p["w"] * p["mask"]).reshape(kh * kw, ci, co)
        nnz = idx_bits = total = 0
        for pos in range(kh * kw):
            pk = mapping.pack_groupsets(wq[pos], alpha=16)
            nnz += pk.nnz
            idx_bits += pk.index_bits
            total += pk.n_total_groupsets
        sp = 1 - nnz / max(total, 1)
        sparsities.append(sp)
        print(f"    conv{li} ({kh}x{kw}x{ci}x{co}): {sp:.1%} group-sets skipped, "
              f"index {idx_bits/1024:.2f} Kb, "
              f"C.R. {sparsity.compression_rate(sp, args.w_bits):.1f}x")

    print("[5] deploy the deepest conv through the TPU BSR kernel:")
    deep = list(cnn.iter_conv_params(params))[-1]
    kh, kw, ci, co = deep["w"].shape
    from repro.core import quant as Q
    w2d = np.asarray(
        Q.mars_weight_quant(
            (deep["w"] * deep["mask"]).reshape(-1, co), args.w_bits, 16)
    )
    packed = ops.pack_for_kernel(w2d, bits=args.w_bits, bk=16, bn=16)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, w2d.shape[0]))
    y_kern = ops.bsr_matmul(x, packed, bm=32)
    y_ref = x @ jnp.asarray(w2d)
    err = float(jnp.max(jnp.abs(y_kern - y_ref)))
    print(f"    kernel vs dense: max|diff|={err:.2e}, density={packed['density']:.2f}")

    print("[6] analytic MARS accelerator speedup at these sparsities:")
    layers = [perf_model.ConvLayer(3, 3, ci, co, 16 // (2**i), 16 // (2**i), s)
              for i, ((ci, co), s) in enumerate(
                  zip([(3, 32), (32, 64), (64, 128)], sparsities))]
    net = perf_model.summarize(layers, args.w_bits, args.a_bits)
    print(f"    fps={net.fps:.0f} (dense baseline {net.fps_dense:.0f}) "
          f"-> speedup {net.speedup:.2f}x, macro eff {net.macro_tops_w:.1f} TOPS/W")
    print("OK")


if __name__ == "__main__":
    main()
