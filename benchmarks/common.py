"""Shared helpers for the paper-table benchmarks (CPU-budget scale)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vgg16_cifar import SMALL_PLAN, cim_config
from repro.data import ImagePipeline
from repro.models import cnn


def train_small_vgg(cim, steps=80, lr=0.05, n_classes=4, hw=16, batch=16,
                    seed=0, params=None, state=None, reg=True):
    """Train the small VGG on synthetic CIFAR-like data; returns
    (params, state, final_acc, losses)."""
    if params is None:
        params, state = cnn.vgg_init(jax.random.PRNGKey(seed), cim, SMALL_PLAN,
                                     n_classes=n_classes)
    pipe = ImagePipeline(n_classes=n_classes, batch=batch, hw=hw, seed=seed)

    def loss_fn(p, st, batch):
        logits, st2 = cnn.vgg_apply(p, st, batch["images"], cim, SMALL_PLAN,
                                    train=True)
        ce = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["labels"][:, None], 1))
        total = ce + (cnn.regularization(p, cim) if reg else 0.0)
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return total, (ce, acc, st2)

    @jax.jit
    def step(p, st, batch):
        (_, (ce, acc, st2)), g = jax.value_and_grad(loss_fn, has_aux=True)(p, st, batch)
        p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        return p, st2, ce, acc

    losses, accs = [], []
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, state, ce, acc = step(params, state, b)
        losses.append(float(ce))
        accs.append(float(acc))
    return params, state, float(np.mean(accs[-10:])), losses


def eval_acc(params, state, cim, n_classes=4, hw=16, batches=8, seed=999):
    pipe = ImagePipeline(n_classes=n_classes, batch=32, hw=hw, seed=seed)
    f = jax.jit(lambda p, st, x: cnn.vgg_apply(p, st, x, cim, SMALL_PLAN,
                                               train=False)[0])
    correct = total = 0
    for _ in range(batches):
        b = pipe.next_batch()
        logits = f(params, state, jnp.asarray(b["images"]))
        correct += int(np.sum(np.argmax(np.asarray(logits), -1) == b["labels"]))
        total += b["labels"].size
    return correct / total


def timeit(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us
