"""Fig. 12 - sweep of the index-sharing hyper-parameter N (eq. 4): accuracy
and compression vs N in {1, 4, 8, 16, 32}; index storage / N."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_acc, train_small_vgg
from repro.configs.vgg16_cifar import cim_config
from repro.core import sparsity as S
from repro.models import cnn


def run(steps=60):
    rows = []
    for n in [1, 4, 8, 16, 32]:
        cim = cim_config(w_bits=4, a_bits=4, n=n, lambda_g=2e-3)
        params, state, _, _ = train_small_vgg(cim, steps=steps)
        cim_p = dataclasses.replace(
            cim, sparsity=dataclasses.replace(cim.sparsity, target_sparsity=0.7))
        pruned = cnn.prune_all(params, cim_p)
        pruned, state, _, _ = train_small_vgg(cim_p, steps=20, params=pruned,
                                              state=state)
        acc = eval_acc(pruned, state, cim_p)
        # group-set sparsity at the CIM granularity (16x16), regardless of N
        zs, idx_bits = [], 0
        for p in cnn.iter_conv_params(pruned):
            if "mask" not in p:
                continue
            kh, kw, ci, co = p["mask"].shape
            m2 = p["mask"].reshape(kh * kw, ci, co)
            per = jax.vmap(lambda m: S.zero_groupset_proportion(m, 16, 16))(m2)
            zs.append(float(jnp.mean(per)))
            for i in range(kh * kw):
                idx_bits += int(S.index_storage_bits(m2[i], 16, 16))
        sp = float(np.mean(zs))
        # eq.4 ties N channels to one code -> index storage divides by N/16
        share = max(n // 16, 1)
        rows.append({
            "name": f"fig12_N{n}",
            "sparsity_groupsets": round(sp, 4),
            "accuracy": round(acc, 4),
            "compression_rate": round(S.compression_rate(sp, 4), 1),
            "index_kb": round(idx_bits / 1024 / share, 3),
        })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
