"""Table III - the proposed BN-fusion quantizer vs DoReFa at matched
bit-widths (no sparsity, mirroring the paper's setup: DoReFa baseline is
trained WITHOUT BN, ours fuses BN into the quantized weights)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_acc
from repro.configs.vgg16_cifar import SMALL_PLAN, cim_config
from repro.core import quant as Q
from repro.core.cim_layer import CIMConfig
from repro.data import ImagePipeline
from repro.models import cnn


def _train_dorefa(w_bits, a_bits, steps, lr=0.05, seed=0, n_classes=4, hw=16):
    """DoReFa baseline: plain convs (no BN), DoReFa quantizers."""
    cim = CIMConfig(mode="dense")  # raw convs; quantization applied here
    params, state = cnn.vgg_init(jax.random.PRNGKey(seed), cim, SMALL_PLAN,
                                 n_classes=n_classes)
    # drop BN params to mirror "trained without BN"
    for p in params["convs"]:
        if p is not None:
            p.pop("gamma", None)
            p.pop("beta", None)

    def apply(p, x):
        h = x
        for v, pc in zip(SMALL_PLAN, p["convs"]):
            if v == "M":
                h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                          (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
                continue
            wq = Q.dorefa_quantize_weight(pc["w"].reshape(-1, pc["w"].shape[-1]),
                                          w_bits).reshape(pc["w"].shape)
            hq = Q.dorefa_quantize_activation(h, a_bits)
            h = jax.lax.conv_general_dilated(
                hq, wq, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = jnp.clip(jax.nn.relu(h), 0.0, 1.0)
        h = jnp.mean(h, axis=(1, 2))
        return h @ p["head"]["w"] + p["head"]["b"]

    pipe = ImagePipeline(n_classes=n_classes, batch=16, hw=hw, seed=seed)

    @jax.jit
    def step(p, batch):
        def loss(p):
            logits = apply(p, batch["images"])
            return -jnp.mean(jnp.take_along_axis(
                jax.nn.log_softmax(logits), batch["labels"][:, None], 1))

        l, g = jax.value_and_grad(loss)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), l

    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, l = step(params, b)

    # eval
    epipe = ImagePipeline(n_classes=n_classes, batch=32, hw=hw, seed=999)
    f = jax.jit(apply)
    correct = total = 0
    for _ in range(8):
        b = epipe.next_batch()
        logits = f(params, jnp.asarray(b["images"]))
        correct += int(np.sum(np.argmax(np.asarray(logits), -1) == b["labels"]))
        total += b["labels"].size
    return correct / total


def run(steps=150):
    from benchmarks.common import train_small_vgg

    rows = []
    for (w, a) in [(8, 8), (4, 4)]:
        acc_dorefa = _train_dorefa(w, a, steps)
        cim = cim_config(w_bits=w, a_bits=a, lambda_g=0.0)
        params, state, _, _ = train_small_vgg(cim, steps=steps, reg=False)
        acc_ours = eval_acc(params, state, cim)
        rows.append({
            "name": f"table3_w{w}a{a}",
            "dorefa_acc": round(acc_dorefa, 4),
            "mars_bnfuse_acc": round(acc_ours, 4),
            "delta": round(acc_ours - acc_dorefa, 4),
        })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
