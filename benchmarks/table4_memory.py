"""Table IV - per-layer memory compression of VGG16 at the paper's measured
sparsity rates, using the real pack_groupsets packer + Fig. 6 index codes.
Weights quantized to 8 bits as in the paper."""
from __future__ import annotations

import numpy as np

from repro.core.mapping import pack_groupsets

# (layer, cin, cout, paper sparsity %, paper index Kb, paper weight Kb)
PAPER_ROWS = [
    ("3x3x64x64", 64, 64, 0.05, 2.14, 273.60),
    ("3x3x64x128", 64, 128, 0.50, 2.25, 288.00),
    ("3x3x128x128", 128, 128, 0.566, 3.91, 488.97),
    ("3x3x128x256", 128, 256, 0.616, 6.91, 884.74),
    ("3x3x256x256", 256, 256, 0.932, 2.46, 313.34),
    ("3x3x256x512", 256, 512, 0.978, 1.58, 202.75),
    ("3x3x512x512", 512, 512, 0.987, 1.87, 239.62),
]


def _masked_weight(cin, cout, sparsity, seed=0):
    """Random weight with `sparsity` fraction of 16x16 group-sets zeroed,
    laid out as the packer sees it: one 2-D (cin, cout) slice per spatial
    position (9 positions for 3x3 kernels)."""
    rng = np.random.default_rng(seed)
    slices = []
    for _ in range(9):
        gi, go = cin // 16, cout // 16
        keep = rng.random((gi, go)) >= sparsity
        w = rng.standard_normal((cin, cout)).astype(np.float32)
        w *= np.repeat(np.repeat(keep, 16, 0), 16, 1)
        slices.append(w)
    return slices


def run():
    rows = []
    for name, cin, cout, sp, idx_kb_paper, w_kb_paper in PAPER_ROWS:
        idx_bits = w_bits = 0
        for w in _masked_weight(cin, cout, sp):
            p = pack_groupsets(w, alpha=16)
            idx_bits += p.index_bits
            w_bits += p.weight_bits_8b
        orig_mb = 9 * cin * cout * 8 / 2**20
        rows.append({
            "name": f"table4_{name}",
            "orig_mb": round(orig_mb, 2),
            "sparsity": sp,
            "index_kb": round(idx_bits / 1024, 2),  # kilobits, as in the paper
            "index_kb_paper": idx_kb_paper,
            "weight_kb": round(w_bits / 1024, 2),
            "weight_kb_paper": w_kb_paper,
            "compression_x": round(orig_mb * 1024 / ((idx_bits + w_bits) / 1024), 2),
        })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
