"""Ablation (beyond-paper): the CIM tile width alpha.

The paper fixes alpha=16 (two 8-partition macros). On TPU the natural tile
is 128 (MXU lanes). This ablation asks: at fixed pruning target, how do
sparsity-at-tile-granularity, accuracy, and index storage move as alpha
grows? Run standalone (not part of the default benchmark set):

  PYTHONPATH=src python -m benchmarks.ablation_alpha
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_acc, train_small_vgg
from repro.configs.vgg16_cifar import cim_config
from repro.core import sparsity as S
from repro.models import cnn


def run(steps=60):
    rows = []
    for alpha in [4, 8, 16, 32]:
        cim = cim_config(w_bits=4, a_bits=4, alpha=alpha, n=alpha,
                         lambda_g=2e-3)
        params, state, _, _ = train_small_vgg(cim, steps=steps)
        cim_p = dataclasses.replace(
            cim, sparsity=dataclasses.replace(cim.sparsity,
                                              target_sparsity=0.7))
        pruned = cnn.prune_all(params, cim_p)
        pruned, state, _, _ = train_small_vgg(cim_p, steps=20,
                                              params=pruned, state=state)
        acc = eval_acc(pruned, state, cim_p)
        # measure skippable fraction at BOTH the trained granularity and
        # the paper's 16x16 macro granularity
        z_own, z_16, idx_bits = [], [], 0
        for p in cnn.iter_conv_params(pruned):
            if "mask" not in p:
                continue
            kh, kw, ci, co = p["mask"].shape
            m2 = p["mask"].reshape(kh * kw, ci, co)
            z_own.append(float(jnp.mean(jax.vmap(
                lambda m: S.zero_groupset_proportion(m, alpha, alpha))(m2))))
            z_16.append(float(jnp.mean(jax.vmap(
                lambda m: S.zero_groupset_proportion(m, 16, 16))(m2))))
            for i in range(kh * kw):
                idx_bits += int(S.index_storage_bits(m2[i], alpha, alpha))
        rows.append({
            "name": f"ablation_alpha{alpha}",
            "accuracy": round(acc, 4),
            "tile_sparsity_at_alpha": round(float(np.mean(z_own)), 4),
            "sparsity_at_macro16": round(float(np.mean(z_16)), 4),
            "index_kb": round(idx_bits / 1024, 3),
        })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
