"""Table II - CIM-aware pruning + quantization: sparsity, accuracy and
compression rate at several bit-widths (small-VGG scale; the paper's exact
claim shape - sparse-quantized accuracy within ~1% of dense - is evaluated
on synthetic CIFAR-shaped data; see EXPERIMENTS.md for the scale caveat)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import eval_acc, train_small_vgg
from repro.configs.vgg16_cifar import cim_config
from repro.core import sparsity as S
from repro.models import cnn

TARGET = 0.7  # tile sparsity target at this scale


def _measure(params, cim):
    zs, idx_bits, w_bits_kept, total_w = [], 0, 0, 0
    for p in cnn.iter_conv_params(params):
        if "mask" not in p:
            continue
        kh, kw, ci, co = p["mask"].shape
        m2 = p["mask"].reshape(kh * kw, ci, co)
        per = jax.vmap(lambda m: S.zero_groupset_proportion(m, 16, 16))(m2)
        zs.append(float(jnp.mean(per)))
        for i in range(kh * kw):
            idx_bits += int(S.index_storage_bits(m2[i], 16, 16))
            w_bits_kept += int(S.weight_storage_bits(m2[i], 16, 16,
                                                     cim.quant.w_bits))
        total_w += p["mask"].size
    sparsity = float(np.mean(zs)) if zs else 0.0
    return sparsity, idx_bits, w_bits_kept, total_w


def run(steps=70):
    rows = []
    for (w, a) in [(32, 32), (8, 8), (8, 4), (4, 4)]:
        cim = cim_config(w_bits=w, a_bits=a, lambda_g=2e-3,
                         mode="qat" if w < 32 else "qat")
        params, state, _, _ = train_small_vgg(cim, steps=steps)
        acc_orig = eval_acc(params, state, cim)
        cim_p = dataclasses.replace(
            cim, sparsity=dataclasses.replace(cim.sparsity,
                                              target_sparsity=TARGET))
        pruned = cnn.prune_all(params, cim_p)
        # brief retrain with masks (paper: retraining restores accuracy)
        pruned, state, _, _ = train_small_vgg(cim_p, steps=max(20, steps // 3),
                                              params=pruned, state=state)
        acc_sparse = eval_acc(pruned, state, cim_p)
        sp, idx_bits, w_kept, total = _measure(pruned, cim_p)
        cr = S.compression_rate(sp, w)
        rows.append({
            "name": f"table2_vgg_small_w{w}a{a}",
            "orig_acc": round(acc_orig, 4),
            "sparsity_groupsets": round(sp, 4),
            "sparse_acc": round(acc_sparse, 4),
            "compression_rate": round(cr, 1),
            "index_kb": round(idx_bits / 1024, 2),  # kilobits, as in the paper
            "weight_kb_kept": round(w_kept / 1024, 2),
        })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
