"""Table I - MARS accelerator performance (analytic model, like the paper's
own 'estimated value' methodology referencing [18]'s measured macro)."""
from __future__ import annotations

from repro.core import perf_model as PM

PAPER = {  # (net, dataset, wbits, abits) -> (fps, gops, tops_w)
    ("vgg16", "c10", 8, 4): (714, 445, 52.3),
    ("vgg16", "c10", 8, 8): (540, 336, 29.7),
    ("resnet18", "c10", 8, 4): (711, 778, 88.2),
    ("resnet18", "c10", 8, 8): (403, 441, 37.6),
}


def run():
    rows = []
    for net, layers_fn in [("vgg16", PM.vgg16_cifar_layers),
                           ("resnet18", PM.resnet18_cifar_layers)]:
        for (w, a) in [(8, 4), (8, 8)]:
            perf = PM.summarize(layers_fn(), w, a)
            p = PAPER.get((net, "c10", w, a), (None, None, None))
            rows.append({
                "name": f"table1_{net}_w{w}a{a}",
                "fps": round(perf.fps, 1),
                "fps_paper": p[0],
                "speedup_vs_dense": round(perf.speedup, 2),
                "avg_gops": round(perf.avg_gops, 1),
                "gops_paper": p[1],
                "macro_tops_w": round(perf.macro_tops_w, 1),
                "tops_w_paper": p[2],
                "peak_tops_w": round(perf.peak_macro_tops_w, 1),
            })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
