"""Benchmark driver: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys
import time


MODULES = [
    "table1_perf",
    "sched_bench",
    "serve_bench",
    "table4_memory",
    "fig10_speedup",
    "fig11_access",
    "kernel_bench",
    "table3_quant",
    "table2_compression",
    "fig12_n_sweep",
]


def main() -> None:
    only = sys.argv[1:] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for modname in MODULES:
        if only and modname not in only:
            continue
        t0 = time.time()
        mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            print(f"{modname},ERROR,{str(e)[:120]}")
            continue
        dt = (time.time() - t0) * 1e6
        for r in rows:
            name = r.pop("name")
            us = r.pop("us_per_call_interp", round(dt / max(len(rows), 1), 1))
            derived = ";".join(f"{k}={v}" for k, v in r.items())
            print(f"{name},{us},{derived}", flush=True)


if __name__ == "__main__":
    main()
