"""Fig. 10 - normalized speedup of MARS over the dense baseline, per
network/dataset (CIFAR100 modeled with the paper's lower sparsity rates)."""
from __future__ import annotations

from repro.core import perf_model as PM

# Table II weight-sparsity translated into per-layer group-set sparsity
# profiles; C100 is less sparse than C10 (paper: 91% vs 96% overall)
C100_VGG = [0.03, 0.03, 0.35, 0.45, 0.50, 0.85, 0.85, 0.92, 0.94, 0.94,
            0.94, 0.94, 0.94]
C100_RESNET = [0.03] + [0.2] * 4 + [0.5] * 4 + [0.8] * 4 + [0.92] * 4


def run():
    rows = []
    cases = [
        ("vgg16_c10", PM.vgg16_cifar_layers()),
        ("vgg16_c100", PM.vgg16_cifar_layers(C100_VGG)),
        ("resnet18_c10", PM.resnet18_cifar_layers()),
        ("resnet18_c100", PM.resnet18_cifar_layers(C100_RESNET)),
    ]
    for name, layers in cases:
        perf = PM.summarize(layers, 8, 4)
        best_layer = max(p.speedup for p in perf.layers)
        rows.append({
            "name": f"fig10_{name}",
            "overall_speedup": round(perf.speedup, 2),
            "best_layer_speedup": round(best_layer, 1),
            "fps_mars": round(perf.fps, 1),
            "fps_dense": round(perf.fps_dense, 1),
        })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
