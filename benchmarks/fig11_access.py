"""Fig. 11 - feature-map SRAM access per layer, baseline vs MARS (the
deeper/sparser the layer, the bigger the reduction)."""
from __future__ import annotations

from repro.core import perf_model as PM


def run():
    rows = []
    for name, layers in [("vgg16", PM.vgg16_cifar_layers()),
                         ("resnet18", PM.resnet18_cifar_layers())]:
        perf = PM.evaluate_network(layers, 8, 4)
        worst = max(p.fm_reduction for p in perf)
        for p in perf:
            rows.append({
                "name": f"fig11_{name}_{p.name}",
                "fm_access_dense": int(p.fm_access_dense),
                "fm_access_mars": int(p.fm_access_mars),
                "reduction_x": round(p.fm_reduction, 1),
            })
        rows.append({"name": f"fig11_{name}_max_reduction",
                     "fm_access_dense": "", "fm_access_mars": "",
                     "reduction_x": round(worst, 1)})
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
