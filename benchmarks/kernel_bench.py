"""TPU-kernel benchmark (interpret mode; structural bytes + CPU wall time).

Wall-clock here is CPU interpret-mode time - NOT TPU performance - but the
bytes-touched model and the sparse-vs-dense op-count ratio are structural
and transfer: the BSR kernel touches density-proportional weight bytes,
which is the paper's zero-group-set skip."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit
from repro.core.mapping import pack_bsr
from repro.kernels import ops, ref
from repro.kernels.cim_bsr_matmul import bsr_matmul
from repro.kernels.fake_quant import fake_quant
from repro.kernels.quant_matmul import quant_matmul

import jax.numpy as jnp


def run():
    rows = []
    m, k, n, bk, bn = 256, 1024, 1024, 128, 128
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    for density in [1.0, 0.5, 0.25, 0.05]:
        gi, go = k // bk, n // bn
        keep = rng.random((gi, go)) < density
        w = rng.integers(-7, 8, (k, n)).astype(np.int8)
        w *= np.repeat(np.repeat(keep, bk, 0), bn, 1).astype(np.int8)
        bsr = pack_bsr(w, bk, bn)
        scales = np.full(bsr.row_idx.shape, 1 / 8, np.float32)
        args = (x, jnp.asarray(bsr.blocks), jnp.asarray(scales),
                jnp.asarray(bsr.row_idx), jnp.asarray(bsr.nnz))
        us = timeit(lambda *a: bsr_matmul(*a, interpret=True), *args, iters=3)
        weight_bytes = int(bsr.nnz.sum()) * bk * bn  # int8
        rows.append({
            "name": f"kernel_bsr_density{density}",
            "us_per_call_interp": round(us, 1),
            "weight_bytes_touched": weight_bytes,
            "dense_weight_bytes": k * n,
            "bytes_skipped_ratio": round(1 - weight_bytes / (k * n), 3),
        })

    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    scale = np.full((n,), 0.01, np.float32)
    us = timeit(lambda: quant_matmul(x, jnp.asarray(w), jnp.asarray(scale),
                                     interpret=True), iters=3)
    rows.append({"name": "kernel_quant_matmul_dense",
                 "us_per_call_interp": round(us, 1),
                 "weight_bytes_touched": k * n,
                 "dense_weight_bytes": k * n, "bytes_skipped_ratio": 0.0})

    big = jnp.asarray(rng.standard_normal((512, 2048)), jnp.float32)
    us = timeit(lambda: fake_quant(big, 4, interpret=True), iters=3)
    rows.append({"name": "kernel_fake_quant_4b",
                 "us_per_call_interp": round(us, 1),
                 "weight_bytes_touched": big.size * 4,
                 "dense_weight_bytes": big.size * 4, "bytes_skipped_ratio": 0.0})
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
