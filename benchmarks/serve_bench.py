"""Serving benchmark: static vs continuous batching vs compressed weights
vs macro-mesh (tensor-parallel) compressed serving.

One synthetic mixed-length trace (every 4th request decodes long, the rest
short - the skew that makes a static batcher idle its lanes) served four
ways on the smoke LM:

  * ``static``     - BatchServer with whole-batch admission (lanes drain
    together; a freed slot waits for the batch);
  * ``continuous`` - the same server, slot-level admission into freed lanes;
  * ``compressed`` - continuous batching where every CIM projection runs on
    the int8 BSR Pallas kernel (``serve.deployed.compress`` with a
    ``sched.search``-chosen tile); this is the LOOP runtime (python loop
    over per-layer packed weights - L kernel dispatches per decode step);
  * ``compressed_scan`` - the SAME weights through the compiled runtime
    (``BatchServer(engine="scan")``: uniform-envelope stacks + one jitted
    ``lax.scan`` decode step, zero per-layer dispatches). The loop-vs-scan
    summary row reports decode-step latency, first-run trace/compile time,
    tokens/s, and the ``tokens_match`` parity bit (bit-exactness contract);
  * ``sharded``    - the compressed server column-sharded over a forced
    4-device host macro mesh (run in a subprocess so the device count can
    be set before jax imports). On CPU fake devices this measures the
    orchestration overhead, not a speedup - the row's purpose is the
    contract: tokens bit-identical to single-device (``tokens_match``);
  * ``spec``       - self-speculative decode with the LAYERSKIP draft
    family: the draft runs the nnz-ranked top-``SPEC_KEEP`` fraction of
    the TARGET envelope's sublayers (no second packing) and proposes k
    tokens per batched multi-token target verify. Reports the measured
    acceptance rate, accepted-length histogram, decode-step p50 and
    tokens/s against ``compressed_scan``, the ``tokens_match_target``
    greedy bit-exactness bit, and the calibrated ``--spec auto`` decision:
    the measured acceptance is folded into a ``sched.search``
    SpecCalibration (persisted into the shared artifact manifest, like
    the autotune cache) and the re-run search either picks a (family, k,
    knob) or records ``declined: scan wins``.

A separate prefix-skew trace (``serve_prefix_skew`` row) serves ~90%
shared-system-prompt requests through the scan runtime with the radix-tree
prefix cache on vs off: cache-hit requests adopt the shared blocks and
prefill only their suffix, so the row reports the hit rate, cache-hit vs
miss service TTFT p50, the hit-TTFT-over-decode-step ratio, tokens/s both
ways and the ``tokens_match_unshared`` parity bit.

The single-host engines share kernels and per-step cost, so static-vs-
continuous isolates the scheduling policy. Each engine is warmed on the
identical trace first (shape buckets compile once); the reported run is
jit-warm and every bench clock fences with ``jax.block_until_ready``.
Results land in ``BENCH_serve.json`` with TTFT / per-token-latency
percentiles (queue wait split out of TTFT), plus a ``sim_vs_measured``
row from a separate ``repro.obs``-instrumented scan run: fenced
decode-step p50 against the event-driven simulator's one-token step on
the modeled CIM fabric (the ratio's drift, not its value, is the signal).
The sharded row carries its own ``sim_vs_measured`` against the
all-gather-aware prediction (``serve_gap(..., n_devices=4)``), so the
collective's modeled share is confronted with the measured step cost.

Packings are cached as serving artifacts under one shared directory
(``MARS_BENCH_ARTIFACTS``, default ``/tmp/mars-bench-artifacts``): the
subprocess rows boot via ``serve.deployed.load_artifact`` instead of
re-packing from scratch, and repeat benchmark runs (or CI smokes pointed at
the same directory) skip the search+quantize+prune+pack pipeline entirely.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.models import registry
from repro.obs import MetricsRegistry
from repro.obs import gap as obs_gap
from repro.serve import (BatchConfig, BatchServer, Request, ServeConfig,
                         SpecConfig)
from repro.serve import deployed as DP
from repro.serve import spec as SP
from repro.sched.search import SpecCalibration, search_spec
from repro.launch.serve import prefix_skew_trace, synthetic_trace

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART_ROOT = os.environ.get("MARS_BENCH_ARTIFACTS", "/tmp/mars-bench-artifacts")

ARCH = "yi-6b"
N_REQUESTS = 12
MAX_PROMPT = 20
MAX_NEW = 36
TARGET_SPARSITY = 0.6
SHARD_DEVICES = 4
SHARD_TILE = (16, 16)  # small tile -> enough block columns to split
SPEC_K = 4
SPEC_DRAFT_SPARSITY = 0.85  # the cached artifact's reprune draft tier
SPEC_FAMILY = "layerskip"   # the family the spec row serves
SPEC_KEEP = 0.5
# prefix-skew trace: ~90% of requests share one system prompt (the
# production workload the radix-tree prefix cache exists for). The shared
# span is a block multiple so the trie can cache every full block of it.
PREFIX_REQUESTS = 24
PREFIX_SHARED = 64
PREFIX_SUFFIX_MAX = 6
PREFIX_MAX_NEW = 8
# two-tenant gateway row: both tenants serve the SAME cached packing over
# ONE shared block pool; the queue is bounded below the offered load so
# the overload contract visibly sheds the low-priority tenant's tail
GATEWAY_REQUESTS = 6   # per tenant
GATEWAY_MAX_NEW = 8
GATEWAY_MAX_PENDING = 8  # < 2 * GATEWAY_REQUESTS -> forced overflow
GATEWAY_TTFT_SLO_MS = 120000.0  # generous: CI runners are interp-mode


def _serve(cfg, sp, continuous: bool, trace_fn, repeats: int = 2,
           engine: str = "loop", **kw):
    rep, _ = _serve_timed(cfg, sp, continuous, trace_fn, repeats=repeats,
                          engine=engine, **kw)
    return rep


def _serve_timed(cfg, sp, continuous: bool, trace_fn, repeats: int = 2,
                 warmup: int = 1, engine: str = "loop", bcfg=None, **kw):
    """Like ``_serve`` but also returns the first-run wall time - dominated
    by trace+compile, the cost the scan runtime amortizes over layers.

    Every bench clock is FENCED: ``jax.block_until_ready`` over each run's
    outputs before the stopwatch stops, so async dispatch can't leak device
    work past a timer. The first run (and any extra ``warmup`` iterations)
    is trace+compile and is excluded from the measured repeats; warmup
    samples are also dropped from any attached obs sinks."""
    srv = BatchServer(cfg, sp, ServeConfig(),
                      bcfg or BatchConfig(n_slots=4, block_size=8,
                                          n_blocks=64),
                      continuous=continuous, engine=engine, **kw)
    t0 = time.perf_counter()
    jax.block_until_ready(srv.run(trace_fn()).outputs)  # compile all buckets
    compile_s = time.perf_counter() - t0
    for _ in range(warmup - 1):
        jax.block_until_ready(srv.run(trace_fn()).outputs)
    # warmup spans/samples are compile noise, not serving cost
    srv.metrics.clear()
    srv.tracer.clear()
    srv.timer.clear()
    best = None
    for _ in range(repeats):
        rep = srv.run(trace_fn())
        jax.block_until_ready(rep.outputs)
        if best is None or rep.tokens_per_s > best.tokens_per_s:
            best = rep
    return best, compile_s


def _cached_packing(name: str, cfg, build_fn, draft: bool = False,
                    want: dict | None = None):
    """Load a packed ServingParams from the shared artifact dir, or build
    it ONCE with ``build_fn() -> (sp, draft_sp_or_None, extra)`` and save
    it there - subprocess rows and repeat runs boot without re-packing.

    ``want`` pins the packing constants the caller is about to report
    (sparsities, forced tile; the arch is always pinned): a cached
    artifact whose stored meta disagrees is STALE (the constants changed
    since it was packed) and is rebuilt rather than silently served under
    the new labels."""
    want = {"arch": cfg.name, **(want or {})}
    path = os.path.join(ART_ROOT, name)
    try:
        sp, dsp, meta = DP.load_artifact_tiers(path)
        if (all(meta.get(k) == v for k, v in want.items())
                and (dsp is not None or not draft)):
            return sp, dsp, meta
    except (FileNotFoundError, ValueError, TypeError):
        pass
    sp, dsp, extra = build_fn()
    extra = {**want, **extra}
    DP.save_artifact(path, sp, cfg, draft=dsp, extra=extra)
    return sp, dsp, extra


def _row(name: str, j: dict) -> dict:
    return {
        "name": f"serve_{name}",
        "tokens_per_s": j["tokens_per_s"],
        "ttft_p50_ms": round(j["ttft"]["p50"] * 1e3, 2),
        "ttft_p99_ms": round(j["ttft"]["p99"] * 1e3, 2),
        "tpot_p50_ms": round(j["tpot"]["p50"] * 1e3, 2),
        "tpot_p99_ms": round(j["tpot"]["p99"] * 1e3, 2),
        "slot_efficiency": j["slot_efficiency"],
    }


def _shard_packing(cfg):
    """The 16x16-tile packing the sharded row serves, cached as a shared
    artifact so the subprocess boots it instead of re-packing."""

    def build():
        params = registry.model_fns(cfg).init_params(cfg,
                                                     jax.random.PRNGKey(0))
        sp = DP.compress(cfg, params, target_sparsity=TARGET_SPARSITY,
                         tile=SHARD_TILE)
        return sp, None, {}

    return _cached_packing("sharded%dx%d" % SHARD_TILE, cfg, build,
                           want={"tile": list(SHARD_TILE),
                                 "target_sparsity": TARGET_SPARSITY})[0]


def sharded_worker():
    """Runs inside a subprocess with SHARD_DEVICES forced host devices:
    serves the benchmark trace single-device and macro-sharded, checks
    bit-identical tokens, prints the sharded report JSON on the last line.
    Boots the packing from the shared artifact dir (the parent process
    already built and saved it - no re-packing here)."""
    from repro.launch.shardings import macro_mesh

    cfg = registry.get_smoke_config(ARCH, dtype="float32")
    spc = _shard_packing(cfg)
    trace_fn = lambda: synthetic_trace(cfg, N_REQUESTS, MAX_PROMPT, MAX_NEW)
    single = _serve(cfg, spc, True, trace_fn, repeats=1)

    mesh = macro_mesh(SHARD_DEVICES)
    sps = DP.shard(spc, mesh)
    n_sharded = sum(1 for dw in sps.deployed().values() if dw.mesh is not None)
    srv = BatchServer(cfg, sps, ServeConfig(),
                      BatchConfig(n_slots=4, block_size=8, n_blocks=64),
                      continuous=True, mesh=mesh)
    srv.run(trace_fn())  # compile
    rep = srv.run(trace_fn())
    match = all(np.array_equal(rep.outputs[r.rid], single.outputs[r.rid])
                for r in trace_fn())
    out = rep.to_json()
    out["n_devices"] = SHARD_DEVICES
    out["n_sharded_projections"] = n_sharded
    out["tile"] = list(SHARD_TILE)
    out["tokens_match_single_device"] = match
    print(json.dumps(out))


def _sharded_report():
    """Spawn the worker with the forced device count (XLA_FLAGS must be set
    before jax imports, so it cannot run in this process)."""
    env = dict(os.environ)
    # forced host devices only exist on the CPU backend: pin the platform
    # (else a GPU host's backend wins and macro_mesh(4) has 1 device) and
    # append to - don't clobber - any flags the caller set
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = " ".join(
        ([env["XLA_FLAGS"]] if env.get("XLA_FLAGS") else [])
        + [f"--xla_force_host_platform_device_count={SHARD_DEVICES}"])
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    r = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.serve_bench import sharded_worker; sharded_worker()"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"sharded worker failed:\n{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _gateway_report(cfg, spc):
    """Two tenants, one pool, bounded queue: per-tenant goodput + SLO
    attainment under forced overload. Both tenants serve the same cached
    packing, so the row isolates the gateway's scheduling, not the
    kernels; the shed evidence pins the strictly-lowest-priority-first
    overload contract."""
    from repro.gateway import (AdmissionController, Gateway, GatewayConfig,
                               TenantRuntime, TenantSLO)

    tenants = [
        TenantRuntime("prio", cfg, spc, priority=1,
                      slo=TenantSLO(ttft_ms=GATEWAY_TTFT_SLO_MS)),
        TenantRuntime("batch", cfg, spc, priority=0),
    ]
    gcfg = GatewayConfig(n_slots=4, block_size=8, n_blocks=96,
                         max_pending=GATEWAY_MAX_PENDING)
    gw = Gateway(tenants, gcfg, ServeConfig())

    def trace():
        reqs = []
        for pi, (name, prio) in enumerate((("prio", 1), ("batch", 0))):
            for r in synthetic_trace(cfg, GATEWAY_REQUESTS, MAX_PROMPT,
                                     GATEWAY_MAX_NEW, seed=pi):
                reqs.append(dataclasses.replace(
                    r, rid=f"{name}-{r.rid}", tenant=name, priority=prio))
        return reqs

    gw.run(trace())  # compile all shape buckets (sheds here are warmup's)
    gw.controller = AdmissionController()  # fresh admission accounting
    rep = gw.run(trace())
    j = rep.to_json()
    lowest = min(t.priority for t in tenants)
    return {
        "n_requests": 2 * GATEWAY_REQUESTS,
        "max_pending": GATEWAY_MAX_PENDING,
        "tenants": {
            name: {
                "priority": t["priority"],
                "n_requests": t["n_requests"],
                "tokens_per_s": t["tokens_per_s"],
                "goodput_tokens_per_s": t["goodput_tokens_per_s"],
                "slo_attainment": t["slo_attainment"],
                "ttft_p50_ms": round(t["ttft"]["p50"] * 1e3, 2),
            } for name, t in j["tenants"].items()},
        "n_shed": j["n_shed"],
        # the overload contract's evidence bit: every shed victim sat at
        # the lowest priority level present in the trace
        "shed_lowest_priority_only": bool(
            j["shed_events"]
            and all(ev["priority"] == lowest for ev in j["shed_events"])),
        "admission": j["admission"],
    }


def run():
    cfg = registry.get_smoke_config(ARCH, dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    sp = DP.from_params(cfg, params)

    def build_compressed():
        schedule = DP.default_schedule(cfg)
        spc = DP.compress(cfg, params, target_sparsity=TARGET_SPARSITY,
                          schedule=schedule)
        draft = SP.draft_serving(cfg, spc, SPEC_DRAFT_SPARSITY)
        return spc, draft, {"tile": list(schedule.candidate.tile)}

    # the searched-tile packing + its speculative draft tier ride one
    # shared two-tier artifact: repeat runs (and anything else pointed at
    # ART_ROOT) boot without re-running search/quantize/prune/pack
    spc, draft, meta = _cached_packing(
        "compressed", cfg, build_compressed, draft=True,
        want={"target_sparsity": TARGET_SPARSITY,
              "draft_sparsity": SPEC_DRAFT_SPARSITY})
    schedule_tile = list(meta.get("tile", []))
    _shard_packing(cfg)  # warm the artifact the sharded subprocess boots

    trace_fn = lambda: synthetic_trace(cfg, N_REQUESTS, MAX_PROMPT, MAX_NEW)

    loop_rep, loop_compile_s = _serve_timed(cfg, spc, True, trace_fn)
    scan_rep, scan_compile_s = _serve_timed(cfg, spc, True, trace_fn,
                                            engine="scan")
    scan_match = all(
        np.array_equal(scan_rep.outputs[r.rid], loop_rep.outputs[r.rid])
        for r in trace_fn())
    spec_cfg = SpecConfig(k=SPEC_K, draft=SPEC_FAMILY, keep=SPEC_KEEP)
    spec_rep = _serve(cfg, spc, True, trace_fn, engine="spec",
                      spec=spec_cfg)
    spec_match = all(
        np.array_equal(spec_rep.outputs[r.rid], scan_rep.outputs[r.rid])
        for r in trace_fn())

    # sim-vs-measured gap: a separate short instrumented scan run (the
    # comparison rows above stay un-instrumented, so phase fencing never
    # taxes their numbers); fenced decode-step p50 + per-phase wall-time
    # shares confronted with the event-driven simulator's one-token step
    # on the modeled CIM fabric. The ratio is cycles-model-vs-host-backend,
    # so its VALUE is not ~1 - CI tracks that it stays finite and stable.
    gap_metrics = MetricsRegistry()
    _serve(cfg, spc, True, trace_fn, repeats=1, engine="scan",
           metrics=gap_metrics)
    snap = gap_metrics.snapshot()
    step_h = snap["histograms"].get("serve_phase_s{phase=decode_step}", {})
    # empty phase table (instrumentation regressed / zero decode steps):
    # fall back to the fenced tpot p50 rather than feeding 0.0 into the gap
    step_p50 = (float(step_h["p50"]) if step_h.get("count")
                else float(scan_rep.to_json()["tpot"]["p50"]))
    sim_gap = obs_gap.serve_gap(
        cfg, step_p50, TARGET_SPARSITY,
        measured_phases={k: v for k, v in
                         obs_gap.measured_phase_shares(snap).items()
                         if k.startswith("step.")})

    reports = {
        "static": _serve(cfg, sp, False, trace_fn),
        "continuous": _serve(cfg, sp, True, trace_fn),
        "compressed": loop_rep,
        "compressed_scan": scan_rep,
        "spec": spec_rep,
    }
    sharded = _sharded_report()
    # sharded gap: the all-gather-aware prediction (perf_model's ring
    # collective at every column-sharded projection) against the sharded
    # run's fenced tpot p50 - the measured anchor for the 7x sharded
    # regression ROADMAP tracks
    sharded["sim_vs_measured"] = obs_gap.serve_gap(
        cfg, float(sharded["tpot"]["p50"]), TARGET_SPARSITY,
        n_devices=SHARD_DEVICES)
    loop_vs_scan = {
        # per-decode-step latency: all slots advance one token per step,
        # so tpot is the step cost; the scan runtime compiles the layer
        # loop into ONE dispatch instead of L kernel launches per step
        "decode_step_p50_ms_loop": round(
            loop_rep.to_json()["tpot"]["p50"] * 1e3, 3),
        "decode_step_p50_ms_scan": round(
            scan_rep.to_json()["tpot"]["p50"] * 1e3, 3),
        "compile_s_loop": round(loop_compile_s, 2),
        "compile_s_scan": round(scan_compile_s, 2),
        "tokens_per_s_loop": loop_rep.to_json()["tokens_per_s"],
        "tokens_per_s_scan": scan_rep.to_json()["tokens_per_s"],
        "layer_dispatches_per_step_loop": cfg.n_layers,
        "layer_dispatches_per_step_scan": 1,
        "tokens_match": scan_match,
    }

    scan_j = scan_rep.to_json()
    spec_j = spec_rep.to_json()
    # close the calibration loop: fold the MEASURED acceptance into a
    # sched.search prior, persist it into the shared artifact manifest
    # (the slot --spec auto boots from), and record the decision the
    # calibrated search would serve next - a winning (family, k, knob)
    # or "declined: scan wins"
    calibration = SpecCalibration()
    calibration.add(cfg.name, SPEC_FAMILY, 1.0 - SPEC_KEEP,
                    spec_j["spec"]["acceptance_rate"],
                    weight=float(max(spec_j["spec"]["proposed"], 1)))
    DP.update_artifact_extra(os.path.join(ART_ROOT, "compressed"),
                             {"spec_calibration": calibration.to_json()})
    auto_decision = search_spec(cfg, target_sparsity=TARGET_SPARSITY,
                                calibration=calibration,
                                arch=cfg.name).decision
    # the bench HAS the end-to-end measurement - the recorded decision is
    # measurement-first: a simulated win that measured a throughput loss
    # on this backend is declined (the auto contract: never ship a loss)
    measured_speedup = round(
        spec_j["tokens_per_s"] / max(scan_j["tokens_per_s"], 1e-9), 4)
    auto_decision["measured_speedup"] = measured_speedup
    if measured_speedup < 1.0 and auto_decision["verdict"] == "spec":
        auto_decision = {**auto_decision, "verdict": "declined",
                         "reason": "scan wins (measured tokens/s)"}
    spec_summary = {
        # draft-k-verify vs the compiled target-only baseline: same
        # weights, same trace - what speculation buys (or costs) end to end
        "family": SPEC_FAMILY,
        "k": SPEC_K,
        "keep": SPEC_KEEP,
        # the artifact also carries the cached reprune draft tier; its
        # compression ratio documents the alternative family's packing
        "reprune_draft_compression_x": round(
            draft.report()["compression_x"], 2),
        "acceptance_rate": spec_j["spec"]["acceptance_rate"],
        "accepted_len_hist": spec_j["spec"]["accepted_len_hist"],
        "spec_k_collapses": spec_j["spec"]["spec_k_collapses"],
        "tokens_per_verify": spec_j["spec"]["tokens_per_verify"],
        # spec tokens materialize in bursts (one round = draft loop +
        # verify), so its per-token latency is the round p50 divided by
        # tokens/round - NOT the pooled token_times diffs, whose
        # intra-burst entries are legitimately zero
        "round_p50_ms_spec": spec_j["spec"]["round_p50_ms"],
        "decode_p50_ms_spec": spec_j["spec"]["ms_per_token_p50"],
        "decode_p50_ms_scan": round(scan_j["tpot"]["p50"] * 1e3, 3),
        "tokens_per_s_spec": spec_j["tokens_per_s"],
        "tokens_per_s_scan": scan_j["tokens_per_s"],
        "tokens_match_target": spec_match,
        "auto_decision": auto_decision,
    }

    # prefix-skew trace through the compiled runtime: ~90% of requests
    # share one 64-token system prompt, so after the first admission the
    # radix trie serves their prefix KV from cache and prefill shrinks to
    # the unshared suffix. Cache on vs off on the SAME trace isolates what
    # reuse buys; the parity bit pins the greedy bit-exactness contract.
    pfx_bcfg = BatchConfig(n_slots=4, block_size=8, n_blocks=96)
    pfx_trace = lambda: prefix_skew_trace(cfg, PREFIX_REQUESTS,
                                          PREFIX_SHARED, PREFIX_SUFFIX_MAX,
                                          PREFIX_MAX_NEW)
    pfx_rep = _serve(cfg, spc, True, pfx_trace, engine="scan",
                     bcfg=pfx_bcfg)
    pfx_off_rep = _serve(cfg, spc, True, pfx_trace, engine="scan",
                         bcfg=dataclasses.replace(pfx_bcfg,
                                                  prefix_cache=False))
    pfx_match = all(
        np.array_equal(pfx_rep.outputs[r.rid], pfx_off_rep.outputs[r.rid])
        for r in pfx_trace())
    pfx_j = pfx_rep.to_json()
    pfx = pfx_j["prefix"]
    pfx_step_ms = round(pfx_j["tpot"]["p50"] * 1e3, 3)
    pfx_hit_ms = round(pfx["ttft_service_hit"]["p50"] * 1e3, 3)
    prefix_summary = {
        # the headline: a cache-hit request's service TTFT (queue wait
        # excluded) lands within ~a decode step of admission, because its
        # first forward pass covers only the unshared suffix
        "n_requests": PREFIX_REQUESTS,
        "shared_tokens": PREFIX_SHARED,
        "hit_rate": pfx["hit_rate"],
        "hit_tokens": pfx["hit_tokens"],
        "ttft_hit_p50_ms": pfx_hit_ms,
        "ttft_miss_p50_ms": round(
            pfx["ttft_service_miss"]["p50"] * 1e3, 3),
        "decode_step_p50_ms": pfx_step_ms,
        "ttft_hit_over_decode_step": round(
            pfx_hit_ms / max(pfx_step_ms, 1e-9), 2),
        "tokens_per_s": pfx_j["tokens_per_s"],
        "tokens_per_s_unshared": pfx_off_rep.to_json()["tokens_per_s"],
        "tokens_match_unshared": pfx_match,
        "cow_copies": pfx["cow_copies"],
    }

    gateway_summary = _gateway_report(cfg, spc)

    report = {
        "arch": cfg.name,
        "trace": {"n_requests": N_REQUESTS, "max_prompt": MAX_PROMPT,
                  "max_new": MAX_NEW},
        "schedule_tile": schedule_tile,
        "compression": spc.report(),
        "speedup_continuous_vs_static": round(
            reports["continuous"].tokens_per_s
            / max(reports["static"].tokens_per_s, 1e-9), 3),
        **{k: v.to_json() for k, v in reports.items()},
        "loop_vs_scan": loop_vs_scan,
        "spec_vs_scan": spec_summary,
        "sharded": sharded,
        "sim_vs_measured": sim_gap,
        "prefix_skew": prefix_summary,
        "gateway_two_tenant": gateway_summary,
    }
    with open(os.path.abspath(OUT_PATH), "w") as f:
        json.dump(report, f, indent=1)

    rows = [_row(name, rep.to_json()) for name, rep in reports.items()]
    for r in rows:
        if r["name"] == "serve_compressed_scan":
            r["tokens_match"] = scan_match
        if r["name"] == "serve_spec":
            r["acceptance_rate"] = spec_summary["acceptance_rate"]
            r["tokens_match_target"] = spec_match
    srow = _row("sharded_macro%d" % SHARD_DEVICES, sharded)
    srow["tokens_match"] = sharded["tokens_match_single_device"]
    rows.append(srow)
    rows.append({"name": "serve_loop_vs_scan", **loop_vs_scan})
    rows.append({"name": "serve_spec_vs_scan", **spec_summary})
    rows.append({"name": "serve_prefix_skew", **prefix_summary})
    rows.append({"name": "serve_gateway_two_tenant", **gateway_summary})
    rows.append({
        "name": "serve_sim_vs_measured",
        "gap": sim_gap["sim_vs_measured"],
        "predicted_us": round(sim_gap["predicted_s"] * 1e6, 2),
        "measured_us": round(sim_gap["measured_s"] * 1e6, 2),
    })
    sharded_gap = sharded["sim_vs_measured"]
    rows.append({
        "name": "serve_sharded_sim_vs_measured",
        "gap": sharded_gap["sim_vs_measured"],
        "n_devices": SHARD_DEVICES,
        "collective_share": sharded_gap["predicted_phase_shares"].get(
            "collective", 0.0),
        "predicted_us": round(sharded_gap["predicted_s"] * 1e6, 2),
        "measured_us": round(sharded_gap["measured_s"] * 1e6, 2),
    })
    rows.append({
        "name": "serve_continuous_speedup",
        "vs_static": report["speedup_continuous_vs_static"],
        "compression_x": round(report["compression"]["compression_x"], 2),
    })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
