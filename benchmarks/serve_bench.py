"""Serving benchmark: static vs continuous batching vs compressed weights.

One synthetic mixed-length trace (every 4th request decodes long, the rest
short - the skew that makes a static batcher idle its lanes) served three
ways on the smoke LM:

  * ``static``     - BatchServer with whole-batch admission (lanes drain
    together; a freed slot waits for the batch);
  * ``continuous`` - the same server, slot-level admission into freed lanes;
  * ``compressed`` - continuous batching where every CIM projection runs on
    the int8 BSR Pallas kernel (``serve.deployed.compress`` with a
    ``sched.search``-chosen tile).

All three share kernels and per-step cost, so static-vs-continuous isolates
the scheduling policy. Each engine is warmed on the identical trace first
(shape buckets compile once); the reported run is jit-warm. Results land in
``BENCH_serve.json`` with TTFT / per-token-latency percentiles.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.models import registry
from repro.serve import BatchConfig, BatchServer, Request, ServeConfig
from repro.serve import deployed as DP
from repro.launch.serve import synthetic_trace

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ARCH = "yi-6b"
N_REQUESTS = 12
MAX_PROMPT = 20
MAX_NEW = 36
TARGET_SPARSITY = 0.6


def _serve(cfg, sp, continuous: bool, trace_fn, repeats: int = 2):
    srv = BatchServer(cfg, sp, ServeConfig(),
                      BatchConfig(n_slots=4, block_size=8, n_blocks=64),
                      continuous=continuous)
    srv.run(trace_fn())  # compile all shape buckets
    best = None
    for _ in range(repeats):
        rep = srv.run(trace_fn())
        if best is None or rep.tokens_per_s > best.tokens_per_s:
            best = rep
    return best


def run():
    cfg = registry.get_smoke_config(ARCH, dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    sp = DP.from_params(cfg, params)
    schedule = DP.default_schedule(cfg)
    spc = DP.compress(cfg, params, target_sparsity=TARGET_SPARSITY,
                      schedule=schedule)

    trace_fn = lambda: synthetic_trace(cfg, N_REQUESTS, MAX_PROMPT, MAX_NEW)

    reports = {
        "static": _serve(cfg, sp, False, trace_fn),
        "continuous": _serve(cfg, sp, True, trace_fn),
        "compressed": _serve(cfg, spc, True, trace_fn),
    }

    report = {
        "arch": cfg.name,
        "trace": {"n_requests": N_REQUESTS, "max_prompt": MAX_PROMPT,
                  "max_new": MAX_NEW},
        "schedule_tile": list(schedule.candidate.tile),
        "compression": spc.report(),
        "speedup_continuous_vs_static": round(
            reports["continuous"].tokens_per_s
            / max(reports["static"].tokens_per_s, 1e-9), 3),
        **{k: v.to_json() for k, v in reports.items()},
    }
    with open(os.path.abspath(OUT_PATH), "w") as f:
        json.dump(report, f, indent=1)

    rows = []
    for name, rep in reports.items():
        j = rep.to_json()
        rows.append({
            "name": f"serve_{name}",
            "tokens_per_s": j["tokens_per_s"],
            "ttft_p50_ms": round(j["ttft"]["p50"] * 1e3, 2),
            "ttft_p99_ms": round(j["ttft"]["p99"] * 1e3, 2),
            "tpot_p50_ms": round(j["tpot"]["p50"] * 1e3, 2),
            "tpot_p99_ms": round(j["tpot"]["p99"] * 1e3, 2),
            "slot_efficiency": j["slot_efficiency"],
        })
    rows.append({
        "name": "serve_continuous_speedup",
        "vs_static": report["speedup_continuous_vs_static"],
        "compression_x": round(report["compression"]["compression_x"], 2),
    })
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
