"""Analytic vs event-driven-simulated vs searched-mapping throughput.

Three numbers per network (VGG16/ResNet18-CIFAR, w8a4 and w8a8):

  * ``fps_analytic``  - the closed-form ``perf_model.summarize``;
  * ``fps_sim``       - the event-driven simulator on the paper's 16x16
    mapping (pipeline on), plus the no-pipeline cross-validation ratio
    against the analytic dense baseline;
  * ``fps_searched``  - the best mapping the grid search finds.

Each entry also carries a ``sim_vs_measured`` row (``repro.obs.gap``): one
real BSR Pallas dispatch at the searched tile, fenced and timed, against
the analytic model's cycles for the same matmul - the measured anchor for
the otherwise purely modeled numbers. The ratio compares CIM cycles to the
host backend's wall clock, so its value is not ~1; finiteness and
stability are the tracked contract.

Results are also written to ``BENCH_sched.json`` at the repo root.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import perf_model as PM
from repro.obs import gap as obs_gap
from repro import sched

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sched.json")

NETWORKS = [
    ("vgg16", PM.vgg16_cifar_layers, sched.vgg16_graph),
    ("resnet18", PM.resnet18_cifar_layers, sched.resnet18_graph),
]


def run():
    rows = []
    report = {}
    gap_cache = {}  # one fenced dispatch per distinct (tile, w, a, sparsity)
    for net, layers_fn, graph_fn in NETWORKS:
        graph = graph_fn()
        for (w, a) in [(8, 4), (8, 8)]:
            analytic = PM.summarize(layers_fn(), w, a)
            sim = sched.simulate(graph, w_bits=w, a_bits=a, pipeline=True)
            cv = sched.cross_validate(layers_fn(), w_bits=w, a_bits=a,
                                      dense=True)
            search = sched.search_mapping(graph, w_bits=w, a_bits=a)
            schedule = sched.schedule_from_search(graph, search, w_bits=w,
                                                  a_bits=a)
            key = f"{net}_w{w}a{a}"
            entry = {
                "fps_analytic": round(analytic.fps, 1),
                "fps_sim": round(sim.fps, 1),
                "fps_searched": round(search.best.fps, 1),
                "dense_sim_vs_analytic": round(cv["ratio"], 3),
                "searched_tile": list(search.best.candidate.tile),
                "search_speedup": round(search.speedup_vs_default, 3),
                "core_utilization": round(sim.core_utilization, 3),
                "schedule": schedule.to_json(),
            }
            tile = tuple(search.best.candidate.tile)
            spars = round(float(np.mean([l.sparsity_gs
                                         for l in layers_fn()])), 3)
            gk = (tile, w, a, spars)
            if gk not in gap_cache:
                gap_cache[gk] = obs_gap.kernel_gap(
                    32, 128, 128, tile, spars, w_bits=w, a_bits=a)
            entry["sim_vs_measured"] = gap_cache[gk]
            report[key] = entry
            rows.append({
                "name": f"sched_{key}",
                "fps_analytic": entry["fps_analytic"],
                "fps_sim": entry["fps_sim"],
                "fps_searched": entry["fps_searched"],
                "dense_ratio": entry["dense_sim_vs_analytic"],
                "tile": f"{search.best.candidate.group}x"
                        f"{search.best.candidate.alpha}",
                "util": entry["core_utilization"],
                "gap": entry["sim_vs_measured"]["sim_vs_measured"],
            })
    with open(os.path.abspath(OUT_PATH), "w") as f:
        json.dump(report, f, indent=1)
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
