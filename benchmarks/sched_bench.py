"""Analytic vs event-driven-simulated vs searched-mapping throughput.

Three numbers per network (VGG16/ResNet18-CIFAR, w8a4 and w8a8):

  * ``fps_analytic``  - the closed-form ``perf_model.summarize``;
  * ``fps_sim``       - the event-driven simulator on the paper's 16x16
    mapping (pipeline on), plus the no-pipeline cross-validation ratio
    against the analytic dense baseline;
  * ``fps_searched``  - the best mapping the grid search finds.

Each entry also carries a ``sim_vs_measured`` row (``repro.obs.gap``): one
real BSR Pallas dispatch at the searched tile, fenced and timed, against
the analytic model's cycles for the same matmul - the measured anchor for
the otherwise purely modeled numbers. The ratio compares CIM cycles to the
host backend's wall clock, so its value is not ~1; finiteness and
stability are the tracked contract.

On top of that one-shot anchor, the observe->tune loop runs per entry: the
top-``TOP_N`` searched tiles are each timed through the real stacked BSR
kernels (``sched.autotune.measure_tile`` at a representative matmul shape)
and the measured winner lands in ``measured_tile`` - by construction its
fenced wall clock is <= the simulated pick's, which is asserted. The
per-sample timings re-fit the cycle constants
(``perf_model.fit_cycle_constants``) and the entry's
``sim_vs_measured.post_refit`` carries the post-refit gap + residual.

Results are also written to ``BENCH_sched.json`` at the repo root.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import perf_model as PM
from repro.obs import gap as obs_gap
from repro import sched
from repro.sched import autotune as AT

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sched.json")

NETWORKS = [
    ("vgg16", PM.vgg16_cifar_layers, sched.vgg16_graph),
    ("resnet18", PM.resnet18_cifar_layers, sched.resnet18_graph),
]

TOP_N = 3  # searched tiles measured per entry
# representative matmul workload the tiles are timed on (d_in, d_out, count)
MEASURE_SHAPES = [(128, 128, 1)]


def run():
    rows = []
    report = {}
    gap_cache = {}  # one fenced dispatch per distinct (tile, w, a, sparsity)
    measure_cache = {}  # one measure_tile row per distinct (tile, w, a, spars)
    for net, layers_fn, graph_fn in NETWORKS:
        graph = graph_fn()
        for (w, a) in [(8, 4), (8, 8)]:
            analytic = PM.summarize(layers_fn(), w, a)
            sim = sched.simulate(graph, w_bits=w, a_bits=a, pipeline=True)
            cv = sched.cross_validate(layers_fn(), w_bits=w, a_bits=a,
                                      dense=True)
            search = sched.search_mapping(graph, w_bits=w, a_bits=a)
            schedule = sched.schedule_from_search(graph, search, w_bits=w,
                                                  a_bits=a)
            key = f"{net}_w{w}a{a}"
            tile = tuple(search.best.candidate.tile)
            spars = round(float(np.mean([l.sparsity_gs
                                         for l in layers_fn()])), 3)

            # observe->tune: fenced wall clock over the top-N searched tiles
            shortlist, seen = [], set()
            for r in sorted(search.table, key=lambda r: r.fps, reverse=True):
                if r.candidate.tile not in seen:
                    seen.add(r.candidate.tile)
                    shortlist.append(r.candidate.tile)
                if len(shortlist) >= TOP_N:
                    break
            measured = {}
            for t in shortlist:
                mk = (t, w, a, spars)
                if mk not in measure_cache:
                    measure_cache[mk] = AT.measure_tile(
                        MEASURE_SHAPES, t, spars, w_bits=w, a_bits=a,
                        repeats=2, stack_layers=2)
                measured[t] = measure_cache[mk]
            best_tile = min(measured, key=lambda t: measured[t]["total_s"])
            # the simulated pick is always in the shortlist, so the measured
            # winner can never clock slower on the timed workload
            assert measured[best_tile]["total_s"] <= measured[tile]["total_s"]
            schedule.measured_tile = best_tile

            # cost-constant re-fit over every sample this entry measured
            refit = AT.refit_from_table(list(measured.values()))
            best_samples = measured[best_tile]["samples"]
            meas_total = sum(s["measured_s"] for s in best_samples)
            pred_total = sum(refit.predict_seconds(s["phases"])
                             for s in best_samples)

            entry = {
                "fps_analytic": round(analytic.fps, 1),
                "fps_sim": round(sim.fps, 1),
                "fps_searched": round(search.best.fps, 1),
                "dense_sim_vs_analytic": round(cv["ratio"], 3),
                "searched_tile": list(tile),
                "measured_tile": list(best_tile),
                "search_speedup": round(search.speedup_vs_default, 3),
                "core_utilization": round(sim.core_utilization, 3),
                "schedule": schedule.to_json(),
            }
            gk = (tile, w, a, spars)
            if gk not in gap_cache:
                gap_cache[gk] = obs_gap.kernel_gap(
                    32, 128, 128, tile, spars, w_bits=w, a_bits=a)
            entry["sim_vs_measured"] = dict(gap_cache[gk])
            entry["sim_vs_measured"]["post_refit"] = {
                "gap": round(meas_total / max(pred_total, 1e-18), 4),
                "residual": round(refit.residual, 4),
                "n_samples": refit.n_samples,
                "seconds_per_cycle": {k: float(f"{v:.6g}") for k, v in
                                      refit.seconds_per_cycle.items()},
                "measured_tile_wall_s": round(meas_total, 6),
                "sim_tile_wall_s": round(
                    sum(s["measured_s"]
                        for s in measured[tile]["samples"]), 6),
            }
            report[key] = entry
            rows.append({
                "name": f"sched_{key}",
                "fps_analytic": entry["fps_analytic"],
                "fps_sim": entry["fps_sim"],
                "fps_searched": entry["fps_searched"],
                "dense_ratio": entry["dense_sim_vs_analytic"],
                "tile": f"{search.best.candidate.group}x"
                        f"{search.best.candidate.alpha}",
                "measured_tile": f"{best_tile[0]}x{best_tile[1]}",
                "util": entry["core_utilization"],
                "gap": entry["sim_vs_measured"]["sim_vs_measured"],
                "gap_post_refit":
                    entry["sim_vs_measured"]["post_refit"]["gap"],
            })
    with open(os.path.abspath(OUT_PATH), "w") as f:
        json.dump(report, f, indent=1)
    return rows


def main():
    for r in run():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
