"""Radix-tree prefix KV reuse with copy-on-write paged blocks.

The contracts under test:

  * sharing never changes tokens: with ``prefix_cache`` on, every engine
    (loop, scan, spec; single-device and macro-sharded) emits greedy tokens
    BIT-IDENTICAL to the same trace served with sharing off, while the
    report shows real cache hits;
  * copy-on-write isolates writers: a write into a block shared by two
    tables (or the trie) copies the block - every tier of it - and repoints
    only the writer, leaving the other readers' K/V untouched;
  * the trie itself matches longest full-block prefixes (capped so a
    suffix token always remains), retains what it registers, and its LRU
    eviction only drops blocks it is the last holder of.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.serve import deployed as DP
from repro.serve import spec as SP
from repro.serve.batching import PagedKVCache, Request
from repro.serve.engine import ServeConfig
from repro.serve.prefix import PrefixTrie
from repro.serve.server import BatchConfig, BatchServer
from repro.serve.spec import SpecConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def dense_model():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prefix_trace(cfg, n=8, shared_len=8, suffix_max=4, max_new=5, seed=3):
    """n requests, ~3/4 sharing one ``shared_len``-token system prompt."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab, shared_len).astype(np.int32)
    reqs = []
    for i in range(n):
        if i % 4 != 3:
            sfx = rng.integers(0, cfg.vocab,
                               int(rng.integers(1, suffix_max + 1)))
            p = np.concatenate([system, sfx.astype(np.int32)])
        else:
            p = rng.integers(0, cfg.vocab, shared_len + 1).astype(np.int32)
        reqs.append(Request(f"r{i}", p, max_new))
    return reqs


# ---------------------------------------------------------------------------
# PrefixTrie unit behaviour
# ---------------------------------------------------------------------------


def test_trie_match_caps_below_full_prompt(dense_model):
    """A match never swallows the whole prompt: >= 1 suffix token must
    remain to produce the first output token."""
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=16, block_size=4)
    trie = PrefixTrie(kv)
    prompt = np.arange(12, dtype=np.int32)
    kv.ensure(0, 12)
    trie.insert(prompt, kv.tables[0][:3])
    # identical prompt: only 2 of the 3 registered blocks may match
    assert trie.match(prompt) == kv.tables[0][:2]
    # longer prompt with the same prefix: all 3 match
    assert trie.match(np.arange(13, dtype=np.int32)) == kv.tables[0][:3]
    # diverging second block: only the first matches
    other = np.concatenate([np.arange(4), [99] * 8]).astype(np.int32)
    assert trie.match(other) == kv.tables[0][:1]
    assert trie.match(np.asarray([7, 7], np.int32)) == []


def test_trie_insert_retains_and_survives_free_slot(dense_model):
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=16, block_size=4)
    trie = PrefixTrie(kv)
    prompt = np.arange(9, dtype=np.int32)
    kv.ensure(0, 9)
    held = list(kv.tables[0])
    trie.insert(prompt[:8], kv.tables[0][:2])
    assert kv.refcnt[held[0]] == 2 and kv.refcnt[held[1]] == 2
    assert kv.refcnt[held[2]] == 1  # partial block never registered
    kv.free_slot(0)
    # registered blocks outlive the producing slot; the partial one freed
    assert kv.refcnt[held[0]] == 1 and kv.refcnt[held[1]] == 1
    assert kv.refcnt[held[2]] == 0
    assert trie.match(prompt) == held[:2]
    # re-inserting the same chunks must not double-retain
    kv.ensure(1, 8)
    trie.insert(prompt[:8], held[:2])
    assert kv.refcnt[held[0]] == 1


def test_trie_lru_eviction_frees_only_last_holder(dense_model):
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=16, block_size=4)
    trie = PrefixTrie(kv)
    kv.ensure(0, 8)
    a = list(kv.tables[0])
    trie.insert(np.arange(8, dtype=np.int32), a)
    kv.ensure(1, 4)
    b = list(kv.tables[1])
    trie.insert(np.asarray([50, 51, 52, 53], np.int32), b)
    # chain a is still held by slot 0 => refcnt 2, not evictable; only the
    # leaf of chain b (slot 1 freed below) can actually free a block
    kv.free_slot(1)
    trie.match(np.arange(9, dtype=np.int32))  # touch a: b becomes LRU
    freed = trie.evict(1)
    assert freed == 1
    assert kv.refcnt[b[0]] == 0 and b[0] in kv._free
    assert trie.match(np.asarray([50, 51, 52, 53, 0], np.int32)) == []
    # nothing else is evictable while slot 0 holds chain a
    assert trie.evict(5) == 0
    assert trie.match(np.arange(9, dtype=np.int32)) == a[:2]


# ---------------------------------------------------------------------------
# Copy-on-write at the pool level
# ---------------------------------------------------------------------------


def test_cow_write_isolates_shared_block(dense_model):
    """A decode write into a shared block copies it first: the sharer keeps
    the original K/V bit-for-bit."""
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=8, block_size=2)
    L_, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    rng = np.random.default_rng(0)
    k0 = rng.standard_normal((L_, 4, KV, dh)).astype(np.float32)
    kv.write_prefill(0, jnp.asarray(k0), jnp.asarray(2 * k0), true_len=4)
    kv.adopt(1, list(kv.tables[0]))
    assert kv.tables[1] == kv.tables[0]
    snap = {b: kv.pool_k[0, b].copy() for b in kv.tables[0]}
    # slot 1 overwrites position 1 (inside the first shared block)
    kn = rng.standard_normal((L_, 2, KV, dh)).astype(np.float32)
    pb, off = kv.write_coords([None, 1])
    kv.write_token(pb, off, jnp.asarray(kn), jnp.asarray(kn))
    assert kv.n_cow == 1
    assert kv.tables[1][0] != kv.tables[0][0]  # writer repointed
    assert kv.tables[1][1] == kv.tables[0][1]  # untouched block still shared
    for b, want in snap.items():  # reader's payload untouched
        np.testing.assert_array_equal(kv.pool_k[0, b], want)
    # writer's copy carries the original data plus the new entry
    nb = kv.tables[1][0]
    np.testing.assert_array_equal(kv.pool_k[0, nb, :, 0], snap[kv.tables[0][0]][:, 0])
    np.testing.assert_array_equal(kv.pool_k[0, nb, :, 1], kn[:, 1])
    assert kv.free_blocks + kv.blocks_in_use == kv.n_blocks - 1


def test_cow_copies_every_tier(dense_model):
    """Tiers share one refcount ledger: CoW on a two-tier pool must copy
    the draft tier alongside the target tier."""
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=8, block_size=2, tiers=2)
    L_, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    rng = np.random.default_rng(1)
    k = rng.standard_normal((L_, 2, KV, dh)).astype(np.float32)
    kv.write_prefill(0, jnp.asarray(k), jnp.asarray(k), true_len=2, tier=0)
    kv.write_prefill(0, jnp.asarray(3 * k), jnp.asarray(3 * k), true_len=2,
                     tier=1)
    kv.adopt(1, list(kv.tables[0]))
    kn = rng.standard_normal((L_, 2, KV, dh)).astype(np.float32)
    pb, off = kv.write_coords([None, 0])
    kv.write_token(pb, off, jnp.asarray(kn), jnp.asarray(kn), tier=0)
    nb, ob = kv.tables[1][0], kv.tables[0][0]
    assert nb != ob
    # tier 1 of the copy carries the draft KV even though only tier 0 wrote
    np.testing.assert_array_equal(kv.pool_k[1, nb], kv.pool_k[1, ob])
    assert np.any(kv.pool_k[1, nb])


def test_write_prefill_start_must_be_block_aligned(dense_model):
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=1, n_blocks=8, block_size=4)
    L_, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    k = np.zeros((L_, 4, KV, dh), np.float32)
    with pytest.raises(ValueError, match="block_size"):
        kv.write_prefill(0, jnp.asarray(k), jnp.asarray(k), true_len=4,
                         start=2)


# ---------------------------------------------------------------------------
# Bit-exactness: sharing on == sharing off (loop, scan, spec)
# ---------------------------------------------------------------------------


def _run_pair(cfg, sp, reqs, engine, bcfg, **kw):
    on = BatchServer(cfg, sp, scfg=ServeConfig(), bcfg=bcfg,
                     engine=engine, **kw).run(
        [dataclasses.replace(r) for r in reqs])
    off = BatchServer(cfg, sp, scfg=ServeConfig(),
                      bcfg=dataclasses.replace(bcfg, prefix_cache=False),
                      engine=engine, **kw).run(
        [dataclasses.replace(r) for r in reqs])
    return on, off


@pytest.mark.parametrize("engine", ["loop", "scan"])
def test_prefix_sharing_tokens_bit_identical(dense_model, engine):
    cfg, params = dense_model
    sp = DP.from_params(cfg, params)
    reqs = _prefix_trace(cfg)
    bcfg = BatchConfig(n_slots=3, block_size=4, n_blocks=48)
    on, off = _run_pair(cfg, sp, reqs, engine, bcfg)
    for r in reqs:
        np.testing.assert_array_equal(
            on.outputs[r.rid], off.outputs[r.rid],
            err_msg=f"{engine}: sharing changed {r.rid}'s tokens")
    assert on.prefix["hits"] > 0, "trace produced no cache hits"
    assert on.prefix["hit_tokens"] > 0
    assert off.prefix is None  # sharing off reports no prefix block
    # shared blocks are counted once: the sharing run allocates fewer
    assert on.kv_stats["allocations"] < off.kv_stats["allocations"]


def test_prefix_sharing_tokens_bit_identical_spec(dense_model):
    cfg0, _ = dense_model
    cfg = registry.get_smoke_config("yi-6b", dtype="float32",
                                    cim_mode="qat")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    draft = SP.draft_serving(cfg, sp, 0.9)
    reqs = _prefix_trace(cfg, n=5, max_new=4)
    bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=48)
    on, off = _run_pair(cfg, sp, reqs, "spec", bcfg, draft=draft,
                        spec=SpecConfig(k=3, draft_sparsity=0.9))
    for r in reqs:
        np.testing.assert_array_equal(
            on.outputs[r.rid], off.outputs[r.rid],
            err_msg=f"spec: sharing changed {r.rid}'s tokens")
    assert on.prefix["hits"] > 0


def test_prefix_report_shape(dense_model):
    cfg, params = dense_model
    sp = DP.from_params(cfg, params)
    rep = BatchServer(cfg, sp, scfg=ServeConfig(),
                      bcfg=BatchConfig(n_slots=2, block_size=4, n_blocks=48)
                      ).run(_prefix_trace(cfg, n=4))
    j = rep.to_json()
    assert "prefix" in j
    for key in ("lookups", "hits", "hit_rate", "hit_tokens", "cow_copies",
                "ttft_service_hit", "ttft_service_miss"):
        assert key in j["prefix"], key
    assert j["prefix"]["lookups"] == 4


# ---------------------------------------------------------------------------
# Macro-sharded parity (subprocess: forced host devices need XLA_FLAGS
# before jax imports - same pattern as tests/test_sharded_serve.py)
# ---------------------------------------------------------------------------


def run_sub(code: str):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = " ".join(
        ([env["XLA_FLAGS"]] if env.get("XLA_FLAGS") else [])
        + ["--xla_force_host_platform_device_count=8"])
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_prefix_sharing_macro_sharded_parity():
    out = run_sub("""
import dataclasses
import numpy as np, jax
from jax.sharding import Mesh
from repro.models import registry
from repro.serve import deployed as DP
from repro.serve.batching import Request
from repro.serve.engine import ServeConfig
from repro.serve.server import BatchConfig, BatchServer

cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
mesh = Mesh(np.array(jax.devices()[:2]), ("macro",))
ssp = DP.shard(sp, mesh)

rng = np.random.default_rng(3)
system = rng.integers(0, cfg.vocab, 8).astype(np.int32)
reqs = []
for i in range(5):
    if i != 2:
        p = np.concatenate([system,
                            rng.integers(0, cfg.vocab, 1 + i % 3).astype(np.int32)])
    else:
        p = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    reqs.append(Request(f"r{i}", p, 4))

bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=48)
on = BatchServer(cfg, ssp, scfg=ServeConfig(), bcfg=bcfg, mesh=mesh,
                 engine="scan").run([dataclasses.replace(r) for r in reqs])
off = BatchServer(cfg, ssp, scfg=ServeConfig(),
                  bcfg=dataclasses.replace(bcfg, prefix_cache=False),
                  mesh=mesh, engine="scan").run(
    [dataclasses.replace(r) for r in reqs])
assert on.prefix["hits"] > 0, on.prefix
for r in reqs:
    np.testing.assert_array_equal(on.outputs[r.rid], off.outputs[r.rid])
print("OK hits=", on.prefix["hits"])
""")
    assert "OK" in out
