"""Tensor-parallel compressed serving over the macro-cluster mesh.

The contract under test: sharding is a PLACEMENT decision, never a numeric
one. Column-sharding every DeployedWeight over a ``macro`` mesh axis (with
the scheduler's LPT assignment), sharding the paged-KV views heads-wise and
scaling the block pool must reproduce the single-device compressed engine's
greedy tokens BIT-EXACTLY on the same requests.

Multi-device cases run in subprocesses with 8 fake CPU devices (XLA_FLAGS
must be set before jax imports, so in-process tests can't do it) - same
pattern as tests/test_distributed.py. Single-device fallback behaviour is
tested in-process.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    # forced host devices only exist on the CPU backend: pin the platform
    # and append to - don't clobber - any flags the caller set
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = " ".join(
        ([env["XLA_FLAGS"]] if env.get("XLA_FLAGS") else [])
        + ["--xla_force_host_platform_device_count=8"])
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# in-process: single-device fallback + sharding preconditions
# ---------------------------------------------------------------------------


def _packed_weight(ts=0.5, d_in=64, d_out=128, bk=16, bn=16):
    from repro.core import deploy as D
    from repro.core.cim_layer import CIMConfig
    from repro.core.quant import QuantConfig
    from repro.core.sparsity import SparsityConfig

    cim = CIMConfig(
        quant=QuantConfig(w_bits=8, a_bits=8, group_size=16, a_signed=True),
        sparsity=SparsityConfig(alpha=16, n=16, target_sparsity=ts),
        mode="qat")
    rng = np.random.default_rng(0)
    w = rng.standard_normal((d_in, d_out)).astype(np.float32) * 0.2
    return D.deploy_weight(w, cim, bk=bk, bn=bn, target_sparsity=ts)


def test_shard_weight_single_device_is_identity():
    from jax.sharding import Mesh
    from repro.core import deploy as D

    dw = _packed_weight()
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("macro",))
    assert D.shard_weight(dw, mesh) is dw  # nothing to split over 1 device
    assert dw.mesh is None


def test_shard_weight_ragged_columns_stay_replicated():
    """go=8 columns cannot split over 3 devices: the projection must be
    served replicated, not crash or drop columns."""
    from jax.sharding import Mesh
    from repro.core import deploy as D

    dw = _packed_weight()
    n = min(3, len(jax.devices()))
    mesh = Mesh(np.asarray(jax.devices()[:n]), ("macro",))
    out = D.shard_weight(dw, mesh)
    if n == 1 or 8 % n == 0:
        pytest.skip("host devices make the split even")
    assert out is dw
    assert not D.shardable_columns(dw, 3)


def test_shardable_columns_predicate():
    from repro.core import deploy as D

    dw = _packed_weight(d_out=128, bn=16)  # 8 block columns
    assert D.shardable_columns(dw, 2)
    assert D.shardable_columns(dw, 4)
    assert not D.shardable_columns(dw, 3)


def test_macro_mesh_bounds():
    from repro.launch import shardings

    m = shardings.macro_mesh(1)
    assert m.axis_names == ("macro",)
    with pytest.raises(ValueError, match="devices"):
        shardings.macro_mesh(len(jax.devices()) + 1)


def test_parse_mesh_flag():
    from repro.launch.serve import _parse_mesh, _parse_tile

    assert _parse_mesh("") is None
    assert _parse_mesh("macro=1").shape == {"macro": 1}
    with pytest.raises(SystemExit):
        _parse_mesh("model=2")
    assert _parse_tile("16x16") == (16, 16)
    assert _parse_tile("") is None
    for bad in ("16", "8y8", "0x8", "axb"):
        with pytest.raises(SystemExit):
            _parse_tile(bad)


def test_serve_kv_view_spec_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.launch import shardings
    from repro.models import registry

    cfg = registry.get_smoke_config("yi-6b", dtype="float32")  # 2 KV heads
    mesh = shardings.macro_mesh(1)
    assert shardings.serve_kv_view_spec(cfg, mesh) == P()


# ---------------------------------------------------------------------------
# multi-device: sharded == single-device, bit-exact
# ---------------------------------------------------------------------------


def test_sharded_projection_matmul_bit_identical():
    """shard_weight + the shard_map'd kernel == the single-device kernel,
    eager and jitted, on 2- and 4-device macro meshes."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import deploy as D
from repro.core.cim_layer import CIMConfig
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig
from repro.sched.allocate import device_assignment

cim = CIMConfig(quant=QuantConfig(w_bits=8, a_bits=8, group_size=16, a_signed=True),
                sparsity=SparsityConfig(alpha=16, n=16, target_sparsity=0.5), mode="qat")
rng = np.random.default_rng(0)
w = rng.standard_normal((64, 128)).astype(np.float32) * 0.2
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
dw = D.deploy_weight(w, cim, bk=16, bn=16, target_sparsity=0.5)
want = np.asarray(D.deployed_matmul(x, dw, a_bits=8, interpret=True))
for n in (2, 4):
    mesh = Mesh(np.asarray(jax.devices()[:n]), ("macro",))
    dws = D.shard_weight(dw, mesh, assign=device_assignment)
    assert dws.mesh is not None
    # per-device residency really is go/n columns of the original packing
    go = dw.packed[0]["blocks"].shape[0]
    assert dws.packed[0]["blocks"].addressable_shards[0].data.shape[0] == go // n
    got = np.asarray(D.deployed_matmul(x, dws, a_bits=8, interpret=True))
    np.testing.assert_array_equal(got, want)
    f = jax.jit(lambda x, d: D.deployed_matmul(x, d, a_bits=8, interpret=True))
    np.testing.assert_array_equal(np.asarray(f(x, dws)), want)
print("OK")
""")
    assert "OK" in out


@pytest.mark.parametrize("n_dev", [2, 4])
def test_sharded_decode_matches_single_device(n_dev):
    """Acceptance: BatchServer over a forced multi-device host mesh produces
    bit-identical greedy tokens to the single-device compressed engine on
    the same trace (KV heads shard at macro=2; at macro=4 the 2 KV heads
    stay replicated while projections still shard - both must be exact)."""
    out = run_sub(f"""
import numpy as np, jax
from repro.models import registry
from repro.serve import BatchConfig, BatchServer, ServeConfig, Request
from repro.serve import deployed as DP
from repro.launch.shardings import macro_mesh

cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
def trace():
    rng = np.random.default_rng(7)
    return [Request(f"r{{i}}", rng.integers(0, cfg.vocab, int(rng.integers(2, 12))),
                    int(rng.integers(1, 7))) for i in range(5)]
sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
want = BatchServer(cfg, sp, ServeConfig(), bcfg).run(trace())
mesh = macro_mesh({n_dev})
sps = DP.shard(sp, mesh)
n_sharded = sum(1 for dw in sps.deployed().values() if dw.mesh is not None)
assert n_sharded > 0, "no projection actually sharded"
srv = BatchServer(cfg, sps, ServeConfig(), bcfg, mesh=mesh)
rep = srv.run(trace())
assert rep.kv_stats["n_devices"] == {n_dev}
# the pool scales ONLY when KV heads actually shard (2 heads: macro=2
# shards them, macro=4 cannot and must keep the single-device budget)
heads_shard = cfg.n_kv_heads_eff % {n_dev} == 0
assert rep.kv_stats["kv_heads_sharded"] == heads_shard
assert rep.kv_stats["n_blocks"] == 24 * ({n_dev} if heads_shard else 1)
for r in trace():
    np.testing.assert_array_equal(rep.outputs[r.rid], want.outputs[r.rid],
                                  err_msg=r.rid)
print("sharded", n_sharded, "OK")
""")
    assert "OK" in out


def test_sharded_static_admission_also_exact():
    """The static (whole-batch) policy rides the same sharded kernels."""
    out = run_sub("""
import numpy as np, jax
from repro.models import registry
from repro.serve import BatchConfig, BatchServer, ServeConfig, Request
from repro.serve import deployed as DP
from repro.launch.shardings import macro_mesh

cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
def trace():
    rng = np.random.default_rng(3)
    return [Request(f"r{i}", rng.integers(0, cfg.vocab, int(rng.integers(2, 10))),
                    int(rng.integers(1, 6))) for i in range(4)]
sp = DP.compress(cfg, params, target_sparsity=0.0, tile=(16, 16))
bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
want = BatchServer(cfg, sp, ServeConfig(), bcfg, continuous=False).run(trace())
mesh = macro_mesh(2)
srv = BatchServer(cfg, DP.shard(sp, mesh), ServeConfig(), bcfg,
                  continuous=False, mesh=mesh)
rep = srv.run(trace())
for r in trace():
    np.testing.assert_array_equal(rep.outputs[r.rid], want.outputs[r.rid])
print("OK")
""")
    assert "OK" in out
