"""Deployment path: QAT-sim oracle == BSR-kernel serving path, the packing
round-trip / kernel differential suite over randomized shapes, tilings and
sparsity levels, plus the Table IV-style storage accounting on a trained
LM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deploy
from repro.core import mapping as M
from repro.core.cim_layer import CIMConfig
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig
from repro.kernels import cim_bsr_matmul as K
from repro.models import registry


def _cim(w_bits=4, a_bits=8, ts=0.5, alpha=16):
    return CIMConfig(
        quant=QuantConfig(w_bits=w_bits, a_bits=a_bits, group_size=alpha,
                          a_signed=True),
        sparsity=SparsityConfig(alpha=alpha, n=alpha, target_sparsity=ts),
        mode="qat",
    )


@pytest.mark.parametrize("w_bits,ts", [(4, 0.5), (8, 0.75), (4, 0.0)])
def test_deployed_matmul_matches_reference(w_bits, ts):
    cim = _cim(w_bits=w_bits, ts=ts)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 64)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    dw = deploy.deploy_weight(w, cim, bk=16, bn=16, target_sparsity=ts)
    got = deploy.deployed_matmul(x, dw, a_bits=cim.quant.a_bits,
                                 interpret=True)
    want = deploy.reference_matmul(x, w, cim, target_sparsity=ts, bk=16, bn=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    if ts > 0:
        assert dw.density < 1.0  # blocks actually dropped


# ---------------------------------------------------------------------------
# pack_bsr <-> bsr_to_dense round-trip and kernel differential, randomized
# over shapes, tilings and sparsity (seeded; the hypothesis variants live in
# tests/test_properties.py)
# ---------------------------------------------------------------------------


def _block_sparse(rng, gi, go, bk, bn, density):
    keep = rng.random((gi, go)) < density
    w = rng.standard_normal((gi * bk, go * bn)).astype(np.float32)
    return w * np.repeat(np.repeat(keep, bk, 0), bn, 1), keep


@pytest.mark.parametrize("seed", range(6))
def test_pack_bsr_roundtrip_randomized(seed):
    rng = np.random.default_rng(seed)
    bk, bn = int(rng.choice([4, 8, 16])), int(rng.choice([4, 8, 16]))
    gi, go = int(rng.integers(1, 6)), int(rng.integers(1, 6))
    w, keep = _block_sparse(rng, gi, go, bk, bn, float(rng.uniform(0, 1)))
    bsr = M.pack_bsr(w, bk, bn)
    np.testing.assert_array_equal(M.bsr_to_dense(bsr), w)
    assert bsr.nnz.sum() == keep.sum()


@pytest.mark.parametrize("seed", range(6, 10))
def test_pack_bsr_nnz_max_truncation(seed):
    """An explicit nnz_max below the true max drops the LAST surviving rows
    of over-full columns; ``nnz`` keeps the TRUE counts (for stats) while
    ``bsr_to_dense`` reconstructs only the stored slots."""
    rng = np.random.default_rng(seed)
    bk = bn = 8
    gi, go = int(rng.integers(3, 7)), int(rng.integers(1, 5))
    w, keep = _block_sparse(rng, gi, go, bk, bn, 0.9)
    cap = int(rng.integers(1, max(keep.sum(axis=0).max(), 2)))
    bsr = M.pack_bsr(w, bk, bn, nnz_max=cap)
    assert bsr.blocks.shape[1] == cap
    np.testing.assert_array_equal(bsr.nnz, keep.sum(axis=0))  # true counts
    want = np.zeros_like(w)
    for j in range(go):
        for i in np.flatnonzero(keep[:, j])[:cap]:
            want[i * bk:(i + 1) * bk, j * bn:(j + 1) * bn] = \
                w[i * bk:(i + 1) * bk, j * bn:(j + 1) * bn]
    np.testing.assert_array_equal(M.bsr_to_dense(bsr), want)


def test_pack_bsr_all_zero_weight():
    """Everything pruned: one padding slot per column, row_idx 0, and the
    kernel must still produce exact zeros (padding is masked, not summed)."""
    w = np.zeros((32, 24), np.float32)
    bsr = M.pack_bsr(w, 8, 8)
    assert bsr.nnz.tolist() == [0, 0, 0]
    assert bsr.blocks.shape[1] == 1  # nnz_max floors at one (inert) slot
    np.testing.assert_array_equal(M.bsr_to_dense(bsr), w)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((5, 32)),
                    jnp.float32)
    y = K.bsr_matmul(x, jnp.asarray(bsr.blocks),
                     jnp.ones(bsr.row_idx.shape, jnp.float32),
                     jnp.asarray(bsr.row_idx), jnp.asarray(bsr.nnz),
                     bm=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(y), np.zeros((5, 24)))


@pytest.mark.parametrize("seed", range(10, 16))
def test_bsr_kernel_matches_dense_randomized(seed):
    """cim_bsr_matmul == x @ bsr_to_dense(packing) across random shapes,
    tilings and densities - including truncated packings, where BOTH sides
    see only the stored slots."""
    rng = np.random.default_rng(seed)
    bk, bn = int(rng.choice([8, 16])), int(rng.choice([8, 16]))
    gi, go = int(rng.integers(1, 5)), int(rng.integers(1, 5))
    m = int(rng.integers(1, 17))
    w, keep = _block_sparse(rng, gi, go, bk, bn, float(rng.uniform(0, 1)))
    truncate = bool(rng.integers(2)) and keep.sum(axis=0).max() > 1
    cap = (int(rng.integers(1, keep.sum(axis=0).max() + 1)) if truncate
           else None)
    bsr = M.pack_bsr(w, bk, bn, nnz_max=cap)
    x = rng.standard_normal((m, gi * bk)).astype(np.float32)
    y = K.bsr_matmul(jnp.asarray(x), jnp.asarray(bsr.blocks),
                     jnp.ones(bsr.row_idx.shape, jnp.float32),
                     jnp.asarray(bsr.row_idx), jnp.asarray(bsr.nnz),
                     bm=max(8, min(128, m)), interpret=True)
    want = x @ M.bsr_to_dense(bsr)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("seed", range(16, 20))
def test_deployed_matmul_randomized_shapes(seed):
    """deploy_weight -> deployed_matmul == reference_matmul on random
    (d_in, d_out), tile and sparsity draws (the end-to-end differential the
    serving path rides on)."""
    rng = np.random.default_rng(seed)
    bk, bn = int(rng.choice([8, 16, 32])), int(rng.choice([8, 16, 32]))
    d_in = bk * int(rng.integers(1, 5))
    d_out = bn * int(rng.integers(1, 5))
    ts = float(rng.choice([0.0, 0.25, 0.5, 0.75]))
    w_bits = int(rng.choice([4, 8]))
    cim = _cim(w_bits=w_bits, ts=ts)
    w = jnp.asarray(rng.standard_normal((d_in, d_out)) * 0.2, jnp.float32)
    x = jnp.asarray(rng.standard_normal((int(rng.integers(1, 9)), d_in)),
                    jnp.float32)
    dw = deploy.deploy_weight(w, cim, bk=bk, bn=bn, target_sparsity=ts)
    got = deploy.deployed_matmul(x, dw, a_bits=cim.quant.a_bits,
                                 interpret=True)
    want = deploy.reference_matmul(x, w, cim, target_sparsity=ts,
                                   bk=bk, bn=bn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_deploy_stacked_lm_layers():
    """Deploy a real (stacked) LM projection and check accounting."""
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    cim = _cim(w_bits=8, ts=0.5)
    dw = deploy.deploy_weight(params["layers"]["w_up"], cim, bk=16, bn=16)
    assert len(dw.packed) == cfg.n_layers
    x = jax.random.normal(jax.random.PRNGKey(2), (4, cfg.d_model))
    for layer in range(cfg.n_layers):
        y = deploy.deployed_matmul(x, dw, layer=layer, interpret=True)
        assert y.shape == (4, cfg.d_ff)
        assert bool(jnp.all(jnp.isfinite(y)))
    rep = deploy.deployment_report({"w_up": dw})
    # fp32 dense -> 8-bit weights at ~50% block sparsity: > 4x compression
    assert rep["compression_x"] > 4.0, rep
    assert rep["weight_Mb"] < rep["dense_Mb"]


@pytest.mark.parametrize("seed", [0, 7, 23])
def test_stack_deployed_matches_per_layer_seeded(seed):
    """Seeded envelope-parity sweep: mixed per-layer sparsities (including
    an all-zero layer) stacked into one uniform envelope must reproduce the
    per-layer kernel bit-for-bit through the layer-indexed entry point."""
    cim = _cim(w_bits=8, ts=0.5)
    rng = np.random.default_rng(seed)
    dws = []
    for ts in (0.0, float(rng.uniform(0.2, 0.8)), 1.0):
        w = rng.standard_normal((64, 96)).astype(np.float32) * 0.3
        if ts >= 1.0:
            w = np.zeros_like(w)
            ts = 0.5
        dws.append(deploy.deploy_weight(w, cim, bk=16, bn=16,
                                        target_sparsity=ts))
    sw = deploy.stack_deployed(dws)
    assert sw.n_layers == 3 and sw.tile == (16, 16)
    x = jnp.asarray(rng.standard_normal((6, 64)), jnp.float32)
    for i, dw in enumerate(dws):
        np.testing.assert_array_equal(
            np.asarray(deploy.stacked_matmul(x, sw, i, a_bits=8,
                                             interpret=True)),
            np.asarray(deploy.deployed_matmul(x, dw, a_bits=8,
                                              interpret=True)),
            err_msg=f"seed={seed} layer={i}")


def test_stack_deployed_accepts_multilayer_weight():
    """A deploy_weight over a stacked (L, d, d) master weight already holds
    L packed dicts - stack_deployed folds them into the same envelope as L
    separate single-layer weights."""
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    cim = _cim(w_bits=8, ts=0.5)
    dw = deploy.deploy_weight(params["layers"]["w_up"], cim, bk=16, bn=16)
    sw = deploy.stack_deployed(dw)
    assert sw.n_layers == cfg.n_layers
    x = jax.random.normal(jax.random.PRNGKey(2), (4, cfg.d_model))
    for layer in range(cfg.n_layers):
        np.testing.assert_array_equal(
            np.asarray(deploy.stacked_matmul(x, sw, layer, interpret=True)),
            np.asarray(deploy.deployed_matmul(x, dw, layer=layer,
                                              interpret=True)))


def test_uniform_fit_tile():
    shapes = [(64, 64), (64, 32), (128, 64), (64, 256)]
    assert deploy.uniform_fit_tile(shapes, 16, 16) == (16, 16)
    assert deploy.uniform_fit_tile(shapes, 48, 48) == (32, 32)
    assert deploy.uniform_fit_tile([(60, 90)], 16, 16) == (15, 15)
    assert deploy.uniform_fit_tile([], 16, 16) == (16, 16)
