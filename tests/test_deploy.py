"""Deployment path: QAT-sim oracle == BSR-kernel serving path, plus the
Table IV-style storage accounting on a trained LM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deploy
from repro.core.cim_layer import CIMConfig
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig
from repro.models import registry


def _cim(w_bits=4, a_bits=8, ts=0.5, alpha=16):
    return CIMConfig(
        quant=QuantConfig(w_bits=w_bits, a_bits=a_bits, group_size=alpha,
                          a_signed=True),
        sparsity=SparsityConfig(alpha=alpha, n=alpha, target_sparsity=ts),
        mode="qat",
    )


@pytest.mark.parametrize("w_bits,ts", [(4, 0.5), (8, 0.75), (4, 0.0)])
def test_deployed_matmul_matches_reference(w_bits, ts):
    cim = _cim(w_bits=w_bits, ts=ts)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (128, 64)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    dw = deploy.deploy_weight(w, cim, bk=16, bn=16, target_sparsity=ts)
    got = deploy.deployed_matmul(x, dw, a_bits=cim.quant.a_bits,
                                 interpret=True)
    want = deploy.reference_matmul(x, w, cim, target_sparsity=ts, bk=16, bn=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    if ts > 0:
        assert dw.density < 1.0  # blocks actually dropped


def test_deploy_stacked_lm_layers():
    """Deploy a real (stacked) LM projection and check accounting."""
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    cim = _cim(w_bits=8, ts=0.5)
    dw = deploy.deploy_weight(params["layers"]["w_up"], cim, bk=16, bn=16)
    assert len(dw.packed) == cfg.n_layers
    x = jax.random.normal(jax.random.PRNGKey(2), (4, cfg.d_model))
    for layer in range(cfg.n_layers):
        y = deploy.deployed_matmul(x, dw, layer=layer, interpret=True)
        assert y.shape == (4, cfg.d_ff)
        assert bool(jnp.all(jnp.isfinite(y)))
    rep = deploy.deployment_report({"w_up": dw})
    # fp32 dense -> 8-bit weights at ~50% block sparsity: > 4x compression
    assert rep["compression_x"] > 4.0, rep
    assert rep["weight_Mb"] < rep["dense_Mb"]
