"""Multi-device behaviour, run in subprocesses with 8 fake CPU devices
(XLA_FLAGS must be set before jax import, so in-process tests can't do it).

Covers: compressed-DP equivalence, pipeline-parallel equivalence, ZeRO-1
sharding specs, elastic checkpoint re-mesh.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_compressed_dp_matches_plain():
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.models import registry
from repro.train import TrainConfig, OptConfig, init_train_state, make_train_step
from repro.train.compression import make_compressed_dp_train_step, init_error_state
from repro.data import TokenPipeline

cfg = registry.get_smoke_config("yi-6b", dtype="float32")
tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1, clip_norm=0.0))
state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq_len=16)
batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}

plain = jax.jit(make_train_step(cfg, tcfg))
s_plain, m_plain = plain(state, batch)

mesh = jax.make_mesh((8,), ("data",))
comp = jax.jit(make_compressed_dp_train_step(cfg, tcfg, mesh, "data"))
cstate = dict(state); cstate["err"] = init_error_state(state["params"])
s_comp, m_comp = comp(cstate, batch)

print("plain", float(m_plain["loss"]), "comp", float(m_comp["loss"]))
assert abs(float(m_plain["loss"]) - float(m_comp["loss"])) < 1e-3
# params close despite int8 gradient wire format: Adam normalizes the
# update, so a per-step divergence up to ~2*lr on near-zero grads is the
# expected compression cost - anything beyond that is a bug
for a, b in zip(jax.tree.leaves(s_plain["params"]), jax.tree.leaves(s_comp["params"])):
    d = float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
    assert d < 2e-3, d
# error feedback state is nonzero (quantization residual captured)
enorm = sum(float(jnp.sum(jnp.abs(e))) for e in jax.tree.leaves(s_comp["err"]))
assert enorm > 0
print("OK")
""")
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.train.pipeline import pipeline_apply, stack_stages, scan_stage

D = 16
L = 8
NS = 4  # stages
M = 6   # microbatches
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) * (1.0 / D**0.5)

def layer_fn(p, x):
    return jnp.tanh(x @ p)

def sequential(w, xs):
    def body(x, p):
        return layer_fn(p, x), None
    def one(x):
        y, _ = jax.lax.scan(body, x, w)
        return y
    return jax.vmap(one)(xs)

mesh = jax.make_mesh((4, 2), ("pipe", "data"))
staged = stack_stages(w, NS)
pipe_fn = pipeline_apply(scan_stage(layer_fn), NS, mesh, "pipe")

xs = jax.random.normal(jax.random.PRNGKey(1), (M, 4, D))
want = sequential(w, xs)
got = pipe_fn(staged, xs)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

# differentiability: grads through the pipeline match sequential grads
def loss_pipe(w):
    return jnp.sum(pipe_fn(stack_stages(w, NS), xs) ** 2)
def loss_seq(w):
    return jnp.sum(sequential(w, xs) ** 2)
g1 = jax.grad(loss_pipe)(w)
g2 = jax.grad(loss_seq)(w)
np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-5)
print("OK")
""")
    assert "OK" in out


def test_elastic_checkpoint_remesh(tmp_path):
    out = run_sub(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.train import checkpoint

# save from a (2,4) mesh layout, restore onto (4,2) - elastic re-mesh
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64.0).reshape(8, 8)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
checkpoint.save("{tmp_path}/ck", 1, {{"x": xa}})

mesh_b = jax.make_mesh((4, 2), ("data", "model"))
shard_b = {{"x": NamedSharding(mesh_b, P("model", "data"))}}
restored, man = checkpoint.restore("{tmp_path}/ck", {{"x": x}}, shardings=shard_b)
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
assert restored["x"].sharding.spec == P("model", "data")
print("OK")
""")
    assert "OK" in out
