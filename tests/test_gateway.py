"""Multi-tenant gateway tests.

The contracts under test:

  * ISOLATION: every tenant's greedy tokens through the shared-pool
    gateway are bit-identical to a dedicated single-tenant BatchServer
    over the same requests, and one tenant's prefix trie never matches
    (or leaks blocks into) another tenant's prompts;
  * HOT-SWAP: swapping an artifact with a matching uniform envelope
    mid-run keeps serving with ZERO recompiles (trace counter), a
    mismatched envelope takes the staged re-jit path, a KV-geometry
    mismatch is rejected;
  * OVERLOAD: a bounded queue / backlog sheds strictly lowest-priority
    first (counted, never silent) while the high-priority tenant's TTFT
    stays within its SLO;
  * the priority/deadline RequestQueue semantics and the artifact
    manifest validation the gateway boots through.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.gateway import (AdmissionController, Gateway, GatewayConfig,
                           SwapEvent, TenantRegistry, TenantRuntime,
                           TenantSLO)
from repro.models import registry
from repro.obs import MetricsRegistry, ScopedMetrics
from repro.sched.pricing import Pricer
from repro.serve import (BatchConfig, BatchServer, Request, RequestQueue,
                         ServeConfig)
from repro.serve import deployed as DP


@pytest.fixture(scope="module")
def model():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    init = registry.model_fns(cfg).init_params
    pA = init(cfg, jax.random.PRNGKey(0))
    pB = init(cfg, jax.random.PRNGKey(1))
    return cfg, DP.from_params(cfg, pA), DP.from_params(cfg, pB)


def _trace(cfg, tenant, n=4, seed=3, priority=0, max_prompt=12, max_new=7):
    rng = np.random.default_rng(seed)
    return [Request(f"{tenant}-r{i}",
                    rng.integers(0, cfg.vocab, int(rng.integers(3, max_prompt))),
                    int(rng.integers(2, max_new)), tenant=tenant,
                    priority=priority)
            for i in range(n)]


def _dedicated(cfg, sp, reqs, n_slots=3, block_size=4, n_blocks=48):
    srv = BatchServer(cfg, sp, ServeConfig(),
                      BatchConfig(n_slots=n_slots, block_size=block_size,
                                  n_blocks=n_blocks))
    return srv.run([Request(r.rid, r.prompt, r.max_new_tokens)
                    for r in reqs])


# ---------------------------------------------------------------------------
# isolation: per-tenant bit-parity + trie separation
# ---------------------------------------------------------------------------


def test_two_tenant_tokens_match_dedicated_servers(model):
    cfg, spA, spB = model
    reqsA = _trace(cfg, "acme", seed=3)
    reqsB = _trace(cfg, "bolt", seed=4)
    gw = Gateway([TenantRuntime("acme", cfg, spA),
                  TenantRuntime("bolt", cfg, spB)],
                 GatewayConfig(n_slots=3, block_size=4, n_blocks=48))
    rep = gw.run(reqsA + reqsB)
    for name, sp, reqs in (("acme", spA, reqsA), ("bolt", spB, reqsB)):
        want = _dedicated(cfg, sp, reqs)
        got = rep.per_tenant[name].outputs
        assert set(got) == {r.rid for r in reqs}
        for r in reqs:
            np.testing.assert_array_equal(
                got[r.rid], want.outputs[r.rid],
                err_msg=f"{r.rid}: gateway diverged from dedicated server")
    # report groups by tenant and labels each sub-report
    j = rep.to_json()
    assert set(j["tenants"]) == {"acme", "bolt"}
    assert j["tenants"]["acme"]["tenant"] == "acme"


def test_chunked_prefill_tokens_match_dedicated(model):
    """Disaggregated prefill (fixed chunk budget interleaved with decode)
    must not change a single token."""
    cfg, spA, _ = model
    rng = np.random.default_rng(11)
    reqs = [Request(f"c{i}", rng.integers(0, cfg.vocab,
                                          int(rng.integers(9, 22))),
                    int(rng.integers(2, 6)), tenant="acme")
            for i in range(5)]
    gw = Gateway([TenantRuntime("acme", cfg, spA)],
                 GatewayConfig(n_slots=2, block_size=4, n_blocks=64,
                               prefill_chunk=4))
    rep = gw.run(reqs)
    want = _dedicated(cfg, spA, reqs, n_slots=2, n_blocks=64)
    for r in reqs:
        np.testing.assert_array_equal(rep.per_tenant["acme"].outputs[r.rid],
                                      want.outputs[r.rid], err_msg=r.rid)


def test_prefix_trie_never_crosses_tenants(model):
    """Two tenants serve the IDENTICAL prompt set: with per-tenant tries
    each tenant's first admission must be a trie miss (a shared trie would
    hit on the other tenant's cached blocks - and serve tenant B's prompt
    through tenant A's KV)."""
    cfg, spA, spB = model
    rng = np.random.default_rng(7)
    shared_prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    reqs = []
    for tenant in ("acme", "bolt"):
        for i in range(2):  # second request per tenant may hit its OWN trie
            suffix = rng.integers(0, cfg.vocab, 3).astype(np.int32)
            reqs.append(Request(f"{tenant}-p{i}",
                                np.concatenate([shared_prompt, suffix]),
                                3, tenant=tenant))
    gw = Gateway([TenantRuntime("acme", cfg, spA),
                  TenantRuntime("bolt", cfg, spB)],
                 GatewayConfig(n_slots=1, block_size=4, n_blocks=64))
    rep = gw.run(reqs)
    for name, sp in (("acme", spA), ("bolt", spB)):
        mine = [r for r in reqs if r.tenant == name]
        want = _dedicated(cfg, sp, mine, n_slots=1, n_blocks=64)
        for r in mine:
            np.testing.assert_array_equal(
                rep.per_tenant[name].outputs[r.rid], want.outputs[r.rid],
                err_msg=f"{r.rid}: cross-tenant prefix contamination")
        pfx = rep.per_tenant[name].prefix
        # each tenant hits only its OWN earlier insertion, never the other
        # tenant's identical prompt
        assert pfx["lookups"] == 2
        assert pfx["hits"] <= 1


def test_unknown_tenant_rejected(model):
    cfg, spA, _ = model
    gw = Gateway([TenantRuntime("acme", cfg, spA)])
    with pytest.raises(ValueError, match="unknown tenant"):
        gw.run([Request("x", np.arange(4), 2, tenant="ghost")])


def test_gateway_is_greedy_only(model):
    cfg, spA, _ = model
    with pytest.raises(ValueError, match="greedy"):
        Gateway([TenantRuntime("acme", cfg, spA)],
                scfg=ServeConfig(temperature=0.7))


# ---------------------------------------------------------------------------
# hot-swap
# ---------------------------------------------------------------------------


def test_hot_swap_inplace_zero_recompiles(model):
    """Mid-run swap to a same-envelope packing: serving continues, the
    post-swap tokens come from the NEW weights, and the tenant's trace
    counter records ZERO recompiles after the swap."""
    cfg, spA, spB = model
    reqs = _trace(cfg, "acme", n=6, seed=9, max_new=8)
    t = TenantRuntime("acme", cfg, spA)
    gw = Gateway([t], GatewayConfig(n_slots=2, block_size=4, n_blocks=48))
    rep = gw.run(reqs, swaps=[SwapEvent(at_step=3, tenant="acme", sp=spB)])
    assert len(rep.swaps) == 1
    assert rep.swaps[0]["mode"] == "inplace"
    assert rep.swaps[0]["recompiles_after_swap"] == 0
    assert t.sp is spB  # the swap actually landed
    # serving kept going: every request still completed
    assert set(rep.per_tenant["acme"].outputs) == {r.rid for r in reqs}


def test_hot_swap_mismatched_envelope_is_staged(model):
    """A packing with a different stacked envelope (compressed BSR vs
    dense) re-jits on the staged path and says so."""
    cfg, spA, _ = model
    qcfg = registry.get_smoke_config("yi-6b", dtype="float32",
                                     cim_mode="qat")
    params = registry.model_fns(qcfg).init_params(qcfg, jax.random.PRNGKey(2))
    spc = DP.compress(qcfg, params, target_sparsity=0.0, tile=(16, 16),
                      uniform=True)
    t = TenantRuntime("acme", qcfg, DP.from_params(qcfg, params))
    rep = t.hot_swap(spc)
    assert rep["mode"] == "staged"
    assert rep["tile"] == [16, 16]


def test_hot_swap_kv_geometry_mismatch_rejected(model):
    cfg, spA, _ = model
    other = dataclasses.replace(cfg, n_layers=cfg.n_layers + 1)
    t = TenantRuntime("acme", cfg, spA)
    with pytest.raises(ValueError, match="KV geometry"):
        t.hot_swap(spA, cfg_new=other)


def test_registry_rejects_mixed_kv_geometry(model):
    cfg, spA, _ = model
    other = dataclasses.replace(cfg, n_layers=cfg.n_layers + 1)
    init = registry.model_fns(other).init_params
    spO = DP.from_params(other, init(other, jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="geometries"):
        TenantRegistry([TenantRuntime("a", cfg, spA),
                        TenantRuntime("b", other, spO)])


# ---------------------------------------------------------------------------
# overload: priority sheds + SLO protection
# ---------------------------------------------------------------------------


def test_overflow_sheds_strictly_lowest_priority_first(model):
    """Queue bounded below the offered load: every shed victim has the
    lowest priority present, the high-priority tenant is fully served, and
    its TTFT p50 stays within its (generous) SLO."""
    cfg, spA, spB = model
    hi = _trace(cfg, "hi", n=5, seed=1, priority=2, max_new=5)
    lo = _trace(cfg, "lo", n=5, seed=2, priority=0, max_new=5)
    gw = Gateway([TenantRuntime("hi", cfg, spA, priority=2,
                                slo=TenantSLO(ttft_ms=120000)),
                  TenantRuntime("lo", cfg, spB, priority=0)],
                 GatewayConfig(n_slots=2, block_size=4, n_blocks=48,
                               max_pending=6))
    rep = gw.run(hi + lo)
    assert rep.shed, "bounded queue under 10 requests must shed"
    assert all(ev["priority"] == 0 for ev in rep.shed), rep.shed
    assert all(ev["reason"] == "queue_overflow" for ev in rep.shed)
    assert set(rep.per_tenant["hi"].outputs) == {r.rid for r in hi}
    meta = rep.tenant_meta["hi"]
    assert meta["slo_attainment"]["ttft_p50_ms"] <= 120000
    assert meta["slo_attainment"]["ttft"] == 1.0
    # sheds are counted, never silent
    assert rep.admission["n_shed"] == len(rep.shed)


def test_deadline_shed_and_backlog_shed(model):
    """An unmeetable deadline sheds immediately; a zero backlog budget
    sheds by the overload rule - both with reasons, both priced first."""
    cfg, spA, _ = model
    t = TenantRuntime("acme", cfg, spA)
    ctrl = AdmissionController(pricer=Pricer())
    dead = Request("late", np.arange(6), 4, tenant="acme",
                   deadline=1e-12)
    price = ctrl.price(t, dead)
    assert price.total_s > 0
    assert ctrl.decide(t, dead, now=1.0, price=price) == ("shed", "deadline")
    tight = AdmissionController(pricer=Pricer(), max_backlog_s=0.0)
    ok = Request("r", np.arange(6), 4, tenant="acme")
    p2 = tight.price(t, ok)
    assert tight.decide(t, ok, now=0.0, price=p2) == ("shed", "overload")


def test_quota_defers_then_serves(model):
    """A tiny token-rate quota DEFERS (never sheds) the over-quota tail;
    everything still completes once the window refills."""
    cfg, spA, _ = model
    reqs = _trace(cfg, "acme", n=3, seed=5, max_new=4)
    gw = Gateway([TenantRuntime("acme", cfg, spA,
                                slo=TenantSLO(token_rate=30.0))],
                 GatewayConfig(n_slots=2, block_size=4, n_blocks=48))
    rep = gw.run(reqs)
    assert set(rep.per_tenant["acme"].outputs) == {r.rid for r in reqs}
    assert rep.admission["n_shed"] == 0


# ---------------------------------------------------------------------------
# RequestQueue priority/deadline semantics
# ---------------------------------------------------------------------------


def test_queue_pops_priority_then_fifo():
    reqs = [Request("a", np.arange(3), 1, priority=0),
            Request("b", np.arange(3), 1, priority=2),
            Request("c", np.arange(3), 1, priority=2),
            Request("d", np.arange(3), 1, priority=1)]
    q = RequestQueue(reqs)
    assert [q.pop_ready(0.0).rid for _ in range(4)] == ["b", "c", "d", "a"]


def test_queue_requeue_goes_to_front_of_class():
    reqs = [Request("a", np.arange(3), 1, priority=1),
            Request("b", np.arange(3), 1, priority=1)]
    q = RequestQueue(reqs)
    first = q.pop_ready(0.0)
    q.requeue(first)
    assert q.pop_ready(0.0).rid == "a"  # deferred head stays the head


def test_queue_overflow_evicts_lowest_priority_newest():
    q = RequestQueue(max_pending=2)
    assert q.push(Request("a", np.arange(3), 1, priority=1)) is None
    assert q.push(Request("b", np.arange(3), 1, priority=0)) is None
    shed = q.push(Request("c", np.arange(3), 1, priority=2))
    assert shed is not None and shed.rid == "b"  # lowest priority loses
    assert q.n_shed == 1
    # an incoming request BELOW everything queued sheds itself
    shed2 = q.push(Request("d", np.arange(3), 1, priority=-1))
    assert shed2 is not None and shed2.rid == "d"
    assert len(q) == 2


def test_request_deadline_validation():
    with pytest.raises(ValueError, match="deadline"):
        Request("r", np.arange(3), 1, arrival=5.0, deadline=1.0)


# ---------------------------------------------------------------------------
# artifact validation + scoped metrics satellites
# ---------------------------------------------------------------------------


def test_load_artifact_rejects_wrong_arch(tmp_path, model):
    cfg, spA, _ = model
    root = str(tmp_path / "art")
    DP.save_artifact(root, spA, cfg)
    with pytest.raises(ValueError, match="expected.*found|arch"):
        DP.load_artifact_tiers(root, arch="llama-7b")


def test_load_artifact_rejects_wrong_tile(tmp_path):
    qcfg = registry.get_smoke_config("yi-6b", dtype="float32",
                                     cim_mode="qat")
    params = registry.model_fns(qcfg).init_params(qcfg, jax.random.PRNGKey(0))
    spc = DP.compress(qcfg, params, target_sparsity=0.0, tile=(16, 16),
                      uniform=True)
    root = str(tmp_path / "art")
    DP.save_artifact(root, spc, qcfg)
    meta = DP.load_artifact_extra(root)
    assert meta["schema"] == DP.ARTIFACT_SCHEMA
    assert meta["tiles"] == [[16, 16]]
    with pytest.raises(ValueError, match=r"8.*8|tile"):
        DP.load_artifact_tiers(root, tile=(8, 8))
    # matching expectations load fine
    sp2, _, _ = DP.load_artifact_tiers(root, arch=qcfg.name, tile=(16, 16))
    assert sp2 is not None


def test_validate_artifact_refuses_newer_schema(tmp_path, model):
    cfg, spA, _ = model
    root = str(tmp_path / "art")
    DP.save_artifact(root, spA, cfg)
    mpath = tmp_path / "art" / "step_00000000" / "manifest.json"
    meta = json.loads(mpath.read_text())
    meta["extra"]["schema"] = DP.ARTIFACT_SCHEMA + 1
    mpath.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="schema"):
        DP.load_artifact_tiers(root)


def test_scoped_metrics_inject_tenant_label():
    reg = MetricsRegistry()
    sm = ScopedMetrics(reg, tenant="acme")
    sm.counter("requests_finished").inc()
    sm.counter("gateway_shed_total", reason="deadline").inc(2)
    snap = reg.snapshot()
    assert snap["counters"]["requests_finished{tenant=acme}"] == 1
    assert snap["counters"][
        "gateway_shed_total{reason=deadline,tenant=acme}"] == 2


def test_gateway_reports_tenant_labeled_metrics(model):
    cfg, spA, _ = model
    reqs = _trace(cfg, "acme", n=2, seed=13, max_new=3)
    reg = MetricsRegistry()
    gw = Gateway([TenantRuntime("acme", cfg, spA)],
                 GatewayConfig(n_slots=2, block_size=4, n_blocks=48),
                 metrics=reg)
    gw.run(reqs)
    snap = reg.snapshot()
    assert snap["counters"]["requests_finished{tenant=acme}"] == 2
    assert snap["counters"]["decode_steps{tenant=acme}"] >= 1
