"""repro.sched: graph extraction, allocation conservation, event-driven
simulation vs the closed-form model, mapping search, schedule execution."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import sched
from repro.core import perf_model as PM
from repro.core.cim_layer import CIMConfig
from repro.core.mapping import pack_groupsets
from repro.core.perf_model import ConvLayer
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# graph extraction
# ---------------------------------------------------------------------------


def test_vgg16_graph_matches_perf_table():
    g = sched.vgg16_graph()
    layers = PM.vgg16_cifar_layers()
    assert len(g.nodes) == len(layers)
    assert [l.macs for l in g.layers()] == [l.macs for l in layers]
    order = g.topo_order()
    # chain: each node depends on its predecessor
    for prev, cur in zip(order, order[1:]):
        assert g.nodes[cur].deps == (prev,)


def test_resnet18_graph_is_a_dag_with_skips():
    g = sched.resnet18_graph()
    order = g.topo_order()
    assert len(order) == len(g.nodes)
    # 17 chain convs + 3 downsample 1x1 convs
    assert len(g.nodes) == 20
    downs = [n for n in g.nodes.values() if n.layer.kh == 1]
    assert len(downs) == 3
    # a post-downsample conv1 must depend on BOTH producers of the stream
    joins = [n for n in g.nodes.values() if len(n.deps) == 2]
    assert len(joins) >= 3
    pos = {n: i for i, n in enumerate(order)}
    for n in g.nodes.values():
        for d in n.deps:
            assert pos[d] < pos[n.name]


def test_lm_graph_projections():
    from repro.models import registry

    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    g = sched.lm_graph(cfg, seq_len=64)
    assert len(g.nodes) == 7 * cfg.n_layers
    node = g.nodes["blk0_w_up"]
    assert node.kind == "matmul"
    assert node.layer.cin == cfg.d_model and node.layer.cout == cfg.d_ff
    assert node.layer.out_pixels == 64
    res = sched.simulate(g)
    assert res.cycles > 0 and np.isfinite(res.fps)


def test_graph_rejects_unknown_dep_and_cycle():
    l = ConvLayer(3, 3, 16, 16, 4, 4)
    with pytest.raises(ValueError):
        sched.LayerGraph({"a": sched.LayerNode("a", l, deps=("ghost",))})
    cyc = sched.LayerGraph({
        "a": sched.LayerNode("a", l, deps=("b",)),
        "b": sched.LayerNode("b", l, deps=("a",)),
    })
    with pytest.raises(ValueError):
        cyc.topo_order()


# ---------------------------------------------------------------------------
# allocator conservation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.95])
@pytest.mark.parametrize("group,alpha", [(16, 16), (8, 32), (32, 8)])
def test_allocator_conservation(sparsity, group, alpha):
    node = sched.LayerNode("l", ConvLayer(3, 3, 128, 256, 8, 8, sparsity))
    alloc = sched.allocate_node(node, group=group, alpha=alpha)
    assert sched.verify_conservation(alloc)
    assert alloc.placed == alloc.nnz_total
    assert alloc.nnz_total == node.layer.nnz_for(group, alpha)


def test_allocator_balances_cores():
    node = sched.LayerNode("l", ConvLayer(3, 3, 256, 512, 4, 4, 0.0))
    alloc = sched.allocate_node(node)
    loads = [a.nnz for a in alloc.assignments]
    assert max(loads) - min(loads) <= max(1, max(loads) // 4)
    assert alloc.imbalance < 1.34  # LPT bound


def test_allocator_exact_counts_from_weight():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(9 * 32, 64)).astype(np.float32)
    # zero half the 16x16 tiles exactly
    w[: 9 * 16, :] = 0.0
    node = sched.LayerNode("l", ConvLayer(3, 3, 32, 64, 4, 4), weight=w)
    counts = node.kernel_group_counts(16, 16)
    assert counts.sum() == 9 * 4  # surviving (gi=18/2) x go=4
    alloc = sched.allocate_node(node)
    assert alloc.nnz_total == counts.sum()
    assert sched.verify_conservation(alloc)


def test_allocate_from_packing_agrees_with_node_counts():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(64, 64)).astype(np.float32)
    w[16:48, :] = 0.0
    p = pack_groupsets(w)
    alloc = sched.allocate_packing(p, name="packed")
    assert alloc.nnz_total == p.nnz
    assert sched.verify_conservation(alloc)


def test_allocator_residency_waves():
    # dense 512->512 3x3: 9216 group-sets, 2304/core, 32/macro -> 72 waves
    node = sched.LayerNode("l", ConvLayer(3, 3, 512, 512, 2, 2, 0.0))
    alloc = sched.allocate_node(node, dense=True)
    assert alloc.capacity_per_macro == 32
    assert alloc.reload_waves == 72
    for a in alloc.assignments:
        assert sum(a.waves) == a.nnz


# ---------------------------------------------------------------------------
# simulator vs the closed-form model
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("a_bits", [4, 8])
def test_sim_within_tolerance_of_analytic_dense_vgg16(a_bits):
    cv = sched.cross_validate(PM.vgg16_cifar_layers(), w_bits=8,
                              a_bits=a_bits, dense=True)
    assert 0.75 <= cv["ratio"] <= 1.25, cv


def test_sim_within_tolerance_of_analytic_dense_resnet18():
    cv = sched.cross_validate(PM.resnet18_cifar_layers(), dense=True)
    assert 0.75 <= cv["ratio"] <= 1.25, cv


def test_sim_single_dense_layer_close_to_analytic():
    # one compute-bound layer, no pipelining: the only divergence is the
    # double-buffered reload, which this layer barely has
    l = ConvLayer(3, 3, 64, 64, 32, 32, 0.0)
    cv = sched.cross_validate([l], dense=True)
    assert 0.9 <= cv["ratio"] <= 1.1, cv


def test_sparse_sim_tracks_analytic_mars_path():
    layers = PM.vgg16_cifar_layers()
    res = sched.simulate(sched.vgg16_graph(), pipeline=False)
    fps_analytic = PM.summarize(layers).fps
    assert 0.75 * fps_analytic <= res.fps <= 1.25 * fps_analytic


def test_pipeline_never_slower():
    g = sched.vgg16_graph()
    nopipe = sched.simulate(g, pipeline=False)
    pipe = sched.simulate(g, pipeline=True)
    assert pipe.cycles <= nopipe.cycles + 1e-6


def test_sim_events_are_consistent():
    res = sched.simulate(sched.vgg16_graph())
    assert res.events, "event log empty"
    for e in res.events:
        assert e.t_end >= e.t_start >= 0.0
    # per-core compute intervals never overlap
    for c in range(res.hw.cores):
        iv = sorted((e.t_start, e.t_end) for e in res.events
                    if e.kind == "compute" and e.core == c)
        for (s1, e1), (s2, e2) in zip(iv, iv[1:]):
            assert s2 >= e1 - 1e-9
    assert 0.0 < res.core_utilization <= 1.0


def test_sim_zero_wave_layer_no_double_release():
    # regression: an all-zero root retires inside release(); its successor
    # must not get its waves queued twice under pipeline=False
    z = sched.LayerNode("z", ConvLayer(3, 3, 16, 16, 4, 4),
                        weight=np.zeros((9 * 16, 16), np.float32))
    n = sched.LayerNode("n", ConvLayer(3, 3, 16, 16, 4, 4, 0.5), deps=("z",))
    g = sched.LayerGraph({"z": z, "n": n})
    res = sched.simulate(g, pipeline=False)
    assert sum(1 for e in res.events if e.kind == "compute") == 1
    assert res.cycles == pytest.approx(sched.simulate(g, pipeline=True).cycles)


def test_sim_metrics_independent_of_event_log():
    g = sched.vgg16_graph()
    full = sched.simulate(g, keep_events=True)
    lean = sched.simulate(g, keep_events=False)
    assert lean.events == []
    assert lean.core_utilization == pytest.approx(full.core_utilization)
    for a, b in zip(full.layers, lean.layers):
        assert a.compute_cycles == pytest.approx(b.compute_cycles)
        assert a.reload_cycles == pytest.approx(b.reload_cycles)


def test_analytic_model_consistent_on_nondefault_tiling():
    # regression: summarize(hw=8x8) must count group-sets at the hw tiling;
    # the event simulator at the same tiling should land in the same range
    hw = PM.HardwareConfig(group=8, alpha=8)
    analytic = PM.summarize(PM.vgg16_cifar_layers(), hw=hw)
    sim = sched.simulate(sched.vgg16_graph(), group=8, alpha=8)
    assert 0.75 * analytic.fps <= sim.fps <= 1.25 * analytic.fps


def test_sim_respects_dag_dependencies():
    res = sched.simulate(sched.resnet18_graph())
    g = sched.resnet18_graph()
    end = {t.name: t.t_end for t in res.layers}
    start = {t.name: t.t_compute for t in res.layers}
    for n in g.nodes.values():
        for d in n.deps:
            assert start[n.name] >= end[d] - 1e-9, (n.name, d)


# ---------------------------------------------------------------------------
# serving device assignment (macro cluster -> mesh devices)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_devices", [1, 2, 4])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_device_assignment_balanced_and_conserving(n_devices, seed):
    rng = np.random.default_rng(seed)
    go = n_devices * int(rng.integers(1, 6))
    counts = rng.integers(0, 40, go)
    dev = sched.device_assignment(counts, n_devices)
    assert dev.shape == (go,)
    # equal cardinality: shard_map shards must be equal-shaped
    sizes = np.bincount(dev, minlength=n_devices)
    assert np.all(sizes == go // n_devices)
    # LPT-style balance: max load within one column of the mean (the
    # greedy places each column on the least-loaded open device)
    loads = np.bincount(dev, weights=counts, minlength=n_devices)
    assert loads.sum() == counts.sum()
    assert loads.max() <= counts.sum() / n_devices + counts.max()


def test_device_assignment_rejects_ragged():
    with pytest.raises(ValueError, match="evenly"):
        sched.device_assignment([1, 2, 3], 2)
    with pytest.raises(ValueError, match="n_devices"):
        sched.device_assignment([1, 2], 0)


def test_device_assignment_matches_allocator_policy():
    """Same LPT greedy as allocate_counts when cardinality never binds:
    with go == n_devices every device gets exactly one column."""
    counts = [7, 3, 9, 1]
    dev = sched.device_assignment(counts, 4)
    assert sorted(dev.tolist()) == [0, 1, 2, 3]
    # heaviest column placed first, on the (then) least-loaded device
    assert dev[2] == 0


# ---------------------------------------------------------------------------
# randomized cross-validation: sim vs closed-form over generated networks
# ---------------------------------------------------------------------------
#
# The 25% contract is defined for realistic workloads. Two analytic
# predicates pin that envelope WITHOUT peeking at the simulator:
#   * every layer has >= 2*cores kernel-group columns (else the LPT split
#     idles cores the closed-form model assumes busy);
#   * the serially-charged reload+ctrl share of analytic cycles is <= 15%
#     (the double-buffered reload hiding is the documented, designed
#     disagreement between the two models).


def _overhead_share(layers, hw, w_bits, a_bits, dense):
    """Fraction of analytic cycles charged serially (reload + APW/ctrl)."""
    tot = exp = 0.0
    pass_f = hw.pass_factor(w_bits, a_bits)
    for l in layers:
        total_gs = l.groupsets_for(hw.group, hw.alpha)
        nnz = total_gs if dense else l.nnz_for(hw.group, hw.alpha)
        compute = l.out_pixels * nnz * pass_f / hw.cores
        fm = (l.out_pixels * nnz
              + l.out_pixels * -(-l.cout // hw.alpha)) / hw.cores
        reload = (nnz * hw.group * hw.alpha * w_bits
                  / (hw.reload_bits_per_cycle * hw.cores))
        over = reload + hw.ctrl_overhead * l.out_pixels
        tot += max(compute, fm) + over
        exp += over
    return exp / max(tot, 1e-9)


def _rand_layer(rng):
    k = int(rng.choice([1, 3]))
    return ConvLayer(k, k, int(rng.choice([32, 64, 128, 256])),
                     int(rng.choice([128, 256, 512])),
                     int(rng.choice([4, 8, 16, 32])),
                     int(rng.choice([4, 8, 16, 32])),
                     float(rng.uniform(0.0, 0.75)))


def _rand_network(rng, hw, a_bits, dense, n_min=2, n_max=8, tries=50):
    for _ in range(tries):
        ls = [_rand_layer(rng) for _ in range(int(rng.integers(n_min, n_max + 1)))]
        if any(-(-l.cout // hw.alpha) < 2 * hw.cores for l in ls):
            continue
        if _overhead_share(ls, hw, 8, a_bits, dense) > 0.15:
            continue
        return ls
    pytest.skip("generator could not hit the envelope")


@pytest.mark.parametrize("seed", range(8))
def test_randomized_chain_dense_within_tolerance(seed):
    rng = np.random.default_rng(seed)
    hw = PM.DEFAULT_HW
    a_bits = int(rng.choice([4, 8]))
    ls = _rand_network(rng, hw, a_bits, dense=True)
    cv = sched.cross_validate(ls, w_bits=8, a_bits=a_bits, dense=True)
    assert 0.75 <= cv["ratio"] <= 1.25, cv


@pytest.mark.parametrize("seed", range(8, 16))
def test_randomized_chain_sparse_within_tolerance(seed):
    rng = np.random.default_rng(seed)
    hw = PM.DEFAULT_HW
    a_bits = int(rng.choice([4, 8]))
    ls = _rand_network(rng, hw, a_bits, dense=False)
    fps_a = PM.summarize(ls, w_bits=8, a_bits=a_bits).fps
    res = sched.simulate(sched.graph_from_layers(ls), w_bits=8, a_bits=a_bits,
                         pipeline=False)
    assert 0.75 * fps_a <= res.fps <= 1.25 * fps_a


@pytest.mark.parametrize("seed", range(16, 22))
def test_randomized_diamond_dag_within_tolerance(seed):
    """Branch-and-join DAGs (resnet-style), not just chains."""
    rng = np.random.default_rng(seed)
    hw = PM.DEFAULT_HW
    a_bits = int(rng.choice([4, 8]))
    ls = _rand_network(rng, hw, a_bits, dense=True, n_min=4)
    nodes = {"l0": sched.LayerNode("l0", ls[0])}
    prev = "l0"
    for i, l in enumerate(ls[1:-2], 1):
        nodes[f"l{i}"] = sched.LayerNode(f"l{i}", l, deps=(prev,))
        prev = f"l{i}"
    nodes["skip"] = sched.LayerNode("skip", ls[-2], deps=("l0",))
    nodes["join"] = sched.LayerNode("join", ls[-1], deps=(prev, "skip"))
    g = sched.LayerGraph(nodes)
    ana = sum(p.cycles_dense for p in PM.evaluate_network(
        [n.layer for n in g.nodes.values()], 8, a_bits))
    res = sched.simulate(g, w_bits=8, a_bits=a_bits, dense=True,
                         pipeline=False)
    assert 0.75 <= res.cycles / ana <= 1.25


# ---------------------------------------------------------------------------
# mapping search
# ---------------------------------------------------------------------------


def test_search_at_least_default():
    g = sched.vgg16_graph()
    r = sched.search_mapping(g, groups=(8, 16, 32), alphas=(8, 16, 32))
    assert r.best.fps >= r.default.fps
    assert r.default.candidate.tile == (16, 16)
    assert len(r.table) == 9


def test_greedy_search_at_least_default():
    g = sched.resnet18_graph()
    r = sched.greedy_search(g, steps=(8, 16, 32))
    assert r.best.fps >= r.default.fps
    assert len(r.table) <= 7  # O(2k), not O(k^2)


# ---------------------------------------------------------------------------
# schedule build + execution on the real kernel path
# ---------------------------------------------------------------------------


def _cim(ts=0.5):
    return CIMConfig(
        quant=QuantConfig(w_bits=8, a_bits=8, group_size=16, a_signed=True),
        sparsity=SparsityConfig(alpha=16, n=16, target_sparsity=ts),
        mode="qat")


def test_build_schedule_artifact():
    g = sched.vgg16_graph()
    r = sched.search_mapping(g)
    ns = sched.schedule_from_search(g, r)
    assert len(ns.layers) == len(g.nodes)
    j = ns.to_json()
    assert j["fps"] == pytest.approx(ns.fps, rel=1e-2)
    for s, name in zip(ns.layers, g.topo_order()):
        assert s.name == name
        assert s.nnz <= s.total_groupsets
        assert sum(s.core_loads) == s.nnz
        assert s.t_end >= s.t_start


def test_scheduled_execution_roundtrip_unchanged_numerics():
    """Acceptance: chosen schedule round-trips deploy_weight ->
    deployed_matmul with unchanged numerics vs the dense oracle."""
    g = sched.vgg16_graph()
    ns = sched.schedule_from_search(g, sched.search_mapping(g))
    cim = _cim(ts=0.5)
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (128, 64))) * 0.2
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (8, 128)))
    layer = dataclasses.replace(ns.layers[0], name="proj")
    err = sched.verify_layer(x, w, layer, cim, target_sparsity=0.5)
    assert err == 0.0


def test_execute_layer_ragged_tile_falls_back_to_divisor():
    # d_in=96 is not divisible by a 32-wide tile; executor must pick a
    # valid (bk, bn) rather than crash in pack_bsr
    ls = sched.LayerSchedule("rag", group=32, alpha=32, nnz=1,
                             total_groupsets=1, reload_waves=1,
                             imbalance=1.0, core_loads=[1, 0, 0, 0])
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (96, 48))) * 0.2
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (4, 96)))
    err = sched.verify_layer(x, w, ls, _cim(0.25), target_sparsity=0.25)
    assert err == 0.0


def test_end_to_end_vgg16_acceptance():
    """The ISSUE acceptance bundle in one test: simulate VGG16-CIFAR
    end-to-end, dense sim within 25% of analytic, search >= default."""
    cv = sched.cross_validate(PM.vgg16_cifar_layers(), dense=True)
    assert abs(cv["ratio"] - 1.0) <= 0.25
    g = sched.vgg16_graph()
    r = sched.search_mapping(g)
    assert r.best.fps >= r.default.fps
    ns = sched.schedule_from_search(g, r)
    assert ns.fps == pytest.approx(r.best.fps, rel=1e-6)
