"""Continuous batching + compressed serving tests.

The contracts under test:

  * batching must never change tokens: every request served through the
    slot/paged-KV machinery (continuous OR static admission) reproduces the
    single-request Engine stream exactly;
  * the paged KV pool really recycles blocks across admissions and bounds
    peak usage below the padded worst case;
  * compressed serving is numerically honest: at target_sparsity=0 the
    deployed (BSR-kernel) engine reproduces the dense-math QAT engine's
    greedy tokens exactly, and at paper-style sparsity every packed
    projection matches ``deploy.reference_matmul``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import deploy as D
from repro.models import registry
from repro.models import layers as L
from repro.serve import (BatchConfig, BatchServer, Engine, PagedKVCache,
                         Request, RequestQueue, ServeConfig)
from repro.serve import deployed as DP


@pytest.fixture(scope="module")
def dense_model():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _trace(cfg, n=7, seed=5, max_prompt=14, max_new=9):
    rng = np.random.default_rng(seed)
    return [Request(f"r{i}", rng.integers(0, cfg.vocab, int(rng.integers(2, max_prompt))),
                    int(rng.integers(1, max_new))) for i in range(n)]


def _engine_reference(cfg, params, reqs):
    out = {}
    for r in reqs:
        eng = Engine(cfg, params, ServeConfig(max_new_tokens=r.max_new_tokens))
        out[r.rid] = eng.generate({"tokens": jnp.asarray(r.prompt[None])})[0]
    return out


@pytest.mark.parametrize("continuous", [True, False])
def test_batching_matches_single_request_engine(dense_model, continuous):
    cfg, params = dense_model
    reqs = _trace(cfg)
    want = _engine_reference(cfg, params, reqs)
    srv = BatchServer(cfg, DP.from_params(cfg, params), ServeConfig(),
                      BatchConfig(n_slots=3, block_size=4, n_blocks=32),
                      continuous=continuous)
    rep = srv.run(_trace(cfg))
    assert set(rep.outputs) == {r.rid for r in reqs}
    for r in reqs:
        np.testing.assert_array_equal(
            rep.outputs[r.rid], want[r.rid],
            err_msg=f"{r.rid}: batched decode diverged from Engine")
    assert rep.total_tokens == sum(len(o) for o in want.values())
    assert len(rep.ttft_s) == len(reqs)


def test_slot_admission_and_paged_reuse(dense_model):
    """More requests than slots and a pool far smaller than padded worst
    case: freed slots must admit the queue tail and freed blocks must be
    physically reused."""
    cfg, params = dense_model
    reqs = _trace(cfg, n=9, seed=11)
    bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=12)
    srv = BatchServer(cfg, DP.from_params(cfg, params), ServeConfig(), bcfg)
    rep = srv.run(reqs)
    assert len(rep.outputs) == 9  # every queued request completed
    st = rep.kv_stats
    assert st["reused_blocks"] > 0, "free list never recycled a block"
    assert st["peak_blocks"] <= bcfg.n_blocks - 1
    # paged: peak is bounded by live sequences, not n_requests * max_len
    assert st["peak_blocks"] < st["allocations"]
    # and correctness held while recycling:
    want = _engine_reference(cfg, params, reqs)
    for r in reqs:
        np.testing.assert_array_equal(rep.outputs[r.rid], want[r.rid])


def test_oversized_request_rejected(dense_model):
    cfg, params = dense_model
    srv = BatchServer(cfg, DP.from_params(cfg, params), ServeConfig(),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=4))
    huge = Request("big", np.zeros(30, np.int32), 10)
    with pytest.raises(ValueError, match="blocks"):
        srv.run([huge])


def test_arrival_times_honored(dense_model):
    cfg, params = dense_model
    reqs = [Request("early", np.arange(4), 2, arrival=0.0),
            Request("late", np.arange(6), 2, arrival=0.05)]
    srv = BatchServer(cfg, DP.from_params(cfg, params), ServeConfig(),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=16))
    rep = srv.run(reqs)
    assert set(rep.outputs) == {"early", "late"}
    # TTFT is measured from arrival, so the late request's wait is excluded
    assert all(t >= 0 for t in rep.ttft_s)


def test_request_queue_requeue_keeps_fifo():
    a = Request("a", np.arange(3), 1)
    b = Request("b", np.arange(3), 1)
    q = RequestQueue([a, b])
    popped = q.pop_ready(now=0.0)
    assert popped.rid == "a"
    q.requeue(popped)  # backpressure: "a" must stay ahead of "b"
    assert q.pop_ready(now=0.0).rid == "a"
    assert q.pop_ready(now=0.0).rid == "b"


def test_request_queue_ordering():
    q = RequestQueue([Request("b", np.arange(3), 1, arrival=0.2),
                      Request("a", np.arange(3), 1, arrival=0.0)])
    assert q.pop_ready(now=0.0).rid == "a"
    assert q.pop_ready(now=0.0) is None  # "b" not arrived yet
    assert q.next_arrival() == 0.2
    assert q.pop_ready(now=0.3).rid == "b"
    assert len(q) == 0


def test_paged_kv_gather_roundtrip(dense_model):
    """Writing per-token K/V through block tables and gathering the view
    back must reproduce a contiguous cache."""
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=8, block_size=4)
    L_, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    rng = np.random.default_rng(0)
    ref = np.zeros((L_, 2, 8, KV, dh), np.float32)
    for pos in range(8):
        for slot in range(2):
            kv.ensure(slot, pos + 1)
        k = rng.standard_normal((L_, 2, KV, dh)).astype(np.float32)
        v = rng.standard_normal((L_, 2, KV, dh)).astype(np.float32)
        ref[:, :, pos] = k
        pb, off = kv.write_coords([pos, pos])
        kv.write_token(pb, off, jnp.asarray(k), jnp.asarray(v))
    got_k, _ = kv.gather(n_view=2)
    np.testing.assert_allclose(np.asarray(got_k), ref, rtol=0, atol=0)
    # freeing returns blocks and the next allocation reuses them
    held = list(kv.tables[0])
    kv.free_slot(0)
    kv.ensure(0, 1)
    assert kv.tables[0][0] == held[0]


def test_decode_attention_multi_matches_per_row(dense_model):
    """Per-row-position attention over a gathered view == scalar-pos
    decode_attention run row by row on a contiguous cache."""
    cfg, params = dense_model
    p = jax.tree.map(lambda a: a[0], params["layers"])
    rng = np.random.default_rng(2)
    B, Sv, KV, dh = 3, 8, cfg.n_kv_heads_eff, cfg.dh
    x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, Sv, KV, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, Sv, KV, dh)), jnp.float32)
    pos = jnp.asarray([2, 5, 0], jnp.int32)
    y, kn, vn = L.decode_attention_multi(p, x, kc, vc, pos, cfg)
    assert kn.shape == (B, 1, KV, dh)  # (B, T, KV, dh) with T=1
    for b in range(B):
        yb, kb, vb = L.decode_attention(p, x[b:b + 1], kc[b:b + 1],
                                        vc[b:b + 1], pos[b], cfg)
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(yb[0]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(kn[b, 0]),
                                   np.asarray(kb[0, pos[b]]), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Report guards: empty traces and zero-duration runs must not divide by zero
# ---------------------------------------------------------------------------


def test_run_with_empty_trace_reports_zeros(dense_model):
    cfg, params = dense_model
    srv = BatchServer(cfg, DP.from_params(cfg, params), ServeConfig(),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=8))
    rep = srv.run([])
    assert rep.n_requests == 0 and rep.total_tokens == 0
    assert rep.tokens_per_s == 0.0
    assert rep.slot_efficiency == 1.0
    j = rep.to_json()  # must serialize without NaN/inf
    assert j["ttft"] == {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    assert j["tpot"] == {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    assert np.isfinite(j["tokens_per_s"])


def test_report_zero_duration_run():
    from repro.serve.server import ServeReport

    rep = ServeReport(n_requests=0, total_tokens=0, wall_s=0.0,
                      n_decode_steps=0, ttft_s=[], tpot_s=[],
                      outputs={}, kv_stats={})
    assert rep.tokens_per_s == 0.0
    assert rep.slot_efficiency == 1.0
    # tokens but zero wall clock (a mocked/degenerate timer) stays finite
    rep2 = ServeReport(n_requests=1, total_tokens=3, wall_s=0.0,
                       n_decode_steps=2, ttft_s=[0.1], tpot_s=[0.01],
                       outputs={}, kv_stats={})
    assert rep2.tokens_per_s == 0.0
    assert np.isfinite(rep2.slot_efficiency)


def test_percentiles_guard_empty_and_nonfinite():
    from repro.serve.server import _percentiles

    assert _percentiles([]) == {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    assert _percentiles([np.nan, np.inf]) == {"p50": 0.0, "p99": 0.0,
                                              "mean": 0.0}
    p = _percentiles([0.5, np.nan, 1.5])  # finite entries still summarized
    assert p["mean"] == pytest.approx(1.0)


def test_slot_efficiency_never_negative():
    from repro.serve.server import ServeReport

    # pathological accounting (more requests than tokens) clamps at 0
    rep = ServeReport(n_requests=5, total_tokens=2, wall_s=1.0,
                      n_decode_steps=3, ttft_s=[], tpot_s=[],
                      outputs={}, kv_stats={})
    rep._n_slots = 2
    assert rep.slot_efficiency == 0.0


# ---------------------------------------------------------------------------
# Compressed serving
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qat_model():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_compressed_sparsity0_tokens_exact(qat_model):
    """target_sparsity=0: the BSR-kernel engine must reproduce the dense
    (QAT-math) engine's greedy tokens EXACTLY - compression may only drop
    zero blocks, never change numerics."""
    cfg, params = qat_model
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(4).integers(0, cfg.vocab, (2, 7)), jnp.int32)}
    want = Engine(cfg, params, ServeConfig(max_new_tokens=5)).generate(batch)
    sp = DP.compress(cfg, params, target_sparsity=0.0,
                     schedule=DP.default_schedule(cfg))
    got = Engine(cfg, sp, ServeConfig(max_new_tokens=5),
                 fns=DP.model_fns(cfg)).generate(batch)
    np.testing.assert_array_equal(got, want)


def test_compressed_batch_server_sparsity0_tokens_exact(qat_model):
    """Same honesty bar for the continuous-batching path."""
    cfg, params = qat_model
    reqs = _trace(cfg, n=4, seed=9, max_new=6)
    want = _engine_reference(cfg, params, reqs)
    sp = DP.compress(cfg, params, target_sparsity=0.0)
    srv = BatchServer(cfg, sp, ServeConfig(),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=24))
    rep = srv.run(_trace(cfg, n=4, seed=9, max_new=6))
    for r in reqs:
        np.testing.assert_array_equal(rep.outputs[r.rid], want[r.rid])


def test_compressed_projections_match_reference(qat_model):
    """Paper-sparsity packing: every deployed projection must match the
    dense quantized oracle (same mask + quant, dense math) within float
    tolerance - the schedule's tile is the kernel's tile."""
    cfg, params = qat_model
    ts = 0.5
    sched = DP.default_schedule(cfg)
    sp = DP.compress(cfg, params, target_sparsity=ts, schedule=sched)
    deployed = sp.deployed()
    assert len(deployed) == cfg.n_layers * 7 + 1  # QKV/O + 3 MLP + head
    per_layer = [jax.tree.map(lambda a: a[i], params["layers"])
                 for i in range(cfg.n_layers)]
    rng = np.random.default_rng(1)
    checked = 0
    for name, dw in deployed.items():
        if name == "head":
            w = params["head"]
        else:
            blk, proj = name.split("_", 1)
            w = per_layer[int(blk[3:])][proj]
        x = jnp.asarray(rng.standard_normal((4, dw.d_in)), jnp.float32)
        bk, bn = dw.tile
        got = D.deployed_matmul(x, dw, a_bits=cfg.cim.quant.a_bits)
        want = D.reference_matmul(x, w, cfg.cim, target_sparsity=ts,
                                  bk=bk, bn=bn)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4, err_msg=name)
        checked += 1
    assert checked == len(deployed)
    # compression actually dropped blocks at this sparsity
    assert sp.report()["compression_x"] > 4.0


def test_compress_respects_schedule_tile(qat_model):
    cfg, params = qat_model
    sched = DP.default_schedule(cfg)
    sp = DP.compress(cfg, params, target_sparsity=0.3, schedule=sched)
    by_name = {s.name: s for s in sched.layers}
    for name, dw in sp.deployed().items():
        if name == "head":
            continue
        g, a = by_name[name].group, by_name[name].alpha
        assert dw.tile == D.fit_tile(dw.d_in, dw.d_out, g, a), name


def test_serving_params_pytree_roundtrip(qat_model):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.25)
    leaves, treedef = jax.tree.flatten(sp)
    sp2 = jax.tree.unflatten(treedef, leaves)
    batch = {"tokens": jnp.asarray(np.arange(10, dtype=np.int32).reshape(2, 5))}
    a, _ = DP.prefill(sp, batch, cfg)
    b, _ = DP.prefill(sp2, batch, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prune_mask_keeps_everything_at_zero_sparsity():
    from repro.core import sparsity as S
    w = jnp.asarray(np.random.default_rng(0).standard_normal((32, 32)),
                    jnp.float32)
    assert float(S.prune_mask_2d(w, 8, 8, 0.0).mean()) == 1.0
    assert float(S.prune_mask_conv(w.reshape(2, 2, 8, 32), 8, 8, 0.0).mean()) == 1.0


# ---------------------------------------------------------------------------
# Block lifecycle: atomic exhaustion, scrub-on-free (ISSUE 8 satellites)
# ---------------------------------------------------------------------------


def test_ensure_exhaustion_is_atomic(dense_model):
    """On pool exhaustion ``ensure`` must raise WITHOUT growing the table -
    a caller that catches the error and requeues the request would
    otherwise leak every block appended before the failure."""
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=6, block_size=4)
    kv.ensure(0, 12)  # 3 of the 5 usable blocks
    free_before = list(kv._free)
    with pytest.raises(RuntimeError, match="exhausted"):
        kv.ensure(1, 16)  # needs 4, only 2 free
    assert kv.tables[1] == []  # nothing leaked into the failed table
    assert kv._free == free_before  # nothing popped either
    assert kv.free_blocks + kv.blocks_in_use == kv.n_blocks - 1
    kv.ensure(1, 8)  # a fitting request still succeeds afterwards
    assert len(kv.tables[1]) == 2


def test_freed_blocks_are_scrubbed(dense_model):
    """``free_slot`` must zero returned blocks: once blocks are shared, a
    reused block carrying the previous request's K/V would surface in
    another slot's gathered view."""
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=1, n_blocks=4, block_size=2)
    L_, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    k = np.ones((L_, 4, KV, dh), np.float32)
    kv.write_prefill(0, jnp.asarray(k), jnp.asarray(2 * k), true_len=4)
    held = list(kv.tables[0])
    assert all(np.any(kv.pool_k[0, b]) for b in held)
    kv.free_slot(0)
    for b in held:
        assert not np.any(kv.pool_k[0, b]), f"block {b} kept stale K"
        assert not np.any(kv.pool_v[0, b]), f"block {b} kept stale V"
    # and a realloc-then-gather sees zeros, not the old payload
    kv.ensure(0, 2)
    got_k, got_v = kv.gather(n_view=1)
    assert not np.any(np.asarray(got_k)) and not np.any(np.asarray(got_v))


def test_debug_poison_fills_freed_blocks_with_nan(dense_model):
    """Under ``debug_poison`` a freed float block is NaN-filled so any
    gather that wrongly references it poisons its output loudly."""
    cfg, _ = dense_model
    kv = PagedKVCache(cfg, n_slots=1, n_blocks=4, block_size=2,
                      debug_poison=True)
    L_, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    k = np.ones((L_, 2, KV, dh), np.float32)
    kv.write_prefill(0, jnp.asarray(k), jnp.asarray(k), true_len=2)
    held = list(kv.tables[0])
    kv.free_slot(0)
    for b in held:
        assert np.all(np.isnan(kv.pool_k[0, b]))
        assert np.all(np.isnan(kv.pool_v[0, b]))
