"""Decode-path correctness: step-by-step decoding with caches must match
teacher-forced full-sequence logits (validates KV caches, RoPE offsets,
sliding-window masks, SSD chunked<->recurrent equivalence, hybrid shared
blocks, cross-attention caching)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry, transformer, encdec
from repro.models import layers as L

ARCHS = ["yi-6b", "gemma3-27b", "phi3.5-moe-42b-a6.6b", "mamba2-780m",
         "zamba2-1.2b", "whisper-tiny"]


def _full_logits(params, batch, cfg):
    """Teacher-forced logits at every position (B, S, V)."""
    if cfg.family == "encdec":
        enc = encdec.encode(params, batch["frames"], cfg)
        hidden = encdec.decode_full(params, batch["tokens"], enc, cfg)
        return L.logits_out(params["embed"].T, hidden, cfg.cim)
    hidden, _, _ = transformer.forward_hidden(params, batch, cfg)
    head = params["head"] if "head" in params else params["embed"].T
    return L.logits_out(head, hidden, cfg.cim)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forced(arch):
    # capacity_factor high enough that MoE routing is drop-free: capacity
    # dropping differs between teacher-forced (tokens compete in a group)
    # and decode (each token alone) - expected, not a cache bug.
    cfg = registry.get_smoke_config(arch, dtype="float32", capacity_factor=16.0)
    fns = registry.model_fns(cfg)
    key = jax.random.PRNGKey(0)
    params = fns.init_params(cfg, key)
    B, S = 2, 12
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.02

    ref = np.asarray(_full_logits(params, batch, cfg))  # (B,S,V)

    cache = fns.init_cache(cfg, B, max_len=S)
    if cfg.family == "encdec":
        enc = encdec.encode(params, batch["frames"], cfg)

        def perlayer_xkv(p):
            b, t, _ = enc.shape
            kx = enc @ p["cross"]["wk"].astype(enc.dtype)
            vx = enc @ p["cross"]["wv"].astype(enc.dtype)
            return (kx.reshape(b, t, cfg.n_kv_heads, cfg.dh),
                    vx.reshape(b, t, cfg.n_kv_heads, cfg.dh))

        kx, vx = jax.vmap(perlayer_xkv)(params["dec_layers"])
        cache["xk"], cache["xv"] = kx, vx

    step = jax.jit(fns.decode_step, static_argnames=("cfg",))
    for t in range(S):
        tok = batch["tokens"][:, t : t + 1]
        logits, cache = step(params, cache, tok, cfg)
        np.testing.assert_allclose(
            np.asarray(logits), ref[:, t, :], rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode diverges from teacher-forced at t={t}",
        )


def test_vlm_prefill_decode_continuation():
    """llava: prefill(patches+prompt) then decode must equal full forward."""
    cfg = registry.get_smoke_config("llava-next-34b", dtype="float32")
    fns = registry.model_fns(cfg)
    key = jax.random.PRNGKey(1)
    params = fns.init_params(cfg, key)
    B, S = 2, 10
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "patch_embeds": jax.random.normal(key, (B, cfg.n_patches, cfg.d_model)) * 0.02,
    }
    total = cfg.n_patches + S
    logits_pre, cache = fns.prefill(params, batch, cfg)
    cache = transformer.pad_cache(cache, total + 4)

    # teacher-forced reference for the next token after position S-1
    batch2 = dict(batch)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    ref = np.asarray(_full_logits(params, batch2, cfg))  # (B, total+1, V)

    np.testing.assert_allclose(
        np.asarray(logits_pre), ref[:, total - 1, :], rtol=2e-3, atol=2e-3,
        err_msg="prefill last-position logits mismatch",
    )
    logits_dec, cache = fns.decode_step(params, cache, nxt, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_dec), ref[:, total, :], rtol=2e-3, atol=2e-3,
        err_msg="decode continuation mismatch",
    )
