"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models.registry import ARCH_IDS


def _batch_for(cfg, B=2, S=32, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(k, (B, cfg.n_patches, cfg.d_model),
                                                  jnp.float32) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k, (B, cfg.enc_seq, cfg.d_model),
                                            jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = registry.get_smoke_config(arch, dtype="float32")
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(fns.train_loss)(params, batch, cfg)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_qat_smoke(arch):
    """The paper's technique enabled end-to-end (w8a8 QAT + group lasso)."""
    cfg = registry.get_smoke_config(
        arch, dtype="float32", cim_mode="qat", w_bits=8, a_bits=8,
        lambda_g=1e-4, cim_alpha=16, cim_n=16,
    )
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(fns.train_loss)(params, batch, cfg)
    assert jnp.isfinite(loss), f"{arch}: non-finite QAT loss"
    gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: bad QAT grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = registry.get_smoke_config(arch, dtype="float32")
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)
    logits, cache = fns.prefill(params, batch, cfg)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: prefill logits NaN"

    # prefill cache layout differs from the fixed-size decode cache; decode
    # continuity vs full-forward is covered in test_decode_consistency.
    dcache = fns.init_cache(cfg, B, max_len=S + 8)
    if cfg.family == "encdec":
        dcache["xk"], dcache["xv"] = cache["xk"], cache["xv"]
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, dcache = fns.decode_step(params, dcache, tok, cfg)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2))), f"{arch}: decode logits NaN"
    assert int(dcache["pos"]) == 1
