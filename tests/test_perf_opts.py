"""Correctness of the beyond-paper performance path (EXPERIMENTS.md §Perf):
chunked online-softmax attention and TP head padding must be numerically
equivalent to the baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import registry


@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("kv", [2, 4])
def test_chunked_attention_matches_full(window, kv):
    key = jax.random.PRNGKey(0)
    B, S, H, dh = 2, 128, 4, 16
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, kv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, kv, dh))
    mask = L.causal_mask(S, S, window)
    want = L.attention_scores(q, L._expand_kv(k, H), L._expand_kv(v, H), mask)
    for chunk in (32, 48, 128):  # 48 exercises ragged padding
        got = L.chunked_attention(q, k, v, H, chunk, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"chunk={chunk} window={window}")


def test_head_padding_forward_identical():
    """head_pad zero-inits the padded Q/KV slices -> same train loss."""
    base = registry.get_smoke_config("llava-next-34b", dtype="float32")
    # smoke config: 4 heads / 2 kv; pad to 6/3-ish via head_pad=3 -> 6 heads
    padded = registry.get_smoke_config("llava-next-34b", dtype="float32",
                                       head_pad=3)
    assert padded.n_heads_eff == 6 and padded.n_heads == 4
    fns = registry.model_fns(base)
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, base.vocab),
        "patch_embeds": jax.random.normal(key, (2, base.n_patches, base.d_model)) * 0.02,
    }
    p_base = fns.init_params(base, key)
    p_pad = fns.init_params(padded, key)
    # graft the base weights into the padded layout (pad slices stay zero)
    dh = base.dh
    for name, n_true in [("wq", base.n_heads), ("wk", base.n_kv_heads),
                         ("wv", base.n_kv_heads)]:
        w = np.array(p_pad["layers"][name])
        w[:, :, : n_true * dh] = np.asarray(p_base["layers"][name])
        w[:, :, n_true * dh:] = 0.0
        p_pad["layers"][name] = jnp.asarray(w)
    wo = np.zeros(np.asarray(p_pad["layers"]["wo"]).shape, np.float32)
    wo[:, : base.n_heads * dh, :] = np.asarray(p_base["layers"]["wo"])
    p_pad["layers"]["wo"] = jnp.asarray(wo)
    for k2 in ("embed", "head", "final_ln", "mm_proj"):
        p_pad[k2] = p_base[k2]
    for k2 in ("ln1", "ln2", "w_gate", "w_up", "w_down"):
        p_pad["layers"][k2] = p_base["layers"][k2]

    l_base = fns.train_loss(p_base, batch, base)
    l_pad = fns.train_loss(p_pad, batch, padded)
    np.testing.assert_allclose(float(l_base), float(l_pad), rtol=1e-5)


def test_chunked_train_loss_matches():
    cfg0 = registry.get_smoke_config("yi-6b", dtype="float32")
    cfg1 = registry.get_smoke_config("yi-6b", dtype="float32", attn_chunk=8)
    fns = registry.model_fns(cfg0)
    key = jax.random.PRNGKey(0)
    params = fns.init_params(cfg0, key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg0.vocab)}
    l0 = fns.train_loss(params, batch, cfg0)
    l1 = fns.train_loss(params, batch, cfg1)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_moe_group_size_invariant():
    cfg0 = registry.get_smoke_config("phi3.5-moe-42b-a6.6b", dtype="float32",
                                     capacity_factor=16.0)
    cfg1 = registry.get_smoke_config("phi3.5-moe-42b-a6.6b", dtype="float32",
                                     capacity_factor=16.0, moe_group_size=8)
    fns = registry.model_fns(cfg0)
    key = jax.random.PRNGKey(0)
    params = fns.init_params(cfg0, key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg0.vocab)}
    l0 = fns.train_loss(params, batch, cfg0)
    l1 = fns.train_loss(params, batch, cfg1)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
