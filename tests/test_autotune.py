"""Measured-latency autotuner + cost-constant re-fit tests.

Contracts under test:

  * the autotuner's shortlist always contains the simulated pick, and the
    measured winner's fenced wall clock on the timed workload is <= the
    simulated pick's (the acceptance criterion of the observe->tune loop);
  * the AutotuneCache round-trips through ``save_artifact`` /
    ``load_artifact`` manifests, a populated cache SKIPS measurement
    entirely, a miss with measurement disabled falls back to the simulated
    tile, and a backend-key mismatch reads as a miss (a TPU wall clock
    must never pick a CPU tile);
  * ``fit_cycle_constants`` recovers synthetic per-phase cost coefficients
    (near-zero residual), degrades to the uniform-scale fallback on
    degenerate systems instead of crashing, and its re-derived
    HardwareConfig reproduces the fitted seconds exactly;
  * the all-gather cost model is zero without a mesh, monotone in bytes
    and devices, and surfaces as the ``collective`` phase of the sharded
    serve prediction.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import perf_model as PM
from repro.kernels.timing import DispatchTimer
from repro.models import registry
from repro.obs import gap as obs_gap
from repro.sched import autotune as AT
from repro.serve import deployed as DP

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def cfg():
    return registry.get_smoke_config("yi-6b", dtype="float32")


@pytest.fixture(scope="module")
def tuned(cfg):
    """One real (slow-ish) autotune pass shared by the module's tests."""
    cache = AT.AutotuneCache()
    res = AT.autotune(cfg, top_n=2, target_sparsity=0.5, prefill_rows=8,
                      decode_rows=2, repeats=1, cache=cache)
    return res, cache


# ---------------------------------------------------------------------------
# workload signature + key
# ---------------------------------------------------------------------------


def test_projection_shapes_stable_and_counted(cfg):
    shapes = AT.projection_shapes(cfg)
    assert shapes == AT.projection_shapes(cfg)
    assert all(d_in > 0 and d_out > 0 and n > 0 for d_in, d_out, n in shapes)
    # counts must cover every projection of every block
    assert sum(n for *_, n in shapes) == 7 * cfg.n_layers


def test_autotune_key_includes_backend(cfg):
    k_cpu = AT.autotune_key(cfg, backend="cpu")
    k_tpu = AT.autotune_key(cfg, backend="tpu")
    assert k_cpu != k_tpu
    assert cfg.name in k_cpu and "cpu" in k_cpu
    assert AT.autotune_key(cfg) == AT.autotune_key(cfg, jax.default_backend())


# ---------------------------------------------------------------------------
# measurement + the measured-winner contract
# ---------------------------------------------------------------------------


def test_measure_tile_times_real_kernel():
    timer = DispatchTimer(enabled=True)
    row = AT.measure_tile([(32, 32, 2)], (16, 16), 0.5, prefill_rows=8,
                          decode_rows=2, repeats=1, timer=timer)
    assert row["tile"] == [16, 16]
    assert row["backend"] == jax.default_backend()
    assert row["total_s"] == pytest.approx(row["prefill_s"] + row["decode_s"])
    assert row["total_s"] > 0
    # one prefill + one decode sample per distinct shape
    assert len(row["samples"]) == 2
    for s in row["samples"]:
        assert s["measured_s"] > 0 and np.isfinite(s["measured_s"])
        assert set(s["phases"]) == {"compute", "fm", "reload", "ctrl"}
    # the fenced dispatches went through the shared timer
    assert timer.records and all(r.name.startswith("autotune.")
                                 for r in timer.records)


def test_autotune_measured_winner_not_slower_than_sim_pick(tuned):
    res, _ = tuned
    assert not res.cache_hit
    assert len(res.table) == 2
    by_tile = {tuple(r["tile"]): r for r in res.table}
    assert res.simulated_tile in by_tile  # sim pick is always shortlisted
    best_row = by_tile[res.best_tile]
    sim_row = by_tile[res.simulated_tile]
    # the acceptance criterion: measured wall clock of the autotuned tile
    # <= the simulated pick's on the same fenced workload
    assert best_row["total_s"] <= sim_row["total_s"]
    assert best_row["total_s"] == min(r["total_s"] for r in res.table)


def test_refit_from_autotune_table(tuned):
    res, _ = tuned
    refit = AT.refit_from_table(res.table)
    assert refit.n_samples == sum(len(r["samples"]) for r in res.table)
    assert np.isfinite(refit.residual) and refit.residual >= 0
    assert all(v >= 0 for v in refit.seconds_per_cycle.values())
    # the re-derived hw must price a sample at the fitted coefficients
    s = res.table[0]["samples"][0]
    assert refit.predict_seconds(s["phases"]) > 0


# ---------------------------------------------------------------------------
# cache semantics
# ---------------------------------------------------------------------------


def test_cache_hit_skips_timing(cfg, tuned, monkeypatch):
    res, cache = tuned
    assert cache.get(res.key) is not None

    def boom(*a, **kw):  # measurement must never run on a hit
        raise AssertionError("cache hit must not re-measure")

    monkeypatch.setattr(AT, "measure_tile", boom)
    res2 = AT.autotune(cfg, top_n=2, target_sparsity=0.5, cache=cache)
    assert res2.cache_hit
    assert res2.best_tile == res.best_tile
    assert res2.table == []


def test_cache_miss_falls_back_to_simulated_tile(cfg):
    res = AT.autotune(cfg, top_n=2, target_sparsity=0.5,
                      cache=AT.AutotuneCache(), allow_measure=False)
    assert not res.cache_hit
    assert res.best_tile == res.simulated_tile
    assert res.table == []


def test_backend_key_mismatch_invalidates(cfg, tuned, monkeypatch):
    res, cache = tuned
    # re-key the stored entry as if it had been measured on a TPU: booting
    # on this (cpu) backend must MISS and fall back to the simulated tile
    other = AT.AutotuneCache(
        {AT.autotune_key(cfg, backend="tpu"): cache.get(res.key)})

    def boom(*a, **kw):
        raise AssertionError("mismatched backend must not serve the cache")

    monkeypatch.setattr(AT, "measure_tile", boom)
    res2 = AT.autotune(cfg, top_n=2, target_sparsity=0.5, cache=other,
                       allow_measure=False)
    assert not res2.cache_hit
    assert res2.best_tile == res2.simulated_tile


def test_cache_round_trips_through_artifact(tmp_path, cfg, tuned):
    res, cache = tuned
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    sp = DP.from_params(cfg, params)
    path = str(tmp_path / "artifact")
    DP.save_artifact(path, sp, cfg, extra={"autotune": cache.to_json(),
                                           "autotune_tile": list(res.best_tile)})
    _, _, meta = DP.load_artifact_tiers(path)
    loaded = AT.AutotuneCache.from_json(meta["autotune"])
    hit = loaded.get(res.key)
    assert hit is not None
    assert tuple(hit["best_tile"]) == res.best_tile
    assert hit["backend"] == res.backend
    assert meta["autotune_tile"] == list(res.best_tile)
    # manifest went through JSON: the payload must be pure-JSON types
    json.dumps(meta["autotune"])


def test_cache_from_json_rejects_malformed():
    with pytest.raises(ValueError):
        AT.AutotuneCache.from_json(["not", "a", "dict"])
    with pytest.raises(ValueError):
        AT.AutotuneCache.from_json({"schema": 99, "entries": {}})
    with pytest.raises(ValueError):
        AT.AutotuneCache.from_json(
            {"schema": AT.CACHE_SCHEMA,
             "entries": {"k": {"best_tile": [0, "x"]}}})


# ---------------------------------------------------------------------------
# cost-constant re-fit
# ---------------------------------------------------------------------------


def _synthetic_samples(theta, n=12, seed=0):
    rng = np.random.default_rng(seed)
    samples = []
    for _ in range(n):
        m = int(rng.integers(1, 64))
        k = int(rng.choice([64, 128, 256]))
        out = int(rng.choice([64, 128, 256]))
        layer = PM.ConvLayer(1, 1, k, out, 1, m, float(rng.uniform(0, 0.9)))
        phases = PM.layer_phase_cycles(layer, 8, 8)
        secs = float(np.dot(PM.phase_features(phases), theta))
        samples.append((phases, secs))
    return samples


def test_fit_cycle_constants_recovers_synthetic_coefficients():
    theta = (2e-9, 5e-9, 1e-9)
    refit = PM.fit_cycle_constants(_synthetic_samples(theta))
    for got, want in zip((refit.seconds_per_cycle[k] for k in PM.REFIT_COEFFS),
                         theta):
        assert got == pytest.approx(want, rel=1e-6)
    assert refit.residual == pytest.approx(0.0, abs=1e-9)
    # the folded HardwareConfig reproduces the fit: cycles/cim_freq == t_mac
    assert refit.hw.cim_freq == pytest.approx(1.0 / theta[0], rel=1e-6)
    phases, secs = _synthetic_samples(theta, n=1, seed=7)[0]
    assert refit.predict_seconds(phases) == pytest.approx(secs, rel=1e-6)


def test_fit_cycle_constants_degenerate_falls_back():
    # a single sample cannot pin three coefficients: uniform-scale fallback
    layer = PM.ConvLayer(1, 1, 64, 64, 1, 8, 0.5)
    phases = PM.layer_phase_cycles(layer, 8, 8)
    refit = PM.fit_cycle_constants([(phases, 1e-3)])
    vals = list(refit.seconds_per_cycle.values())
    assert all(v == pytest.approx(vals[0]) for v in vals)  # one shared scale
    assert np.isfinite(refit.residual)
    assert refit.predict_seconds(phases) == pytest.approx(1e-3, rel=1e-6)


def test_fit_cycle_constants_rejects_garbage():
    layer = PM.ConvLayer(1, 1, 64, 64, 1, 8, 0.5)
    phases = PM.layer_phase_cycles(layer, 8, 8)
    with pytest.raises(ValueError):
        PM.fit_cycle_constants([(phases, float("nan")), (phases, -1.0)])


# ---------------------------------------------------------------------------
# all-gather cost model (sharded serve prediction)
# ---------------------------------------------------------------------------


def test_allgather_cycles_shape():
    hw = PM.DEFAULT_HW
    assert hw.allgather_cycles(4096, 1) == 0.0
    assert hw.allgather_cycles(0, 4) == 0.0
    c2, c4 = hw.allgather_cycles(4096, 2), hw.allgather_cycles(4096, 4)
    assert c2 > 0 and c4 > c2  # more hops
    assert hw.allgather_cycles(8192, 4) > c4  # more bytes


def test_predicted_serve_step_collective_phase(cfg):
    p1 = obs_gap.predicted_serve_step(cfg, 0.5, n_devices=1)
    p4 = obs_gap.predicted_serve_step(cfg, 0.5, n_devices=4)
    assert "collective" not in p1["phases"]
    assert p4["phases"]["collective"] > 0
    assert p4["predicted_s"] > p1["predicted_s"]
    # the non-collective phases are the single-device ones, unchanged
    for k, v in p1["phases"].items():
        assert p4["phases"][k] == pytest.approx(v)


def test_serve_gap_sharded_row(cfg):
    g = obs_gap.serve_gap(cfg, 5e-3, 0.5, n_devices=4)
    assert g["n_devices"] == 4
    assert np.isfinite(g["sim_vs_measured"]) and g["sim_vs_measured"] > 0
    assert "collective" in g["predicted_phase_shares"]
