"""expert_split correctness: splitting each expert's FFN into sub-experts
must be numerically identical to the unsplit computation (the grok-1
sharding trick - down(concat halves) == sum of half-downs)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import registry


def test_expert_split_exact():
    cfg1 = registry.get_smoke_config("grok-1-314b", dtype="float32",
                                     capacity_factor=16.0)
    cfg2 = registry.get_smoke_config("grok-1-314b", dtype="float32",
                                     capacity_factor=16.0, expert_split=2)
    key = jax.random.PRNGKey(0)
    e, d, ff = cfg1.n_experts, cfg1.d_model, cfg1.d_ff
    ks = jax.random.split(key, 4)
    p1 = {
        "router": jax.random.normal(ks[0], (d, e)) * 0.1,
        "w_gate": jax.random.normal(ks[1], (e, d, ff)) * 0.1,
        "w_up": jax.random.normal(ks[2], (e, d, ff)) * 0.1,
        "w_down": jax.random.normal(ks[3], (e, ff, d)) * 0.1,
    }
    # split view: (e, d, ff) -> (2e, d, ff/2); down (e, ff, d) -> (2e, ff/2, d)
    p2 = {
        "router": p1["router"],
        "w_gate": p1["w_gate"].reshape(e, d, 2, ff // 2).transpose(0, 2, 1, 3)
        .reshape(2 * e, d, ff // 2),
        "w_up": p1["w_up"].reshape(e, d, 2, ff // 2).transpose(0, 2, 1, 3)
        .reshape(2 * e, d, ff // 2),
        "w_down": p1["w_down"].reshape(e, 2, ff // 2, d).reshape(2 * e, ff // 2, d),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d)) * 0.3
    y1, aux1 = L.moe_block(p1, x, cfg1)
    y2, aux2 = L.moe_block(p2, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)
