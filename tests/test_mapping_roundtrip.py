"""core.mapping round-trips on the awkward shapes: ragged dims (not
multiples of group/alpha), all-zero weights, and nnz_max truncation."""
import numpy as np
import pytest

from repro.core import mapping as M


def _sparse_weight(rng, d_in, d_out, group, alpha, keep=0.4):
    """Weight whose zero pattern is exactly tile-structured."""
    gi, go = -(-d_in // group), -(-d_out // alpha)
    w = rng.normal(size=(d_in, d_out)).astype(np.float32)
    # make sure no accidental zeros, then kill tiles
    w[w == 0] = 1.0
    mask = rng.random((gi, go)) < keep
    for i in range(gi):
        for j in range(go):
            if not mask[i, j]:
                w[i * group: (i + 1) * group, j * alpha: (j + 1) * alpha] = 0.0
    return w


# ---------------------------------------------------------------------------
# pack_groupsets / unpack_groupsets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d_in,d_out", [(64, 64), (50, 40), (17, 33), (16, 16),
                                        (100, 7)])
def test_groupsets_roundtrip_ragged(d_in, d_out):
    rng = np.random.default_rng(0)
    w = _sparse_weight(rng, d_in, d_out, M.GROUP, 16)
    p = M.pack_groupsets(w)
    back = M.unpack_groupsets(p, d_in, d_out)
    np.testing.assert_array_equal(back, w)
    # survivors only: nnz matches the live-tile count
    gi, go = -(-d_in // M.GROUP), -(-d_out // 16)
    wp = np.zeros((gi * M.GROUP, go * 16), np.float32)
    wp[:d_in, :d_out] = w
    tiles = wp.reshape(gi, M.GROUP, go, 16)
    assert p.nnz == int(np.any(tiles != 0, axis=(1, 3)).sum())


def test_groupsets_all_zero():
    p = M.pack_groupsets(np.zeros((48, 32), np.float32))
    assert p.nnz == 0
    assert p.blocks.shape == (0, M.GROUP, 16)
    back = M.unpack_groupsets(p, 48, 32)
    assert back.shape == (48, 32)
    assert not back.any()


def test_groupsets_index_code_fields_survive():
    rng = np.random.default_rng(1)
    w = _sparse_weight(rng, 128, 64, M.GROUP, 16, keep=0.5)
    p = M.pack_groupsets(w)
    for code, i, j in zip(p.codes, p.spatial_pos, p.channel_pos):
        first, total, spatial, channel = M.decode_index(int(code))
        assert channel == i % 32
        assert spatial == (i // 32) % 16
        assert 0 <= total <= 63


# ---------------------------------------------------------------------------
# pack_bsr / bsr_to_dense
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bk,bn", [(16, 16), (8, 32), (32, 8)])
def test_bsr_roundtrip(bk, bn):
    rng = np.random.default_rng(2)
    w = _sparse_weight(rng, 64, 64, bk, bn, keep=0.3)
    bw = M.pack_bsr(w, bk, bn)
    np.testing.assert_array_equal(M.bsr_to_dense(bw), w)
    assert 0.0 < bw.density <= 1.0


def test_bsr_all_zero():
    bw = M.pack_bsr(np.zeros((64, 32), np.float32), 16, 16)
    assert bw.nnz.sum() == 0
    assert bw.density == 0.0
    assert not M.bsr_to_dense(bw).any()


def test_bsr_nnz_max_truncation_keeps_first_rows():
    rng = np.random.default_rng(3)
    w = _sparse_weight(rng, 128, 32, 16, 16, keep=1.0)  # fully dense blocks
    bw = M.pack_bsr(w, 16, 16, nnz_max=3)
    assert bw.blocks.shape[1] == 3
    dense = M.bsr_to_dense(bw)
    # the first 3 block-rows of each column survive, the rest truncate.
    # NOTE bsr_to_dense caps at nnz (true counts) which exceed nnz_max;
    # reconstruct by slots actually stored
    for j in range(32 // 16):
        for s in range(3):
            i = int(bw.row_idx[j, s])
            np.testing.assert_array_equal(
                dense[i * 16:(i + 1) * 16, j * 16:(j + 1) * 16],
                w[i * 16:(i + 1) * 16, j * 16:(j + 1) * 16])


def test_bsr_rejects_ragged_shapes():
    with pytest.raises(AssertionError):
        M.pack_bsr(np.ones((50, 64), np.float32), 16, 16)
