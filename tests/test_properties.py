"""Hypothesis property tests for the MARS core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import mapping as M
from repro.core import quant as Q
from repro.core import sparsity as S

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Fig. 6 index codes
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(first=st.integers(0, 1), total=st.integers(0, 63),
       spatial=st.integers(0, 15), channel=st.integers(0, 31))
def test_index_code_roundtrip(first, total, spatial, channel):
    code = M.encode_index(first, total, spatial, channel)
    assert 0 <= code < 2**16  # fits the 16-bit Index SRAM word
    assert M.decode_index(code) == (first, total, spatial, channel)


# ---------------------------------------------------------------------------
# Group-set packing (Fig. 5b)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    gi=st.integers(1, 6), go=st.integers(1, 4),
    density=st.floats(0.0, 1.0), seed=st.integers(0, 2**16),
)
def test_pack_groupsets_roundtrip(gi, go, density, seed):
    rng = np.random.default_rng(seed)
    d_in, d_out = gi * 16, go * 16
    keep = rng.random((gi, go)) < density
    w = rng.standard_normal((d_in, d_out)).astype(np.float32)
    w *= np.repeat(np.repeat(keep, 16, 0), 16, 1)
    p = M.pack_groupsets(w, alpha=16)
    assert p.nnz == int(keep.sum())
    assert p.index_bits == 16 * p.nnz  # one 16-bit code per surviving set
    back = M.unpack_groupsets(p, d_in, d_out, alpha=16)
    np.testing.assert_array_equal(back, w)


@settings(**SETTINGS)
@given(
    gi=st.integers(1, 5), go=st.integers(1, 5),
    density=st.floats(0.0, 1.0), seed=st.integers(0, 2**16),
    bk=st.sampled_from([8, 16]), bn=st.sampled_from([8, 16]),
)
def test_pack_bsr_roundtrip(gi, go, density, seed, bk, bn):
    rng = np.random.default_rng(seed)
    keep = rng.random((gi, go)) < density
    w = rng.standard_normal((gi * bk, go * bn)).astype(np.float32)
    w *= np.repeat(np.repeat(keep, bk, 0), bn, 1)
    bsr = M.pack_bsr(w, bk, bn)
    np.testing.assert_array_equal(M.bsr_to_dense(bsr), w)
    assert abs(bsr.density - keep.mean()) < 1e-9


@settings(**SETTINGS)
@given(
    gi=st.integers(2, 6), go=st.integers(1, 4),
    density=st.floats(0.3, 1.0), seed=st.integers(0, 2**16),
    cap=st.integers(1, 4),
)
def test_pack_bsr_truncation_keeps_first_rows(gi, go, density, seed, cap):
    """With an explicit nnz_max, each column stores its FIRST ``cap``
    surviving rows; ``nnz`` keeps true counts and ``bsr_to_dense`` only
    reconstructs the stored slots."""
    rng = np.random.default_rng(seed)
    keep = rng.random((gi, go)) < density
    bk = bn = 8
    w = rng.standard_normal((gi * bk, go * bn)).astype(np.float32)
    w *= np.repeat(np.repeat(keep, bk, 0), bn, 1)
    bsr = M.pack_bsr(w, bk, bn, nnz_max=cap)
    assert bsr.blocks.shape[1] == cap
    np.testing.assert_array_equal(bsr.nnz, keep.sum(axis=0))
    want = np.zeros_like(w)
    for j in range(go):
        for i in np.flatnonzero(keep[:, j])[:cap]:
            want[i * bk:(i + 1) * bk, j * bn:(j + 1) * bn] = \
                w[i * bk:(i + 1) * bk, j * bn:(j + 1) * bn]
    np.testing.assert_array_equal(M.bsr_to_dense(bsr), want)


@settings(max_examples=15, deadline=None)
@given(
    gi=st.integers(1, 4), go=st.integers(1, 4), m=st.integers(1, 9),
    density=st.floats(0.0, 1.0), seed=st.integers(0, 2**16),
    bk=st.sampled_from([8, 16]), bn=st.sampled_from([8, 16]),
    truncate=st.booleans(),
)
def test_bsr_kernel_matches_dense(gi, go, m, density, seed, bk, bn, truncate):
    """The Pallas BSR kernel == x @ bsr_to_dense(packing) for any packing -
    including nnz_max-truncated ones and all-zero columns (padding slots
    must be masked from the accumulation, never summed)."""
    from repro.kernels import cim_bsr_matmul as K

    rng = np.random.default_rng(seed)
    keep = rng.random((gi, go)) < density
    w = rng.standard_normal((gi * bk, go * bn)).astype(np.float32)
    w *= np.repeat(np.repeat(keep, bk, 0), bn, 1)
    cap = max(1, gi - 1) if truncate else None
    bsr = M.pack_bsr(w, bk, bn, nnz_max=cap)
    x = rng.standard_normal((m, gi * bk)).astype(np.float32)
    y = K.bsr_matmul(jnp.asarray(x), jnp.asarray(bsr.blocks),
                     jnp.ones(bsr.row_idx.shape, jnp.float32),
                     jnp.asarray(bsr.row_idx), jnp.asarray(bsr.nnz),
                     bm=max(8, min(128, m)), interpret=True)
    np.testing.assert_allclose(np.asarray(y), x @ M.bsr_to_dense(bsr),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Quantizers (eqs. 5-8)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
def test_weight_quant_levels_and_range(bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((32, 32)) * 3, jnp.float32)
    wq = np.asarray(Q.mars_weight_quant(w, bits, group_size=16))
    qmax = 2 ** (bits - 1) - 1
    levels = np.unique(np.round(wq * 2 ** (bits - 1)))
    assert levels.size <= 2 * qmax + 1  # {-qmax..qmax}: implementable on macro
    assert np.abs(wq).max() <= qmax / 2 ** (bits - 1) + 1e-7
    # every output is exactly on the k/2^{b-1} hardware grid (int levels)
    np.testing.assert_allclose(wq * 2 ** (bits - 1),
                               np.round(wq * 2 ** (bits - 1)), atol=1e-6)
    # NOTE eq.8 is intentionally NOT idempotent: the grid is k/2^{b-1} while
    # the scale is (2^{b-1}-1) - matching the paper's macro exactly.


@settings(**SETTINGS)
@given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16),
       signed=st.booleans())
def test_activation_quant_grid(bits, seed, signed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal(256) * 2, jnp.float32)
    aq = np.asarray(Q.quantize_activation(a, bits, signed))
    denom = 2.0**bits if not signed else 2.0 ** (bits - 1)
    np.testing.assert_allclose(aq * denom, np.round(aq * denom), atol=1e-6)
    if signed:
        assert np.abs(aq).max() <= 1.0
    else:
        assert aq.min() >= 0.0 and aq.max() <= (2**bits - 1) / 2**bits


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), bits=st.sampled_from([4, 8]))
def test_ste_gradient_is_clip_mask(seed, bits):
    """STE backward of eq.5 == gradient of clamp (1 inside, 0 outside)."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal(64) * 2, jnp.float32)
    g = jax.grad(lambda x: jnp.sum(Q.quantize_activation(x, bits)))(a)
    inside = (np.asarray(a) > 0) & (np.asarray(a) < 1)
    scale = (2.0**bits - 1.0) / 2.0**bits
    np.testing.assert_allclose(np.asarray(g), inside * scale, atol=1e-6)


# ---------------------------------------------------------------------------
# Group lasso / pruning structure (eqs. 3-4)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), c=st.floats(0.1, 10.0))
def test_group_lasso_homogeneous(seed, c):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    r1 = float(S.group_lasso_2d(w, 16, 16))
    rc = float(S.group_lasso_2d(c * w, 16, 16))
    assert r1 >= 0
    np.testing.assert_allclose(rc, c * r1, rtol=1e-4)
    assert float(S.group_lasso_2d(jnp.zeros((64, 64)), 16, 16)) < 1e-6


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16),
       target=st.floats(0.1, 0.95),
       n=st.sampled_from([4, 8, 16]), alpha=st.sampled_from([8, 16]))
def test_prune_mask_is_tile_structured(seed, target, n, alpha):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    mask = np.asarray(S.prune_mask_2d(w, n, alpha, target))
    tiles = mask.reshape(64 // n, n, 64 // alpha, alpha).transpose(0, 2, 1, 3)
    per_tile = tiles.reshape(tiles.shape[0], tiles.shape[1], -1)
    # every tile is uniformly 0 or 1 - the CIM-skippable structure
    assert np.all((per_tile.min(-1) == per_tile.max(-1)))
    # achieved tile sparsity >= requested quantile (ties can exceed)
    zero_frac = 1.0 - per_tile.max(-1).mean()
    assert zero_frac >= target - 0.15


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16))
def test_storage_accounting_consistent(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    mask = S.prune_mask_2d(w, 16, 16, 0.5)
    zg = float(S.zero_groupset_proportion(mask, 16, 16))
    idx_bits = int(S.index_storage_bits(mask, 16, 16))
    n_sets = (64 // 16) * (64 // 16)
    assert idx_bits == 16 * round((1 - zg) * n_sets)


# ---------------------------------------------------------------------------
# Paged-KV block lifecycle (refcounts, CoW, atomic ensure)
# ---------------------------------------------------------------------------

_KV_CFG = None


def _kv_cfg():
    global _KV_CFG
    if _KV_CFG is None:
        from repro.models import registry
        _KV_CFG = registry.get_smoke_config("yi-6b", dtype="float32")
    return _KV_CFG


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_paged_kv_lifecycle_invariants(data):
    """Random admit/ensure/write/adopt/free sequences keep the pool
    accounting exact: every usable block is either free or live (counted
    once however many tables share it), refcounts equal table references,
    the free list never aliases a live block, and a failed ``ensure``
    changes nothing."""
    from repro.serve.batching import PagedKVCache

    cfg = _kv_cfg()
    n_slots, n_blocks, bs = 3, 8, 2
    kv = PagedKVCache(cfg, n_slots, n_blocks, bs)
    rng = np.random.default_rng(0)
    shared_used = False

    def check():
        assert kv.free_blocks + kv.blocks_in_use == kv.n_blocks - 1
        refs = np.zeros(kv.n_blocks, np.int64)
        for t in kv.tables:
            for b in t:
                assert b > 0  # scratch never enters a table
                refs[b] += 1
        # no prefix trie in play: table references ARE the refcounts
        np.testing.assert_array_equal(refs, kv.refcnt)
        if not shared_used:
            assert (kv.refcnt <= 1).all()  # no aliasing without adopt
        free = kv._free
        assert len(set(free)) == len(free) and 0 not in free
        assert all(kv.refcnt[b] == 0 for b in free)
        assert kv.peak_blocks <= kv.n_blocks - 1
        assert kv.n_reused <= kv.n_alloc

    for _ in range(data.draw(st.integers(1, 30))):
        op = data.draw(st.sampled_from(["ensure", "free", "write",
                                        "adopt", "write"]))
        s = data.draw(st.integers(0, n_slots - 1))
        if op == "ensure":
            n_pos = data.draw(st.integers(1, (n_blocks + 1) * bs))
            before = list(kv.tables[s])
            free_before = list(kv._free)
            try:
                kv.ensure(s, n_pos)
            except RuntimeError:  # exhausted: must be all-or-nothing
                assert kv.tables[s] == before
                assert kv._free == free_before
        elif op == "free":
            kv.free_slot(s)
        elif op == "adopt":
            src = data.draw(st.integers(0, n_slots - 1))
            if kv.tables[src] and not kv.tables[s] and src != s:
                kv.adopt(s, list(kv.tables[src]))
                shared_used = True
        else:  # decode-style write, copy-on-write when the block is shared
            if not kv.tables[s]:
                continue
            pos = data.draw(st.integers(0, len(kv.tables[s]) * bs - 1))
            positions = [None] * n_slots
            positions[s] = pos
            try:
                pb, off = kv.write_coords(positions)
            except RuntimeError:
                check()  # CoW found the pool exhausted: still balanced
                continue
            k = rng.standard_normal(
                (cfg.n_layers, n_slots, cfg.n_kv_heads_eff, cfg.dh)
            ).astype(np.float32)
            kv.write_token(pb, off, k, k)
            # after a write the touched block is exclusively owned
            assert kv.refcnt[kv.tables[s][pos // bs]] == 1
        check()
