"""End-to-end system behaviour tests: training convergence, fault-tolerant
resume, QAT+prune+deploy pipeline, serving determinism."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cim_layer as CL
from repro.core import sparsity as S
from repro.data import ImagePipeline, TokenPipeline
from repro.models import cnn, registry
from repro.serve import Engine, ServeConfig
from repro.train import (OptConfig, TrainConfig, checkpoint, init_train_state,
                         make_train_step)


def _train(cfg, tcfg, steps, pipe, state=None, key=0):
    if state is None:
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(key))
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return state, losses


def test_lm_training_loss_drops():
    cfg = registry.get_smoke_config("granite-8b", dtype="float32")
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5, total_steps=200))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq_len=32)
    _, losses = _train(cfg, tcfg, 25, pipe)
    assert losses[-1] < losses[0] - 0.2, f"no learning: {losses[0]} -> {losses[-1]}"


def test_qat_lm_training_loss_drops():
    """Training WITH the paper's technique converges too (w8a8 + lasso)."""
    cfg = registry.get_smoke_config(
        "granite-8b", dtype="float32", cim_mode="qat", w_bits=8, a_bits=8,
        lambda_g=1e-5, cim_alpha=16, cim_n=16,
    )
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=5, total_steps=200))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq_len=32)
    _, losses = _train(cfg, tcfg, 25, pipe)
    assert losses[-1] < losses[0] - 0.2, f"QAT no learning: {losses[0]} -> {losses[-1]}"


def test_checkpoint_resume_bitwise(tmp_path):
    """Kill-and-restart: resume from step 10 must reproduce the run that
    never died (same data stream, same params) - fault-tolerance contract."""
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=100))

    pipe_a = TokenPipeline(vocab=cfg.vocab, batch=4, seq_len=16)
    state_a, _ = _train(cfg, tcfg, 10, pipe_a)
    d = str(tmp_path / "ck")
    checkpoint.save(d, 10, state_a, extra={"pipe": pipe_a.state()})
    state_a, _ = _train(cfg, tcfg, 5, pipe_a, state=state_a)  # continue to 15

    # "crash": fresh process state, restore
    template = init_train_state(cfg, tcfg, jax.random.PRNGKey(99))
    state_b, manifest = checkpoint.restore(d, template)
    pipe_b = TokenPipeline(vocab=cfg.vocab, batch=4, seq_len=16)
    pipe_b.restore(manifest["extra"]["pipe"])
    state_b, _ = _train(cfg, tcfg, 5, pipe_b, state=state_b)

    for ka, kb in zip(jax.tree.leaves(state_a["params"]), jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(ka), np.asarray(kb), rtol=1e-6, atol=1e-6)


def test_checkpoint_retention(tmp_path):
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    tcfg = TrainConfig(opt=OptConfig())
    state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    for s in [1, 2, 3, 4, 5]:
        checkpoint.save(d, s, state, keep=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]
    assert checkpoint.latest_step(d) == 5


def test_cnn_qat_prune_deploy_pipeline():
    """The full paper pipeline on a small CNN: QAT+lasso train -> prune ->
    retrain -> deploy check (masked weights stay masked, stats coherent)."""
    from repro.configs.vgg16_cifar import SMALL_PLAN, cim_config

    cim = cim_config(w_bits=4, a_bits=4, lambda_g=1e-3, mode="qat")
    key = jax.random.PRNGKey(0)
    params, state = cnn.vgg_init(key, cim, SMALL_PLAN, n_classes=4)
    pipe = ImagePipeline(n_classes=4, batch=16, hw=16)

    def loss_fn(p, st, batch):
        logits, st2 = cnn.vgg_apply(p, st, batch["images"], cim, SMALL_PLAN, train=True)
        ce = -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(logits), batch["labels"][:, None], 1)
        )
        return ce + cnn.regularization(p, cim), (ce, st2)

    @jax.jit
    def step(p, st, batch):
        (_, (ce, st2)), g = jax.value_and_grad(loss_fn, has_aux=True)(p, st, batch)
        p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
        return p, st2, ce

    ces = []
    for _ in range(60):
        b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, state, ce = step(params, state, b)
        ces.append(float(ce))
    assert np.mean(ces[-5:]) < np.mean(ces[:5]) - 0.05, \
        f"CNN QAT did not learn: {np.mean(ces[:5])} -> {np.mean(ces[-5:])}"

    # prune to the CIM structure
    import dataclasses
    cim_p = dataclasses.replace(
        cim, sparsity=dataclasses.replace(cim.sparsity, target_sparsity=0.6)
    )
    pruned = cnn.prune_all(params, cim_p)
    # group-sets live per spatial position (Fig. 6: spatial + channel
    # fields) - measure on the deepest conv where sparsity concentrates
    deep = pruned["convs"][4]  # (3,3,64,128)
    kh, kw, ci, co = deep["mask"].shape
    per_pos = jax.vmap(lambda m: S.zero_groupset_proportion(m, 16, 16))(
        deep["mask"].reshape(kh * kw, ci, co)
    )
    zg = float(jnp.mean(per_pos))
    assert zg > 0.3, f"pruning produced no skippable group-sets: {zg}"

    # retrain with mask: masked weights must remain exactly dead
    for _ in range(5):
        b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        pruned, state, ce = step(pruned, state, b)
    co = pruned["convs"][4]["w"].shape[-1]
    w_eff = CL.effective_weight(
        {"w": pruned["convs"][4]["w"].reshape(-1, co),
         "mask": pruned["convs"][4]["mask"].reshape(-1, co)},
        cim_p,
    )
    dead = np.asarray(pruned["convs"][4]["mask"].reshape(-1, co)) == 0
    assert np.all(np.asarray(w_eff)[dead] == 0.0)


def test_serving_deterministic():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=6))
    batch = {"tokens": jnp.asarray(np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab)}
    a = eng.generate(batch)
    b = eng.generate(batch)
    np.testing.assert_array_equal(a, b)


def test_data_pipeline_checkpoint_replay():
    p1 = TokenPipeline(vocab=100, batch=2, seq_len=8, seed=7)
    p1.next_batch()
    st = p1.state()
    b_expected = p1.next_batch()
    p2 = TokenPipeline(vocab=100, batch=2, seq_len=8, seed=7)
    p2.restore(st)
    np.testing.assert_array_equal(p2.next_batch()["tokens"], b_expected["tokens"])
