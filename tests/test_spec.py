"""Self-speculative decoding over two-tier CIM compression.

The contracts under test:

  * greedy exactness: ``BatchServer(engine="spec")`` emits BIT-IDENTICAL
    tokens to target-only greedy decode - dense and compressed targets,
    single-device and macro-sharded (subprocess mesh parity);
  * verify honesty: ``stacked.verify_step`` over T tokens reproduces T
    sequential ``decode_step_paged`` calls bit-exactly (the property the
    accept rule stands on);
  * draft-tier construction: re-pruning keeps the uniform tile, strictly
    drops blocks, and surviving blocks stay bit-identical to the target's;
  * KV hygiene: two-tier pools share one block layout and rejected draft
    KV never reaches the pool;
  * two-tier artifacts round-trip (shared dense leaves stored once) and
    the booted tiers serve identically;
  * the speculative cost model behaves (monotone in acceptance, search
    returns a simulated-feasible winner).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import perf_model as PM
from repro.models import registry
from repro.serve import (BatchConfig, BatchServer, Request, ServeConfig,
                         SpecConfig)
from repro.serve import deployed as DP
from repro.serve import spec as SP
from repro.serve import stacked as ST


@pytest.fixture(scope="module")
def qat_model():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _trace(cfg, n=5, seed=7, max_prompt=12, max_new=9):
    rng = np.random.default_rng(seed)
    return [Request(f"r{i}",
                    rng.integers(0, cfg.vocab, int(rng.integers(2, max_prompt))),
                    int(rng.integers(1, max_new))) for i in range(n)]


_BCFG = dict(n_slots=2, block_size=4, n_blocks=32)


# ---------------------------------------------------------------------------
# Greedy exactness: spec tokens == target-only tokens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ts", [0.0, 0.5])
def test_spec_matches_target_only_compressed(qat_model, ts):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=ts, tile=(16, 16))
    draft = SP.draft_serving(cfg, sp, 0.85)
    bcfg = BatchConfig(**_BCFG)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg,
                       engine="scan").run(_trace(cfg))
    got = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="spec",
                      draft=draft,
                      spec=SpecConfig(k=3, draft_sparsity=0.85)
                      ).run(_trace(cfg))
    for r in _trace(cfg):
        np.testing.assert_array_equal(got.outputs[r.rid], want.outputs[r.rid],
                                      err_msg=f"ts={ts} {r.rid}")
    assert got.spec["n_rounds"] > 0
    assert got.spec["slot_rounds"] >= got.spec["n_rounds"]
    assert 0.0 <= got.spec["acceptance_rate"] <= 1.0
    assert got.spec["tokens_per_verify"] >= 1.0


@pytest.mark.parametrize("k", [1, 4])
def test_spec_matches_target_only_dense(qat_model, k):
    cfg, params = qat_model
    sp = DP.from_params(cfg, params)
    draft = SP.draft_serving(cfg, sp, 0.8, tile=(16, 16))
    bcfg = BatchConfig(**_BCFG)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg,
                       engine="scan").run(_trace(cfg, seed=11))
    got = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="spec",
                      draft=draft,
                      spec=SpecConfig(k=k, draft_sparsity=0.8)
                      ).run(_trace(cfg, seed=11))
    for r in _trace(cfg, seed=11):
        np.testing.assert_array_equal(got.outputs[r.rid], want.outputs[r.rid],
                                      err_msg=f"k={k} {r.rid}")


def test_spec_identical_tiers_accept_everything(qat_model):
    """Draft == target packing: every draft token the budget allows must
    be accepted (the accept rule compares the target against itself)."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    reqs = [Request(f"r{i}", np.arange(4) + i, 24) for i in range(3)]
    rep = BatchServer(cfg, sp, ServeConfig(), BatchConfig(**_BCFG),
                      engine="spec", draft=sp,
                      spec=SpecConfig(k=3, draft_sparsity=0.5)).run(reqs)
    st = rep.spec
    # only end-of-budget truncation may leave proposals unconverted: per
    # request at most one final partial round
    assert st["proposed"] - st["accepted"] <= st["k"] * len(reqs)
    assert st["tokens_per_verify"] > 2.0


def test_spec_eos_stops_inside_accepted_run(qat_model):
    """An EOS inside an accepted run must cut the stream exactly where
    sequential decode would have stopped."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    bcfg = BatchConfig(**_BCFG)
    reqs = [Request("r0", np.arange(5), 20)]
    ref = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="scan").run(
        [Request("r0", np.arange(5), 20)])
    eos = int(ref.outputs["r0"][2])  # force a stop on the 3rd greedy token
    want = BatchServer(cfg, sp, ServeConfig(eos_id=eos), bcfg,
                       engine="scan").run([Request("r0", np.arange(5), 20)])
    got = BatchServer(cfg, sp, ServeConfig(eos_id=eos), bcfg, engine="spec",
                      draft=sp, spec=SpecConfig(k=4, draft_sparsity=0.5)
                      ).run([Request("r0", np.arange(5), 20)])
    np.testing.assert_array_equal(got.outputs["r0"], want.outputs["r0"])


def test_spec_matches_target_macro_sharded():
    """Acceptance: spec decode over macro-sharded two-tier envelopes
    reproduces single-device target-only tokens at mesh macro=2
    (subprocess: forced host devices must exist before jax imports)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = " ".join(
        ([env["XLA_FLAGS"]] if env.get("XLA_FLAGS") else [])
        + ["--xla_force_host_platform_device_count=8"])
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = """
import numpy as np, jax
from repro.models import registry
from repro.serve import BatchConfig, BatchServer, ServeConfig, Request, SpecConfig
from repro.serve import deployed as DP
from repro.serve import spec as SP
from repro.launch.shardings import macro_mesh

cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
def trace():
    rng = np.random.default_rng(7)
    return [Request(f"r{i}", rng.integers(0, cfg.vocab, int(rng.integers(2, 10))),
                    int(rng.integers(1, 7))) for i in range(3)]
sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
draft = SP.draft_serving(cfg, sp, 0.85)
bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
want = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="scan").run(trace())
mesh = macro_mesh(2)
srv = BatchServer(cfg, DP.shard(sp, mesh), ServeConfig(), bcfg, mesh=mesh,
                  engine="spec", draft=DP.shard(draft, mesh),
                  spec=SpecConfig(k=3, draft_sparsity=0.85))
assert any(sw.mesh is not None for sw in srv._params.target.packed.values()), \\
    "no target envelope actually sharded"
assert any(sw.mesh is not None for sw in srv._params.draft.packed.values()), \\
    "no draft envelope actually sharded"
rep = srv.run(trace())
for r in trace():
    np.testing.assert_array_equal(rep.outputs[r.rid], want.outputs[r.rid],
                                  err_msg=f"macro=2 {r.rid}")
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Verify pass honesty: multi-token == sequential, bit for bit
# ---------------------------------------------------------------------------


def test_verify_step_matches_sequential_decode(qat_model):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    sxp = ST.stack(sp)
    B, T, Sv = 2, 4, 16
    L, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    rng = np.random.default_rng(0)
    vk = jnp.asarray(rng.standard_normal((L, B, Sv, KV, dh)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((L, B, Sv, KV, dh)), jnp.float32)
    pos = jnp.asarray([3, 5], jnp.int32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    lg_multi, ks, vs = ST.verify_step(sxp, vk, vv, pos, toks, cfg)
    assert ks.shape == (L, B, T, KV, dh)
    rows = jnp.arange(B)
    vk2, vv2 = vk, vv
    for t in range(T):
        lg, kn, vn = ST.decode_step_paged(sxp, vk2, vv2, pos + t,
                                          toks[:, t:t + 1], cfg)
        np.testing.assert_array_equal(np.asarray(lg_multi[:, t]),
                                      np.asarray(lg), err_msg=f"t={t}")
        np.testing.assert_array_equal(np.asarray(ks[:, :, t]),
                                      np.asarray(kn))
        vk2 = vk2.at[:, rows, pos + t].set(kn)
        vv2 = vv2.at[:, rows, pos + t].set(vn)


def test_draft_propose_consistent_with_sequential(qat_model):
    """The jitted draft loop's proposals are the draft tier's own greedy
    chain (and its KV covers k+1 positions for the lockstep commit)."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    sxp = ST.stack(sp)
    B, k, Sv = 2, 3, 16
    L, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    rng = np.random.default_rng(1)
    vk = jnp.asarray(rng.standard_normal((L, B, Sv, KV, dh)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((L, B, Sv, KV, dh)), jnp.float32)
    pos = jnp.asarray([2, 6], jnp.int32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    props, ks, vs = SP.draft_propose(sxp, vk, vv, pos, toks, cfg, k)
    assert props.shape == (B, k) and ks.shape == (L, B, k + 1, KV, dh)
    rows = jnp.arange(B)
    vk2, vv2, tok = vk, vv, toks
    for t in range(k):
        lg, kn, vn = ST.decode_step_paged(sxp, vk2, vv2, pos + t, tok, cfg)
        vk2 = vk2.at[:, rows, pos + t].set(kn)
        vv2 = vv2.at[:, rows, pos + t].set(vn)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        np.testing.assert_array_equal(np.asarray(props[:, t]),
                                      np.asarray(tok[:, 0]), err_msg=f"t={t}")


# ---------------------------------------------------------------------------
# Draft tier construction
# ---------------------------------------------------------------------------


def test_draft_serving_is_sparser_same_tile(qat_model):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.4, tile=(16, 16))
    draft = SP.draft_serving(cfg, sp, 0.9)
    t_dep, d_dep = sp.deployed(), draft.deployed()
    assert set(t_dep) == set(d_dep)
    for name in t_dep:
        assert d_dep[name].tile == t_dep[name].tile, name
        assert d_dep[name].density <= t_dep[name].density + 1e-9, name
    assert (sum(d.density for d in d_dep.values())
            < 0.6 * sum(d.density for d in t_dep.values()))
    # dense leaves are shared BY REFERENCE (two-tier artifacts dedupe them)
    assert draft.embed is sp.embed
    assert draft.layers[0]["ln1"] is sp.layers[0]["ln1"]


def test_draft_surviving_blocks_bit_identical(qat_model):
    """Re-pruning only drops blocks: a draft block that survives must carry
    the target's exact int8 levels (the draft differs in WHICH blocks
    exist, never in their values)."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.3, tile=(16, 16))
    draft = SP.draft_serving(cfg, sp, 0.8)
    dw_t = sp.layers[0]["wq"]
    dw_d = draft.layers[0]["wq"]
    pt, pd = dw_t.packed[0], dw_d.packed[0]
    bt = np.asarray(pt["blocks"])
    rt = np.asarray(pt["row_idx"])
    nt = np.asarray(pt["nnz"])
    bd = np.asarray(pd["blocks"])
    rd = np.asarray(pd["row_idx"])
    nd = np.asarray(pd["nnz"])
    assert nd.sum() < nt.sum()  # strictly sparser
    for j in range(bd.shape[0]):
        tmap = {int(rt[j, s]): bt[j, s] for s in range(int(nt[j]))}
        for s in range(int(nd[j])):
            row = int(rd[j, s])
            assert row in tmap, f"draft kept a block the target pruned ({j},{row})"
            np.testing.assert_array_equal(bd[j, s], tmap[row])


def test_draft_of_dense_target_is_packed(qat_model):
    cfg, params = qat_model
    sp = DP.from_params(cfg, params)
    draft = SP.draft_serving(cfg, sp, 0.85, tile=(16, 16))
    assert len(draft.deployed()) > 0
    tiles = {dw.tile for dw in draft.deployed().values()}
    assert len(tiles) == 1  # uniform: the draft must stack
    ST.stack(draft)


def test_spec_params_validation(qat_model):
    cfg, params = qat_model
    sp16 = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    sp32 = DP.compress(cfg, params, target_sparsity=0.5, tile=(32, 32))
    with pytest.raises(ValueError, match="tile"):
        SP.SpecParams.build(sp16, sp32)
    with pytest.raises(ValueError, match="k must"):
        SpecConfig(k=0)
    with pytest.raises(ValueError, match="draft_sparsity"):
        SpecConfig(draft_sparsity=1.0)


def test_spec_server_guards(qat_model):
    cfg, params = qat_model
    sp = DP.from_params(cfg, params)
    with pytest.raises(ValueError, match="draft"):
        BatchServer(cfg, sp, engine="spec")
    draft = SP.draft_serving(cfg, sp, 0.85, tile=(16, 16))
    with pytest.raises(ValueError, match="greedy"):
        BatchServer(cfg, sp, ServeConfig(temperature=0.7), engine="spec",
                    draft=draft)


# ---------------------------------------------------------------------------
# Two-tier KV pool
# ---------------------------------------------------------------------------


def test_paged_kv_tiers_share_layout(qat_model):
    from repro.serve import PagedKVCache
    cfg, _ = qat_model
    kv = PagedKVCache(cfg, n_slots=2, n_blocks=8, block_size=4, tiers=2)
    L, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    rng = np.random.default_rng(0)
    kv.ensure(0, 6)
    run_t = rng.standard_normal((L, 3, KV, dh)).astype(np.float32)
    run_d = rng.standard_normal((L, 3, KV, dh)).astype(np.float32)
    kv.write_run(0, 2, run_t, run_t, tier=0)
    kv.write_run(0, 2, run_d, run_d, tier=1)
    gk_t, _ = kv.gather(2, tier=0)
    gk_d, _ = kv.gather(2, tier=1)
    np.testing.assert_array_equal(np.asarray(gk_t[:, 0, 2:5]), run_t)
    np.testing.assert_array_equal(np.asarray(gk_d[:, 0, 2:5]), run_d)
    # one free list, one table: freeing releases both tiers' storage
    assert kv.blocks_in_use == 2
    kv.free_slot(0)
    assert kv.blocks_in_use == 0


def test_write_run_partial_commit_is_rollback(qat_model):
    """Only the accepted prefix reaches the pool; positions past it keep
    their prior content (the rejected suffix was never committed)."""
    from repro.serve import PagedKVCache
    cfg, _ = qat_model
    kv = PagedKVCache(cfg, n_slots=1, n_blocks=4, block_size=4, tiers=2)
    L, KV, dh = cfg.n_layers, cfg.n_kv_heads_eff, cfg.dh
    kv.ensure(0, 8)
    before = kv.pool_k[1].copy()
    run = np.ones((L, 2, KV, dh), np.float32)
    kv.write_run(0, 1, run, run, tier=1)  # accept 2 of a longer candidate
    gk, _ = kv.gather(2, tier=1)
    np.testing.assert_array_equal(np.asarray(gk[:, 0, 1:3]), run)
    # position 3 onward untouched
    np.testing.assert_array_equal(np.asarray(gk[:, 0, 3:]),
                                  np.zeros((L, 5, KV, dh), np.float32))
    # tier 0 untouched entirely
    np.testing.assert_array_equal(kv.pool_k[0], before)


# ---------------------------------------------------------------------------
# Two-tier artifacts
# ---------------------------------------------------------------------------


def test_two_tier_artifact_roundtrip(qat_model, tmp_path):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    draft = SP.draft_serving(cfg, sp, 0.85)
    d = DP.save_artifact(str(tmp_path / "two"), sp, cfg, draft=draft,
                         extra={"draft_sparsity": 0.85})
    sp2, meta = DP.load_artifact(str(tmp_path / "two"))
    draft2, _ = DP.load_artifact(str(tmp_path / "two"), tier="draft")
    assert meta["two_tier"] is True and meta["draft_sparsity"] == 0.85
    bcfg = BatchConfig(**_BCFG)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="spec",
                       draft=draft,
                       spec=SpecConfig(k=3, draft_sparsity=0.85)
                       ).run(_trace(cfg))
    got = BatchServer(cfg, sp2, ServeConfig(), bcfg, engine="spec",
                      draft=draft2,
                      spec=SpecConfig(k=3, draft_sparsity=0.85)
                      ).run(_trace(cfg))
    for r in _trace(cfg):
        np.testing.assert_array_equal(got.outputs[r.rid], want.outputs[r.rid])


def test_two_tier_artifact_dedupes_shared_leaves(qat_model, tmp_path):
    """Dense leaves the draft shares by reference with the target must be
    stored ONCE (checkpoint leaf dedup)."""
    import json as _json, os as _os
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    draft = SP.draft_serving(cfg, sp, 0.85)
    d1 = DP.save_artifact(str(tmp_path / "single"), sp, cfg)
    d2 = DP.save_artifact(str(tmp_path / "two"), sp, cfg, draft=draft)

    def n_arrays(d):
        with open(_os.path.join(d, "manifest.json")) as f:
            return _json.load(f)["n_arrays"]

    # the two-tier artifact adds ONLY the draft's packed arrays, not a
    # second copy of embed/norm/head leaves
    n_shared = sum(1 for p in sp.layers for k, v in p.items()
                   if not hasattr(v, "packed")) + 1  # + embed
    assert n_arrays(d2) < 2 * n_arrays(d1) - n_shared + 1


def test_single_tier_artifact_has_no_draft(qat_model, tmp_path):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    DP.save_artifact(str(tmp_path / "one"), sp, cfg)
    with pytest.raises(ValueError, match="draft"):
        DP.load_artifact(str(tmp_path / "one"), tier="draft")


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def test_expected_spec_tokens():
    assert PM.expected_spec_tokens(4, 0.0) == pytest.approx(1.0)
    assert PM.expected_spec_tokens(4, 1.0) == pytest.approx(5.0)
    # monotone in acceptance
    vals = [PM.expected_spec_tokens(4, a) for a in (0.1, 0.4, 0.7, 0.95)]
    assert all(b > a for a, b in zip(vals, vals[1:]))


def test_speculative_summary_tradeoff():
    # a free draft with perfect acceptance multiplies throughput by ~k+1
    s = PM.speculative_summary(0.0, 100.0, 4, 1.0)
    assert s["tokens_per_kcycle"] == pytest.approx(50.0)
    # zero acceptance with a costly draft is strictly worse than target-only
    s0 = PM.speculative_summary(100.0, 100.0, 4, 0.0)
    assert s0["tokens_per_round"] == pytest.approx(1.0)
    assert s0["cycles_per_round"] > 100.0


def test_search_spec_picks_simulated_best(qat_model):
    from repro.sched import search_spec
    cfg, _ = qat_model
    res = search_spec(cfg, target_sparsity=0.6,
                      draft_sparsities=(0.8, 0.9), ks=(2, 4),
                      keeps=(0.5,))
    # (2 reprune sparsities + 1 layerskip keep) x 2 ks
    assert len(res.table) == 6
    assert {r["family"] for r in res.table} == {"reprune", "layerskip"}
    best = max(res.table, key=lambda r: r["tokens_per_kcycle"])
    assert res.best == best
    assert res.decision["verdict"] in ("spec", "declined")
    for row in res.table:
        assert row["cycles_per_round"] > 0
        assert 1.0 <= row["tokens_per_round"] <= row["k"] + 1
        # layerskip rounds run k draft steps, reprune k+1
        assert row["draft_steps"] == \
            (row["k"] if row["family"] == "layerskip" else row["k"] + 1)


# ---------------------------------------------------------------------------
# Multi-token attention building block (T>1 generalization)
# ---------------------------------------------------------------------------


def test_decode_attention_multi_t_gt_1_matches_chained(qat_model):
    from repro.models import layers as L
    cfg, params = qat_model
    p = jax.tree.map(lambda a: a[0], params["layers"])
    rng = np.random.default_rng(3)
    B, T, Sv, KV, dh = 2, 3, 12, cfg.n_kv_heads_eff, cfg.dh
    x = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, Sv, KV, dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, Sv, KV, dh)), jnp.float32)
    pos = jnp.asarray([2, 5], jnp.int32)
    y, kn, vn = L.decode_attention_multi(p, x, kc, vc, pos, cfg)
    assert y.shape == (B, T, cfg.d_model) and kn.shape == (B, T, KV, dh)
    rows = jnp.arange(B)
    kc2, vc2 = kc, vc
    for t in range(T):
        yt, kt, vt = L.decode_attention_multi(p, x[:, t:t + 1], kc2, vc2,
                                              pos + t, cfg)
        np.testing.assert_array_equal(np.asarray(y[:, t]),
                                      np.asarray(yt[:, 0]), err_msg=f"t={t}")
        kc2 = kc2.at[rows, pos + t].set(kt[:, 0])
        vc2 = vc2.at[rows, pos + t].set(vt[:, 0])


# ---------------------------------------------------------------------------
# Layer-skip draft family: masks, importance, bit-exactness
# ---------------------------------------------------------------------------


def test_decode_step_masked_all_on_matches_paged(qat_model):
    """With every sublayer on, the masked step IS decode_step_paged -
    bit for bit (the identity the layer-skip draft degrades from)."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    sxp = ST.stack(sp)
    rng = np.random.default_rng(11)
    B, Sv, KV, dh = 2, 10, cfg.n_kv_heads_eff, cfg.dh
    vk = jnp.asarray(rng.standard_normal((cfg.n_layers, B, Sv, KV, dh)),
                     jnp.float32)
    vv = jnp.asarray(rng.standard_normal((cfg.n_layers, B, Sv, KV, dh)),
                     jnp.float32)
    pos = jnp.asarray([3, 6], jnp.int32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    ones = jnp.ones(cfg.n_layers, jnp.float32)
    want_l, want_k, want_v = ST.decode_step_paged(sxp, vk, vv, pos, toks, cfg)
    got_l, got_k, got_v = ST.decode_step_masked(sxp, vk, vv, pos, toks, cfg,
                                               ones, ones)
    np.testing.assert_array_equal(np.asarray(want_l), np.asarray(got_l))
    np.testing.assert_array_equal(np.asarray(want_k), np.asarray(got_k))
    np.testing.assert_array_equal(np.asarray(want_v), np.asarray(got_v))


def test_layerskip_masks_rank_and_floor():
    L = 4
    # keep=1: everything on
    a_on, m_on = SP.layerskip_masks(L, 1.0)
    assert a_on == (1,) * L and m_on == (1,) * L
    # positional prior drops MLPs front-first, then attentions front-first
    a_on, m_on = SP.layerskip_masks(L, 0.5)
    assert m_on == (0, 0, 0, 0) and a_on == (1, 1, 1, 1)
    assert SP.kept_fraction(a_on, m_on) == 0.5
    # the LAST layer's attention survives even the floor
    a_on, m_on = SP.layerskip_masks(L, 0.0)
    assert a_on[-1] == 1 and sum(a_on) + sum(m_on) == 1
    # nnz importance overrides position: dead units (score 0) go first
    attn_imp = np.array([5.0, 0.0, 7.0, 9.0])
    mlp_imp = np.array([3.0, 0.0, 8.0, 6.0])
    a_on, m_on = SP.layerskip_masks(L, 0.5, importance=(attn_imp, mlp_imp))
    assert a_on[1] == 0 and m_on[1] == 0  # both dead units dropped
    assert m_on[0] == 0 and a_on[0] == 0  # then the cheapest live ones
    assert a_on[3] == 1 and m_on[2] == 1  # most important survive


def test_sublayer_importance_detects_dead_sublayers(qat_model):
    """On the aggressively-compressed smoke packing the nnz ranking must
    score pruning-killed sublayers exactly 0 (skipping them is free)."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.6,
                     schedule=DP.default_schedule(cfg))
    sxp = ST.stack(sp)
    attn, mlp = SP.sublayer_importance(sxp)
    assert attn.shape == (cfg.n_layers,) and mlp.shape == (cfg.n_layers,)
    assert np.all(attn >= 0) and np.all(mlp >= 0)
    # this packing's wk/wv lose every block -> both attentions are dead
    assert np.all(attn == 0)
    # masks at keep=0.5 must then shed ONLY dead/cheapest units
    a_on, m_on = SP.layerskip_masks(cfg.n_layers, 0.5,
                                    importance=(attn, mlp))
    dropped = [(k, li) for k, on in (("attn", a_on), ("mlp", m_on))
               for li, v in enumerate(on) if v == 0]
    imp = {"attn": attn, "mlp": mlp}
    kept_scores = [imp[k][li] for k, on in (("attn", a_on), ("mlp", m_on))
                   for li, v in enumerate(on) if v == 1]
    assert all(imp[k][li] <= min(kept_scores) for k, li in dropped)


def test_layerskip_spec_matches_scan(qat_model):
    """Greedy bit-exactness for the layerskip family: no draft packing,
    the draft runs a sublayer subset of the TARGET envelope."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.6,
                     schedule=DP.default_schedule(cfg))
    bcfg = BatchConfig(**_BCFG)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="scan"
                       ).run(_trace(cfg))
    srv = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="spec",
                      spec=SpecConfig(k=3, draft="layerskip", keep=0.5))
    rep = srv.run(_trace(cfg))
    for r in _trace(cfg):
        np.testing.assert_array_equal(rep.outputs[r.rid],
                                      want.outputs[r.rid], err_msg=r.rid)
    st = rep.spec
    assert st["family"] == "layerskip" and st["keep"] == 0.5
    # the nnz masks shed the dead sublayers -> the draft actually agrees
    assert st["acceptance_rate"] >= 0.3
    assert sum(st["accepted_len_hist"]) == st["slot_rounds"]


def test_layerskip_spec_matches_scan_macro2():
    """Layerskip spec decode over a macro-sharded TARGET envelope (the
    draft shares it - nothing extra to shard) reproduces single-device
    target-only tokens at mesh macro=2 (subprocess: forced host devices
    must exist before jax imports)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = " ".join(
        ([env["XLA_FLAGS"]] if env.get("XLA_FLAGS") else [])
        + ["--xla_force_host_platform_device_count=8"])
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = """
import numpy as np, jax
from repro.models import registry
from repro.serve import BatchConfig, BatchServer, ServeConfig, Request, SpecConfig
from repro.serve import deployed as DP
from repro.launch.shardings import macro_mesh

cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
def trace():
    rng = np.random.default_rng(7)
    return [Request(f"r{i}", rng.integers(0, cfg.vocab, int(rng.integers(2, 10))),
                    int(rng.integers(1, 7))) for i in range(3)]
sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
want = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="scan").run(trace())
mesh = macro_mesh(2)
srv = BatchServer(cfg, DP.shard(sp, mesh), ServeConfig(), bcfg, mesh=mesh,
                  engine="spec", spec=SpecConfig(k=3, draft="layerskip", keep=0.5))
assert any(sw.mesh is not None for sw in srv._params.target.packed.values()), \\
    "no target envelope actually sharded"
assert srv._params.draft is None, "layerskip must not carry a draft packing"
rep = srv.run(trace())
for r in trace():
    np.testing.assert_array_equal(rep.outputs[r.rid], want.outputs[r.rid],
                                  err_msg=f"macro=2 {r.rid}")
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


def test_layerskip_server_rejects_draft(qat_model):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    with pytest.raises(ValueError, match="layerskip"):
        BatchServer(cfg, sp, ServeConfig(), BatchConfig(**_BCFG),
                    engine="spec", draft=sp,
                    spec=SpecConfig(k=2, draft="layerskip", keep=0.5))


# ---------------------------------------------------------------------------
# Adaptive k: collapse / recovery state machine
# ---------------------------------------------------------------------------


def test_adaptive_k_collapse_and_recovery():
    ad = SP.AdaptiveK(k_max=4, ewma=0.5, collapse_below=0.2,
                      expand_above=0.6)
    assert ad.k == 4 and ad.acc == pytest.approx(0.6)  # optimistic start
    assert ad.observe(4, 0) == 4          # acc 0.30: in the band, hold
    assert ad.observe(4, 0) == 1          # acc 0.15 < 0.2: COLLAPSE
    assert ad.collapses == 1
    # recovery through the doubling ladder on perfect probe acceptance
    assert ad.observe(1, 1) == 1          # acc 0.575: still below expand
    assert ad.observe(1, 1) == 2          # acc 0.7875 >= 0.6: 1 -> 2
    assert ad.observe(2, 2) == 4          # 2 -> 4 (capped at k_max)
    assert ad.expands == 2 and ad.k == 4
    assert ad.observe(4, 4) == 4          # at k_max: no further expand
    assert ad.expands == 2


def test_adaptive_k_hysteresis_band_holds():
    ad = SP.AdaptiveK(k_max=8, ewma=0.35, collapse_below=0.2,
                      expand_above=0.6)
    ad.observe(8, 0)  # knock acc below expand_above
    k0, c0, e0 = ad.k, ad.collapses, ad.expands
    for _ in range(20):
        assert ad.observe(k0, int(0.4 * k0)) == k0  # borderline slot
    assert ad.collapses == c0 and ad.expands == e0


def test_adaptive_k_collapses_in_server(qat_model):
    """A mismatched layerskip draft (positional masks on a packing whose
    live compute is elsewhere) must drive per-slot k down; greedy tokens
    stay bit-identical through every k trajectory."""
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.6,
                     schedule=DP.default_schedule(cfg))
    bcfg = BatchConfig(**_BCFG)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="scan"
                       ).run(_trace(cfg))
    srv = BatchServer(cfg, sp, ServeConfig(), bcfg, engine="spec",
                      spec=SpecConfig(k=4, draft="layerskip", keep=0.25))
    # keep=0.25 with nnz masks keeps only the live layer-0 MLP path's
    # cheapest units - force the POSITIONAL prior instead so the draft
    # mispredicts and the tracker must collapse
    import jax.numpy as jnp2
    a_on, m_on = SP.layerskip_masks(cfg.n_layers, 0.25)
    srv.spec_masks = (a_on, m_on)
    srv._attn_on = jnp2.asarray(a_on, jnp2.float32)
    srv._mlp_on = jnp2.asarray(m_on, jnp2.float32)
    rep = srv.run(_trace(cfg))
    for r in _trace(cfg):
        np.testing.assert_array_equal(rep.outputs[r.rid],
                                      want.outputs[r.rid], err_msg=r.rid)


# ---------------------------------------------------------------------------
# Calibration: measured rows -> fitted prior -> search decision
# ---------------------------------------------------------------------------


def test_calibration_roundtrip_and_fit(qat_model):
    from repro.sched.search import SpecCalibration, search_spec
    cfg, _ = qat_model
    cal = SpecCalibration()
    cal.add(cfg.name, "layerskip", 0.5, 0.7, weight=120.0)
    cal.add(cfg.name, "layerskip", 0.25, 0.9, weight=80.0)
    cal2 = SpecCalibration.from_json(cal.to_json())
    m = cal2.accept_model(cfg.name, "layerskip")
    # exact re-queries reproduce the measurements (the other measured
    # point keeps a sub-percent inverse-distance share)
    assert m(0.5) == pytest.approx(0.7, abs=5e-3)
    assert m(0.25) == pytest.approx(0.9, abs=5e-3)
    # in-between gaps interpolate inside the measured bracket
    assert 0.7 < m(0.4) < 0.9
    # the fitted prior prices the search: the winning row's expected
    # tokens/round must be the cost model's at the fitted acceptance
    res = search_spec(cfg, target_sparsity=0.6, calibration=cal2,
                      arch=cfg.name, ks=(2, 4), draft_sparsities=(0.85,),
                      keeps=(0.5, 0.75))
    for row in res.table:
        if row["accept_source"] == "calibrated":
            want = PM.expected_spec_tokens(row["k"], row["accept"])
            # both row fields are rounded to 4 decimals in the summary
            assert row["tokens_per_round"] == pytest.approx(want, abs=1e-3)
    assert any(r["accept_source"] == "calibrated" for r in res.table)


def test_calibration_rejects_malformed():
    from repro.sched.search import SpecCalibration
    with pytest.raises(ValueError):
        SpecCalibration.from_json({"schema": 99, "rows": []})
    with pytest.raises(ValueError):
        SpecCalibration.from_json({"schema": 1, "rows": [{"arch": "a"}]})
    cal = SpecCalibration()
    with pytest.raises(ValueError):
        cal.add("a", "layerskip", 0.5, 1.5)  # accept out of range
    with pytest.raises(ValueError):
        cal.add("a", "layerskip", 0.5, 0.5, weight=0.0)


def test_calibration_trust_decays_to_prior(qat_model):
    from repro.sched.search import SpecCalibration
    cfg, _ = qat_model
    cal = SpecCalibration()
    cal.add(cfg.name, "layerskip", 0.5, 0.95, weight=100.0)
    prior = lambda g: max(0.0, 1.0 - g)
    m = cal.accept_model(cfg.name, "layerskip", prior=prior)
    # at the measured gap: the measurement
    assert m(0.5) == pytest.approx(0.95, abs=1e-3)
    # far from all data the answer falls back TOWARD the prior instead of
    # flat-extrapolating the single measurement across the knob axis
    far = m(0.9)
    assert prior(0.9) < far < 0.95
    assert far - prior(0.9) < 0.95 - prior(0.9)


def test_search_spec_declines_when_calibrated_dead(qat_model):
    """Measured-dead acceptance across both families must produce the
    'declined' verdict - the auto policy never ships a modeled loss."""
    from repro.sched.search import SpecCalibration, search_spec
    cfg, _ = qat_model
    cal = SpecCalibration()
    for fam, gaps in (("reprune", (0.15, 0.25, 0.35)),
                      ("layerskip", (0.25, 0.5, 0.75))):
        for g in gaps:
            cal.add(cfg.name, fam, g, 0.0, weight=500.0)
    res = search_spec(cfg, target_sparsity=0.6, calibration=cal,
                      arch=cfg.name)
    d = res.decision
    assert d["verdict"] == "declined" and d["reason"] == "scan wins"
    assert d["accept_source"] == "calibrated"


def test_spec_stats_histogram_and_counters():
    st = SP.SpecStats(k=4, draft_sparsity=0.0, family="layerskip", keep=0.5)
    st.record(n_proposed=4, n_accepted=4, n_emitted=5)
    st.record(n_proposed=4, n_accepted=0, n_emitted=1)
    st.record(n_proposed=1, n_accepted=1, n_emitted=2)  # collapsed round
    j = st.to_json()
    assert j["family"] == "layerskip" and j["keep"] == 0.5
    assert j["proposed"] == 9 and j["accepted"] == 5
    assert j["spec_accepted_tokens"] == 5
    assert j["spec_rejected_tokens"] == 4
    assert j["accepted_len_hist"] == [1, 1, 0, 0, 1]
    assert j["acceptance_rate"] == pytest.approx(5 / 9, abs=1e-3)
