"""Uniform-envelope stacked deployment + compiled (lax.scan) runtime tests.

The contracts under test:

  * envelope honesty: ``stack_deployed`` pads the slot axis but keeps the
    per-layer ``nnz``/``row_idx`` exact, so the layer-indexed stacked kernel
    is BIT-IDENTICAL to the per-layer ``deployed_matmul`` - including
    all-zero layers (nothing survives), fully-dense layers (maximal
    ``nnz_max``: the envelope for everyone else), and truncated layers
    (true counts exceed stored slots - padding must stay inert);
  * runtime honesty: the scan runtime (``serve.stacked`` /
    ``BatchServer(engine="scan")``) reproduces the loop runtime's greedy
    tokens exactly - dense and compressed, single-device and macro-sharded;
  * artifact honesty: ``save_artifact``/``load_artifact`` round-trips the
    packed model (int8 blocks stay int8, mesh never serialized) and the
    booted model serves identical tokens;
  * uniform-tile mode: the search only returns network-feasible tiles and
    the schedule exposes them as one envelope.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import deploy as D
from repro.core.cim_layer import CIMConfig
from repro.core.mapping import pack_bsr
from repro.core.quant import QuantConfig
from repro.core.sparsity import SparsityConfig
from repro.models import registry
from repro.serve import (BatchConfig, BatchServer, Engine, Request,
                         ServeConfig)
from repro.serve import deployed as DP
from repro.serve import stacked as ST
from repro.train import checkpoint as ckpt


def _cim(ts=0.5):
    return CIMConfig(
        quant=QuantConfig(w_bits=8, a_bits=8, group_size=16, a_signed=True),
        sparsity=SparsityConfig(alpha=16, n=16, target_sparsity=ts),
        mode="qat")


def _layer_stack(seed=0, d_in=64, d_out=128, bk=16, bn=16):
    """Four layers spanning the envelope edge cases: no pruning (densest
    layer sets the envelope), paper sparsity, extreme sparsity, all-zero."""
    cim = _cim()
    rng = np.random.default_rng(seed)
    dws, ws = [], []
    for ts in (0.0, 0.5, 0.9, 1.0):
        w = rng.standard_normal((d_in, d_out)).astype(np.float32) * 0.2
        if ts >= 1.0:
            w = np.zeros_like(w)
            ts = 0.5
        ws.append(w)
        dws.append(D.deploy_weight(w, cim, bk=bk, bn=bn, target_sparsity=ts))
    return dws, ws


# ---------------------------------------------------------------------------
# Envelope padding: stacked layer-indexed kernel == per-layer kernel
# ---------------------------------------------------------------------------


def test_stack_deployed_envelope_geometry():
    dws, _ = _layer_stack()
    sw = D.stack_deployed(dws)
    nnz_maxes = [dw.packed[0]["row_idx"].shape[1] for dw in dws]
    assert sw.blocks.shape[:2] == (4, 8)
    assert sw.blocks.shape[2] == max(nnz_maxes)  # padded to the max
    # per-layer counts stay exact - padding is envelope-only
    for i, dw in enumerate(dws):
        np.testing.assert_array_equal(np.asarray(sw.nnz[i]),
                                      np.asarray(dw.packed[0]["nnz"]))
    # padding slots carry zero scales (inert even past a truncated guard)
    for i, nm in enumerate(nnz_maxes):
        if nm < sw.blocks.shape[2]:
            assert float(np.abs(np.asarray(sw.scales[i][:, nm:])).max()) == 0.0
            assert float(np.abs(np.asarray(sw.blocks[i][:, nm:])).max()) == 0.0


def test_stacked_kernel_matches_per_layer_bit_exact():
    dws, _ = _layer_stack()
    sw = D.stack_deployed(dws)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    for i, dw in enumerate(dws):
        want = np.asarray(D.deployed_matmul(x, dw, a_bits=8, interpret=True))
        got = np.asarray(D.stacked_matmul(x, sw, i, a_bits=8, interpret=True))
        np.testing.assert_array_equal(got, want, err_msg=f"layer {i}")


def test_stacked_kernel_under_scan_matches_per_layer():
    """The traced layer index (a scan carry) must hit the same kernel."""
    dws, _ = _layer_stack(seed=3)
    sw = D.stack_deployed(dws)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((4, 64)),
                    jnp.float32)

    def body(c, i):
        return c, D.stacked_matmul(x, sw, i, a_bits=8, interpret=True)

    _, ys = jax.jit(lambda: jax.lax.scan(body, 0, jnp.arange(4)))()
    for i, dw in enumerate(dws):
        want = np.asarray(D.deployed_matmul(x, dw, a_bits=8, interpret=True))
        np.testing.assert_array_equal(np.asarray(ys[i]), want,
                                      err_msg=f"layer {i}")


def test_stacked_all_zero_layer_outputs_zero():
    dws, _ = _layer_stack()
    sw = D.stack_deployed(dws)
    x = jnp.ones((3, 64), jnp.float32)
    out = np.asarray(D.stacked_matmul(x, sw, 3, interpret=True))
    assert np.all(out == 0.0)
    assert int(np.asarray(sw.nnz[3]).sum()) == 0


def test_stacked_truncated_layer_padding_is_inert():
    """A layer packed with nnz_max SMALLER than its true counts (truncation)
    keeps ``nnz`` > stored slots; when the stacked guard walks past the
    stored slots into envelope padding, the zero blocks/scales must
    contribute exactly nothing - parity with the per-layer kernel holds."""
    rng = np.random.default_rng(5)
    levels = rng.integers(-127, 128, (64, 128)).astype(np.int8)
    scale = 1.0 / 2.0 ** 7

    def mk(bsr):
        return D.DeployedWeight([{
            "blocks": jnp.asarray(bsr.blocks),
            "scales": jnp.asarray(np.full(bsr.row_idx.shape, scale,
                                          np.float32)),
            "row_idx": jnp.asarray(bsr.row_idx),
            "nnz": jnp.asarray(bsr.nnz),
            "density": bsr.density,
        }], 64, 128, 8)

    trunc = mk(pack_bsr(levels, 16, 16, nnz_max=2))
    full = mk(pack_bsr(levels, 16, 16))
    assert int(np.asarray(trunc.packed[0]["nnz"]).max()) > 2  # truly truncated
    sw = D.stack_deployed([trunc, full])  # envelope >> truncated slots
    assert sw.blocks.shape[2] > 2
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    for i, dw in enumerate((trunc, full)):
        want = np.asarray(D.deployed_matmul(x, dw, interpret=True))
        got = np.asarray(D.stacked_matmul(x, sw, i, interpret=True))
        np.testing.assert_array_equal(got, want, err_msg=f"layer {i}")


def test_stack_deployed_rejects_mixed_geometry():
    cim = _cim()
    rng = np.random.default_rng(0)
    a = D.deploy_weight(rng.standard_normal((64, 128)).astype(np.float32),
                        cim, bk=16, bn=16, target_sparsity=0.5)
    b = D.deploy_weight(rng.standard_normal((64, 128)).astype(np.float32),
                        cim, bk=32, bn=16, target_sparsity=0.5)
    with pytest.raises(ValueError, match="uniform"):
        D.stack_deployed([a, b])
    c = D.deploy_weight(rng.standard_normal((64, 64)).astype(np.float32),
                        cim, bk=16, bn=16, target_sparsity=0.5)
    with pytest.raises(ValueError, match="geometry"):
        D.stack_deployed([a, c])


def test_stacked_weight_pytree_roundtrip():
    dws, _ = _layer_stack()
    sw = D.stack_deployed(dws)
    leaves, treedef = jax.tree.flatten(sw)
    sw2 = jax.tree.unflatten(treedef, leaves)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((3, 64)),
                    jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(D.stacked_matmul(x, sw, 1, interpret=True)),
        np.asarray(D.stacked_matmul(x, sw2, 1, interpret=True)))


def test_stacked_parity_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    cim = _cim()

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 4),
           st.sampled_from([(32, 32), (64, 32), (32, 64)]))
    def prop(seed, n_layers, shape):
        d_in, d_out = shape
        rng = np.random.default_rng(seed)
        dws = []
        for _ in range(n_layers):
            w = rng.standard_normal((d_in, d_out)).astype(np.float32)
            ts = float(rng.uniform(0.0, 0.95))
            dws.append(D.deploy_weight(w, cim, bk=16, bn=16,
                                       target_sparsity=ts))
        sw = D.stack_deployed(dws)
        x = jnp.asarray(rng.standard_normal((3, d_in)), jnp.float32)
        for i, dw in enumerate(dws):
            np.testing.assert_array_equal(
                np.asarray(D.stacked_matmul(x, sw, i, a_bits=8,
                                            interpret=True)),
                np.asarray(D.deployed_matmul(x, dw, a_bits=8,
                                             interpret=True)))

    prop()


# ---------------------------------------------------------------------------
# Retrace bucketing (deployed_matmul row tiles)
# ---------------------------------------------------------------------------


def test_bm_for_rows_bucket_ladder():
    assert [D.bm_for_rows(n) for n in (1, 7, 8)] == [8, 8, 8]
    assert [D.bm_for_rows(n) for n in (9, 16)] == [16, 16]
    assert D.bm_for_rows(17) == 32
    assert D.bm_for_rows(100) == 128
    assert D.bm_for_rows(5000) == 128  # capped
    # admission growing the active batch 1..8 shares ONE bucket
    assert len({D.bm_for_rows(n) for n in range(1, 9)}) == 1


def test_deployed_matmul_same_result_across_buckets():
    dws, ws = _layer_stack()
    rng = np.random.default_rng(9)
    x12 = jnp.asarray(rng.standard_normal((12, 64)), jnp.float32)
    # rows 12 pads to a 16-bucket; each row's result must equal the same
    # row computed alone (8-bucket) - bucketing never changes numerics
    full = np.asarray(D.deployed_matmul(x12, dws[1], a_bits=8, interpret=True))
    one = np.asarray(D.deployed_matmul(x12[:1], dws[1], a_bits=8,
                                       interpret=True))
    np.testing.assert_array_equal(full[:1], one)


# ---------------------------------------------------------------------------
# Scan runtime == loop runtime (tokens bit-exact)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qat_model():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _trace(cfg, n=5, seed=7, max_prompt=12, max_new=7):
    rng = np.random.default_rng(seed)
    return [Request(f"r{i}",
                    rng.integers(0, cfg.vocab, int(rng.integers(2, max_prompt))),
                    int(rng.integers(1, max_new))) for i in range(n)]


@pytest.mark.parametrize("ts", [0.0, 0.5])
def test_scan_batch_server_matches_loop_compressed(qat_model, ts):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=ts, tile=(16, 16))
    bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg).run(_trace(cfg))
    got = BatchServer(cfg, sp, ServeConfig(), bcfg,
                      engine="scan").run(_trace(cfg))
    for r in _trace(cfg):
        np.testing.assert_array_equal(got.outputs[r.rid], want.outputs[r.rid],
                                      err_msg=f"ts={ts} {r.rid}")


def test_scan_batch_server_matches_loop_dense(qat_model):
    cfg, params = qat_model
    sp = DP.from_params(cfg, params)
    bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg).run(_trace(cfg, seed=11))
    got = BatchServer(cfg, sp, ServeConfig(), bcfg,
                      engine="scan").run(_trace(cfg, seed=11))
    for r in _trace(cfg, seed=11):
        np.testing.assert_array_equal(got.outputs[r.rid], want.outputs[r.rid])


def test_scan_engine_matches_loop_engine(qat_model):
    cfg, params = qat_model
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(4).integers(0, cfg.vocab, (2, 7)), jnp.int32)}
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    want = Engine(cfg, sp, ServeConfig(max_new_tokens=5),
                  fns=DP.model_fns(cfg)).generate(batch)
    got = Engine(cfg, ST.stack(sp), ServeConfig(max_new_tokens=5),
                 fns=ST.model_fns(cfg)).generate(batch)
    np.testing.assert_array_equal(got, want)


def test_stack_validates_mixed_packing(qat_model):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    sp.layers[1]["wq"] = jnp.zeros((cfg.d_model,
                                    cfg.n_heads_eff * cfg.dh), jnp.float32)
    with pytest.raises(ValueError, match="packed in"):
        ST.stack(sp)


def test_server_rejects_unknown_engine(qat_model):
    cfg, params = qat_model
    with pytest.raises(ValueError, match="engine"):
        BatchServer(cfg, DP.from_params(cfg, params), engine="vliw")


def test_scan_matches_loop_macro_sharded():
    """Acceptance: the scan runtime over a macro-sharded uniform envelope
    reproduces the single-device loop runtime's tokens at macro=2 and 4
    (subprocess: forced host devices must exist before jax imports)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = " ".join(
        ([env["XLA_FLAGS"]] if env.get("XLA_FLAGS") else [])
        + ["--xla_force_host_platform_device_count=8"])
    env["PYTHONPATH"] = os.path.join(repo, "src")
    code = """
import numpy as np, jax
from repro.models import registry
from repro.serve import BatchConfig, BatchServer, ServeConfig, Request
from repro.serve import deployed as DP
from repro.launch.shardings import macro_mesh

cfg = registry.get_smoke_config("yi-6b", dtype="float32", cim_mode="qat")
params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
def trace():
    rng = np.random.default_rng(7)
    return [Request(f"r{i}", rng.integers(0, cfg.vocab, int(rng.integers(2, 12))),
                    int(rng.integers(1, 7))) for i in range(4)]
sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
want = BatchServer(cfg, sp, ServeConfig(), bcfg).run(trace())
for n in (2, 4):
    mesh = macro_mesh(n)
    sps = DP.shard(sp, mesh)
    srv = BatchServer(cfg, sps, ServeConfig(), bcfg, mesh=mesh, engine="scan")
    assert any(sw.mesh is not None for sw in srv._params.packed.values()), \\
        "no envelope actually sharded"
    rep = srv.run(trace())
    for r in trace():
        np.testing.assert_array_equal(rep.outputs[r.rid], want.outputs[r.rid],
                                      err_msg=f"macro={n} {r.rid}")
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=repo, timeout=420)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Offline artifacts: pack once, boot bit-identically
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrips_deployed_and_stacked(tmp_path):
    dws, _ = _layer_stack()
    sw = D.stack_deployed(dws)
    tree = {"dw": dws[1], "sw": sw, "raw": jnp.arange(6, dtype=jnp.int8)}
    ckpt.save_pytree(str(tmp_path / "c"), tree, extra={"k": 1})
    got, manifest = ckpt.load_pytree(str(tmp_path / "c"))
    assert manifest["extra"] == {"k": 1}
    assert isinstance(got["dw"], D.DeployedWeight)
    assert isinstance(got["sw"], D.StackedWeight)
    assert got["sw"].mesh is None and got["dw"].mesh is None
    # int8 leaves round-trip as int8 - no float detour
    assert got["raw"].dtype == jnp.int8
    assert np.asarray(got["sw"].blocks).dtype == np.int8
    np.testing.assert_array_equal(np.asarray(got["sw"].blocks),
                                  np.asarray(sw.blocks))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((3, 64)),
                    jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(D.stacked_matmul(x, got["sw"], 2, interpret=True)),
        np.asarray(D.stacked_matmul(x, sw, 2, interpret=True)))
    np.testing.assert_array_equal(
        np.asarray(D.deployed_matmul(x, got["dw"], interpret=True)),
        np.asarray(D.deployed_matmul(x, dws[1], interpret=True)))


def test_checkpoint_refuses_sharded_serialization():
    from jax.sharding import Mesh
    dws, _ = _layer_stack()
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("macro",))
    dw = dws[1]
    dw_sharded = D.DeployedWeight(dw.packed, dw.d_in, dw.d_out, dw.bits,
                                  mesh=mesh)
    with pytest.raises(ValueError, match="mesh"):
        ckpt.save_pytree("/tmp/never-written", dw_sharded)


def test_artifact_roundtrip_serves_identically(qat_model, tmp_path):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16))
    DP.save_artifact(str(tmp_path / "art"), sp, cfg, extra={"note": "t"})
    sp2, meta = DP.load_artifact(str(tmp_path / "art"))
    assert meta["arch"] == cfg.name and meta["note"] == "t"
    bcfg = BatchConfig(n_slots=2, block_size=4, n_blocks=24)
    want = BatchServer(cfg, sp, ServeConfig(), bcfg).run(_trace(cfg))
    for engine in ("loop", "scan"):
        rep = BatchServer(cfg, sp2, ServeConfig(), bcfg,
                          engine=engine).run(_trace(cfg))
        for r in _trace(cfg):
            np.testing.assert_array_equal(rep.outputs[r.rid],
                                          want.outputs[r.rid],
                                          err_msg=f"{engine} {r.rid}")


def test_artifact_rebuilds_tied_head(tmp_path):
    """A tied-embeddings model's head_t is derived, not stored - the loader
    must rebuild it."""
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(1))
    params.pop("head", None)  # force the tied path
    sp = DP.from_params(cfg, params)
    assert sp.head is None and sp.head_t is not None
    d = DP.save_artifact(str(tmp_path / "tied"), sp, cfg)
    import json as _json, os as _os
    with open(_os.path.join(d, "manifest.json")) as f:
        n_arrays = _json.load(f)["n_arrays"]
    sp2, _ = DP.load_artifact(str(tmp_path / "tied"))
    assert sp2.head_t is not None
    np.testing.assert_array_equal(np.asarray(sp2.head_t),
                                  np.asarray(sp.head_t))
    # head_t was NOT serialized (derived data stays out of the artifact)
    flat_with_head = len(jax.tree.leaves(sp))
    assert n_arrays == flat_with_head - 1


# ---------------------------------------------------------------------------
# Uniform-tile mode
# ---------------------------------------------------------------------------


def test_uniform_search_only_feasible_tiles(qat_model):
    from repro.sched import lm_graph
    from repro.sched.search import (search_mapping, tile_divides_graph,
                                    uniform_tile_candidates)
    cfg, _ = qat_model
    graph = lm_graph(cfg, seq_len=32)
    res = search_mapping(graph, groups=(16, 48), alphas=(16, 48),
                        uniform=True)
    for row in res.table:
        assert tile_divides_graph(graph, row.candidate.group,
                                  row.candidate.alpha)
    cands = uniform_tile_candidates(graph, (16, 48), (16, 48))
    assert all(tile_divides_graph(graph, c.group, c.alpha) for c in cands)
    # 48 divides neither d_model=64 nor d_ff=128
    assert not tile_divides_graph(graph, 48, 16)


def test_schedule_uniform_tile_property(qat_model):
    cfg, _ = qat_model
    sched = DP.default_schedule(cfg, uniform=True)
    g, a = sched.uniform_tile
    assert all((s.group, s.alpha) == (g, a) for s in sched.layers)


def test_compress_uniform_packs_one_tile(qat_model):
    cfg, params = qat_model
    sp = DP.compress(cfg, params, target_sparsity=0.5, tile=(16, 16),
                     uniform=True)
    tiles = {dw.tile for dw in sp.deployed().values()}
    assert len(tiles) == 1, tiles
    net_tile = tiles.pop()
    sxp = ST.stack(sp)  # the uniform envelope must be stackable
    assert sxp.packed
    assert all(sw.tile == net_tile for sw in sxp.packed.values())


# ---------------------------------------------------------------------------
# Tied-head precompute
# ---------------------------------------------------------------------------


def test_head_t_precomputed_once():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    sp = DP.from_params(cfg, params)
    assert sp.head is not None and sp.head_t is None  # untied: no cache
    params.pop("head")
    spt = DP.from_params(cfg, params)
    assert spt.head is None
    np.testing.assert_array_equal(np.asarray(spt.head_t),
                                  np.asarray(params["embed"]).T)
    assert DP._head(spt) is spt.head_t  # the SAME array every call
