"""Observability layer tests.

Contracts under test:

  * the tracer is thread-safe, gives each thread its own track, and nested
    spans are properly contained in their parent's [ts, ts+dur] window;
  * the exported JSON is valid Chrome trace-event format (golden schema
    check via ``validate_chrome_trace`` - the same validator CI runs on
    emitted files);
  * the disabled path allocates nothing: NULL sinks hand back shared
    singleton no-op objects;
  * histogram percentiles interpolate correctly and snapshots validate;
  * the per-phase cycle split in ``perf_model`` sums back to the exact
    ``_layer_cycles`` totals (the gap comparator's prediction side);
  * an instrumented ``BatchServer`` emits the step-phase spans, request
    lifecycle tracks, queue-wait split and kernel dispatch table - and its
    tokens are bit-identical to an un-instrumented server's.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.core import perf_model as PM
from repro.kernels.timing import DispatchTimer
from repro.models import registry
from repro.obs import (MetricsRegistry, NULL_METRICS, NULL_TRACER, Tracer,
                       gap, history, phase_scope, trace as trace_mod,
                       validate_chrome_trace, validate_metrics_snapshot)
from repro.serve import BatchConfig, BatchServer, Request, ServeConfig
from repro.serve import deployed as DP


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_span_nesting_contained():
    tr = Tracer()
    with tr.span("outer", step=1):
        with tr.span("inner"):
            tr.instant("mark", note="x")
    ev = {e["name"]: e for e in tr.to_chrome()["traceEvents"]
          if e["ph"] in ("X", "i")}
    outer, inner = ev["outer"], ev["inner"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert outer["args"]["step"] == 1
    assert ev["mark"]["ph"] == "i"


def test_tracer_thread_safety():
    tr = Tracer()
    n_threads, n_spans = 8, 50

    def work(t):
        for i in range(n_spans):
            with tr.span(f"t{t}.s{i}"):
                pass

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    events = [e for e in tr.to_chrome()["traceEvents"] if e["ph"] == "X"]
    assert len(events) == n_threads * n_spans
    # each worker thread recorded on its own track
    assert len({e["tid"] for e in events}) == n_threads


def test_chrome_trace_schema_golden(tmp_path):
    tr = Tracer()
    with tr.span("phase", k=2):
        tr.instant("tick")
    tr.counter("pool", used=3, free=5)
    tr.complete("retro", 0.001, 0.002, track="queue", rid="r0")
    obj = tr.to_chrome()
    # golden structural facts of the trace-event format
    assert obj["displayTimeUnit"] == "ms"
    phs = {e["ph"] for e in obj["traceEvents"]}
    assert phs == {"M", "X", "i", "C"}
    for e in obj["traceEvents"]:
        assert {"name", "ph", "pid", "tid"} <= set(e)
    n = validate_chrome_trace(obj)
    assert n == len(obj["traceEvents"])
    p = tmp_path / "trace.json"
    tr.save(str(p))
    from repro.obs import validate_chrome_trace_file
    assert validate_chrome_trace_file(str(p)) == n
    # named track got a thread_name metadata record
    names = [e["args"]["name"] for e in obj["traceEvents"] if e["ph"] == "M"]
    assert "queue" in names


def test_validator_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    with pytest.raises(ValueError):
        validate_chrome_trace({"no": "events"})
    with pytest.raises(ValueError):
        validate_metrics_snapshot({"counters": {}})


def test_clear_keeps_epoch_and_tracks():
    tr = Tracer()
    t = tr.track("queue")
    with tr.span("warmup"):
        pass
    epoch = tr.epoch
    tr.clear()
    assert tr.epoch == epoch
    assert tr.track("queue") == t
    assert all(e["ph"] == "M" for e in tr.to_chrome()["traceEvents"])


# ---------------------------------------------------------------------------
# no-op fast path
# ---------------------------------------------------------------------------


def test_null_sinks_allocate_nothing():
    # disabled spans are ONE shared object, not per-call allocations
    assert NULL_TRACER.span("a") is NULL_TRACER.span("b")
    assert not NULL_TRACER.recording
    with NULL_TRACER.span("x", arg=1):
        pass
    NULL_TRACER.counter("c", v=1)
    NULL_TRACER.complete("r", 0.0, 1.0)
    assert NULL_TRACER.to_chrome()["traceEvents"] == []
    # same for metrics: one shared instrument regardless of name/labels
    assert (NULL_METRICS.counter("a") is NULL_METRICS.histogram("b", x=1))
    assert NULL_METRICS.snapshot() == {}
    # phase_scope with both sinks off returns the shared null span
    assert (phase_scope(NULL_TRACER, NULL_METRICS, "p")
            is phase_scope(NULL_TRACER, NULL_METRICS, "q", k=1))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", phase="x")
    for v in range(1, 101):  # 1..100
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["sum"] == pytest.approx(5050.0)
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["p50"] == pytest.approx(50.5)  # linear interpolation
    assert s["p99"] == pytest.approx(99.01)
    snap = reg.snapshot()
    assert validate_metrics_snapshot(snap) >= 1
    assert "lat{phase=x}" in snap["histograms"]


def test_registry_memoizes_and_counts():
    reg = MetricsRegistry()
    assert reg.counter("n", k="a") is reg.counter("n", k="a")
    reg.counter("n", k="a").inc()
    reg.counter("n", k="a").inc(2)
    reg.gauge("g").set(7)
    snap = reg.snapshot()
    assert snap["counters"]["n{k=a}"] == 3
    assert snap["gauges"]["g"] == 7
    reg.clear()
    assert reg.snapshot()["counters"] == {}


# ---------------------------------------------------------------------------
# dispatch timer
# ---------------------------------------------------------------------------


def test_dispatch_timer_fences_and_groups():
    timer = DispatchTimer(enabled=True)
    import jax.numpy as jnp
    x = jnp.ones((8, 8))
    for _ in range(3):
        timer.timed("matmul", (8, 8), (4, 4), lambda a: a @ a, x)
    rows = timer.summary()
    assert len(rows) == 1
    r = rows[0]
    assert r["calls"] == 3 and r["tile"] == [4, 4]
    assert 0.0 <= r["min_ms"] <= r["p50_ms"] <= r["max_ms"]
    # disabled timer records nothing and passes the value through
    off = DispatchTimer(enabled=False)
    out = off.timed("m", None, None, lambda: 41 + 1)
    assert out == 42 and off.summary() == []


# ---------------------------------------------------------------------------
# perf-model phase split + gap comparator
# ---------------------------------------------------------------------------


def test_phase_cycles_sum_to_layer_cycles():
    hw = PM.DEFAULT_HW
    for l in PM.vgg16_cifar_layers()[:4]:
        total = l.groupsets_for(hw.group, hw.alpha)
        nnz = l.nnz_for(hw.group, hw.alpha)
        cycles, _ = PM._layer_cycles(l, nnz, total, 8, 4, True, hw=hw)
        p = PM.layer_phase_cycles(l, 8, 4, hw=hw)
        assert (max(p["compute"], p["fm"]) + p["reload"] + p["ctrl"]
                == pytest.approx(cycles))
    net = PM.network_phase_breakdown(PM.vgg16_cifar_layers()[:4], 8, 4)
    assert all(v >= 0 for v in net.values()) and net["compute"] > 0


def test_gap_report_contract():
    g = gap.gap_report(2e-6, 4e-4, predicted_phases={"a": 3.0, "b": 1.0},
                       measured_phases={"x": 0.2})
    assert g["sim_vs_measured"] == pytest.approx(200.0)
    assert g["predicted_phase_shares"] == {"a": 0.75, "b": 0.25}
    assert g["measured_phase_shares"] == {"x": 1.0}
    for bad in (0.0, float("nan"), float("inf"), -1.0):
        with pytest.raises(ValueError):
            gap.gap_report(bad, 1.0)
        with pytest.raises(ValueError):
            gap.gap_report(1.0, bad)


def test_measured_phase_shares_parses_labels():
    reg = MetricsRegistry()
    reg.histogram("serve_phase_s", phase="step.dispatch").observe(0.3)
    reg.histogram("serve_phase_s", phase="step.gather").observe(0.1)
    reg.histogram("other_metric", phase="x").observe(9.0)
    ph = gap.measured_phase_shares(reg.snapshot())
    assert ph == {"step.dispatch": pytest.approx(0.3),
                  "step.gather": pytest.approx(0.1)}


def test_measured_phase_shares_tolerates_malformed_snapshot():
    # hand-built snapshot with every malformation the parser must skip:
    # a non-dict histogram, a label block with no '=', and a non-finite sum
    snap = {"histograms": {
        "serve_phase_s{phase=good}": {"sum": 0.4, "count": 2},
        "serve_phase_s{phase=poison}": {"sum": float("nan"), "count": 1},
        "serve_phase_s{nolabels}": {"sum": 1.0, "count": 1},
        "serve_phase_s{phase=notdict}": "garbage",
        "serve_phase_s{phase=badsum}": {"sum": "NaN-ish", "count": 1},
    }}
    assert gap.measured_phase_shares(snap) == {"good": pytest.approx(0.4)}


def test_shares_drop_nonfinite_phases():
    s = gap._shares({"a": 3.0, "b": float("inf"), "c": float("nan"),
                     "d": 1.0})
    assert s == {"a": 0.75, "d": 0.25}
    assert gap._shares({"a": float("nan")}) == {}


def test_clamp_measured_guards():
    # honest samples pass through as their min
    assert gap.clamp_measured([2e-3, 5e-3]) == pytest.approx(2e-3)
    # zero-duration clock reads are floored, not propagated as 0 / raised
    assert gap.clamp_measured([0.0]) == gap.MIN_MEASURED_S
    # non-finite samples are dropped before the min
    assert gap.clamp_measured([float("nan"), 3e-3]) == pytest.approx(3e-3)
    # empty phase table / all-garbage samples is a hard error with a
    # message that names the cause, not a silent zero
    for bad in ([], [float("nan")], [float("inf")], [-1.0]):
        with pytest.raises(ValueError, match="no usable measured samples"):
            gap.clamp_measured(bad)


def test_serve_gap_zero_duration_floored(smoke_model):
    cfg, _ = smoke_model
    # a zero p50 (empty histogram quirk) must not crash gap_report with
    # "measured_s must be finite > 0" - it gets floored upstream
    g = gap.serve_gap(cfg, 0.0, 0.6)
    assert g["measured_s"] == gap.MIN_MEASURED_S
    assert np.isfinite(g["sim_vs_measured"])


def test_dispatch_timer_emits_metric_histograms():
    reg = MetricsRegistry()
    timer = DispatchTimer(enabled=True, metrics=reg)
    import jax.numpy as jnp
    x = jnp.ones((8, 8))
    for _ in range(3):
        timer.timed("matmul", (8, 8), (4, 4), lambda a: a @ a, x)
    timer.timed("gemv", (1, 8), None, lambda a: a.sum(), x)
    snap = reg.snapshot()
    validate_metrics_snapshot(snap)
    hk = [k for k in snap["histograms"] if k.startswith("kernel_dispatch_s{")]
    assert len(hk) == 2  # one labeled series per (name, shape, tile) group
    be = jax.default_backend()
    mm = snap["histograms"][
        f"kernel_dispatch_s{{backend={be},kernel=matmul,shape=8x8,tile=4x4}}"]
    assert mm["count"] == 3 and mm["sum"] > 0
    gv = snap["histograms"][
        f"kernel_dispatch_s{{backend={be},kernel=gemv,shape=1x8,tile=none}}"]
    assert gv["count"] == 1
    # a metrics-less timer still works and emits nothing
    bare = DispatchTimer(enabled=True)
    bare.timed("m", None, None, lambda: 1)
    # NULL metrics (recording=False) must not be written to either
    null_timer = DispatchTimer(enabled=True, metrics=NULL_METRICS)
    null_timer.timed("m", None, None, lambda: 1)
    assert NULL_METRICS.snapshot() == {}


# ---------------------------------------------------------------------------
# bench history (append-only JSONL + regression gate)
# ---------------------------------------------------------------------------


def _hist_row(ts, metrics, backend="cpu", arch="smoke"):
    return {"schema": history.SCHEMA_VERSION, "ts": ts, "git_sha": "abc1234",
            "backend": backend, "arch": arch, "metrics": metrics}


def test_history_append_load_round_trip(tmp_path):
    p = tmp_path / "hist.jsonl"
    r1 = history.make_row({"serve.gap": 3.0}, git_sha="s1", backend="cpu",
                          arch="smoke")
    r2 = history.make_row({"serve.gap": 3.1}, git_sha="s2", backend="cpu",
                          arch="smoke")
    history.append_row(str(p), r1)
    history.append_row(str(p), r2)
    rows = history.load_history(str(p))
    assert [r["git_sha"] for r in rows] == ["s1", "s2"]
    assert rows[0]["schema"] == history.SCHEMA_VERSION
    # appending a malformed row is refused before it hits the file
    with pytest.raises(ValueError):
        history.append_row(str(p), {"schema": "x"})
    assert len(history.load_history(str(p))) == 2


def test_history_load_rejects_malformed(tmp_path):
    p = tmp_path / "bad.jsonl"
    # line 1 is a valid row; line 2 is not JSON - the error names the line
    p.write_text(json.dumps(_hist_row("t0", {"m": 1.0})) + "\nnot json\n")
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        history.load_history(str(p))
    # a structurally-bad row (valid JSON) is also rejected with its line
    p.write_text('{"schema": 1}\n')
    with pytest.raises(ValueError, match="bad.jsonl:1"):
        history.load_history(str(p))
    p2 = tmp_path / "bad2.jsonl"
    p2.write_text(json.dumps(_hist_row("t", {"m": "NaN-string"})) + "\n")
    with pytest.raises(ValueError):
        history.load_history(str(p2))


def test_history_check_needs_baseline():
    # a single row has nothing to regress against: green, no findings
    assert history.check_history([_hist_row("t0", {"serve.gap": 3.0})]) == []


def test_history_check_flags_gap_drift_and_throughput_drop():
    rows = [
        _hist_row("t0", {"serve.gap": 3.0, "serve.s.tokens_per_s": 50.0}),
        _hist_row("t1", {"serve.gap": 3.2, "serve.s.tokens_per_s": 52.0}),
        _hist_row("t2", {"serve.gap": 30.0, "serve.s.tokens_per_s": 5.0}),
    ]
    kinds = {f["kind"] for f in history.check_history(rows)}
    assert kinds == {"gap-drift", "throughput-drop"}
    # drift fires in BOTH directions (a 10x better gap is also suspicious)
    rows[2]["metrics"] = {"serve.gap": 0.1, "serve.s.tokens_per_s": 52.0}
    assert [f["kind"] for f in history.check_history(rows)] == ["gap-drift"]
    # within tolerance: green
    rows[2]["metrics"] = {"serve.gap": 3.4, "serve.s.tokens_per_s": 48.0}
    assert history.check_history(rows) == []


def test_history_check_groups_by_backend_and_arch():
    # a cpu baseline must never judge a tpu row
    rows = [
        _hist_row("t0", {"serve.gap": 3.0}, backend="cpu"),
        _hist_row("t1", {"serve.gap": 3.0}, backend="cpu"),
        _hist_row("t2", {"serve.gap": 300.0}, backend="tpu"),
    ]
    assert history.check_history(rows) == []


def test_history_flatten_bench_reports():
    sched = {"vgg16_w8a8": {
        "fps_searched": 100.0,
        "sim_vs_measured": {"sim_vs_measured": 60.0,
                            "post_refit": {"gap": 0.7}}}}
    m = history.flatten_sched(sched)
    assert m == {"sched.vgg16_w8a8.gap": 60.0,
                 "sched.vgg16_w8a8.gap_post_refit": 0.7,
                 "sched.vgg16_w8a8.fps_searched": 100.0}
    serve = {"arch": "yi-6b",
             "sim_vs_measured": {"sim_vs_measured": 3.0},
             "sharded": {"sim_vs_measured": {"sim_vs_measured": 5.0}},
             "scan": {"tokens_per_s": 400.0}}
    s = history.flatten_serve(serve)
    assert s["serve.gap"] == 3.0
    assert s["serve.sharded.gap"] == 5.0
    assert s["serve.scan.tokens_per_s"] == 400.0


def test_history_tracks_spec_acceptance():
    serve = {"arch": "yi-6b",
             "spec": {"tokens_per_s": 300.0},
             "spec_vs_scan": {"acceptance_rate": 0.62,
                              "tokens_per_s_spec": 300.0}}
    s = history.flatten_serve(serve)
    assert s["serve.spec.tokens_per_s"] == 300.0
    assert s["serve.spec_vs_scan.acceptance_rate"] == 0.62
    # acceptance gates like throughput: a large relative drop regresses
    rows = [history.make_row(
        {"serve.spec_vs_scan.acceptance_rate": v}, backend="cpu",
        arch="yi-6b") for v in (0.6, 0.6, 0.1)]
    findings = history.check_history(rows)
    assert [f["metric"] for f in findings] == \
        ["serve.spec_vs_scan.acceptance_rate"]
    assert findings[0]["kind"] == "throughput-drop"
    assert not history.check_history(rows[:2] + [
        history.make_row({"serve.spec_vs_scan.acceptance_rate": 0.55},
                         backend="cpu", arch="yi-6b")])


def test_history_cli_end_to_end(tmp_path, capsys):
    p = tmp_path / "h.jsonl"
    sched_p = tmp_path / "BENCH_sched.json"
    sched_p.write_text(json.dumps({"net_w8a8": {
        "fps_searched": 10.0,
        "sim_vs_measured": {"sim_vs_measured": 50.0,
                            "post_refit": {"gap": 0.9}}}}))
    args = ["append", "--out", str(p), "--sched", str(sched_p),
            "--sha", "deadbee", "--backend", "cpu", "--arch", "bench"]
    history.main(args)  # returns without raising on success
    history.main(args)
    capsys.readouterr()
    history.main(["check", str(p)])
    assert "no regressions" in capsys.readouterr().out
    # inject a regression: check exits 1, --warn-only exits 0
    bad = history.make_row({"sched.net_w8a8.gap": 5000.0,
                            "sched.net_w8a8.fps_searched": 1.0},
                           git_sha="bad", backend="cpu", arch="bench")
    history.append_row(str(p), bad)
    with pytest.raises(SystemExit) as ei:
        history.main(["check", str(p)])
    assert ei.value.code == 1
    capsys.readouterr()
    history.main(["check", str(p), "--warn-only"])  # warn-only: no exit
    assert "REGRESSION" in capsys.readouterr().out
    # malformed history hard-fails with exit 2 even under --warn-only
    badfile = tmp_path / "corrupt.jsonl"
    badfile.write_text("not json\n")
    with pytest.raises(SystemExit) as ei:
        history.main(["check", str(badfile), "--warn-only"])
    assert ei.value.code == 2


# ---------------------------------------------------------------------------
# instrumented server smoke
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = registry.get_smoke_config("yi-6b", dtype="float32")
    params = registry.model_fns(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, DP.from_params(cfg, params)


def _reqs(cfg, n=4, seed=5):
    rng = np.random.default_rng(seed)
    return [Request(f"r{i}", rng.integers(0, cfg.vocab,
                                          int(rng.integers(2, 10))),
                    int(rng.integers(1, 6))) for i in range(n)]


def test_batchserver_instrumented_smoke(smoke_model, tmp_path):
    cfg, sp = smoke_model
    tr, mr = Tracer(), MetricsRegistry()
    srv = BatchServer(cfg, sp, ServeConfig(),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=32),
                      tracer=tr, metrics=mr)
    rep = srv.run(_reqs(cfg))

    obj = tr.to_chrome()
    validate_chrome_trace(obj)
    names = {e["name"] for e in obj["traceEvents"]}
    for phase in ("step.admit", "prefill", "decode_step", "step.gather",
                  "step.dispatch", "step.sample", "step.writeback"):
        assert phase in names, f"missing phase span {phase}"
    # per-request lifecycle spans landed on the queue/slot tracks
    assert any(n.startswith("queued:") for n in names)
    assert any(n.startswith("req:") for n in names)

    j = rep.to_json()
    # queue wait is split out of TTFT: queue + service ~= ttft per request
    assert len(rep.queue_wait_s) == j["n_requests"]
    for t, w in zip(rep.ttft_s, rep.queue_wait_s):
        assert 0.0 <= w <= t + 1e-9
    assert "queue_wait" in j and "ttft_service" in j
    assert (j["queue_wait"]["p50"] + j["ttft_service"]["p50"]
            <= j["ttft"]["p99"] + j["ttft"]["p50"])

    snap = j["metrics"]
    validate_metrics_snapshot(snap)
    assert snap["counters"]["requests_finished"] == j["n_requests"]
    assert any(k.startswith("serve_phase_s{") for k in snap["histograms"])
    assert 0.0 <= snap["gauges"]["kv_utilization"] <= 1.0
    disp = snap["kernel_dispatch"]
    assert disp and all(r["name"] == "decode.loop" for r in disp)
    # one fenced dispatch per decode step, grouped by view-shape bucket
    assert sum(r["calls"] for r in disp) == j["n_decode_steps"]

    # tokens identical to an un-instrumented server (observability is
    # read-only: it must never perturb the decode stream)
    ref = BatchServer(cfg, sp, ServeConfig(),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=32))
    ref_rep = ref.run(_reqs(cfg))
    assert ref_rep.metrics is None and "metrics" not in ref_rep.to_json()
    for rid in rep.outputs:
        np.testing.assert_array_equal(rep.outputs[rid], ref_rep.outputs[rid])


def test_serve_gap_from_instrumented_run(smoke_model):
    cfg, sp = smoke_model
    mr = MetricsRegistry()
    srv = BatchServer(cfg, sp, ServeConfig(),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=32),
                      metrics=mr)
    srv.run(_reqs(cfg, n=3))
    snap = mr.snapshot()
    step = snap["histograms"]["serve_phase_s{phase=decode_step}"]
    g = gap.serve_gap(cfg, float(step["p50"]), 0.6,
                      measured_phases=gap.measured_phase_shares(snap))
    assert np.isfinite(g["sim_vs_measured"]) and g["sim_vs_measured"] > 0
    assert set(g["predicted_phase_shares"]) == {"compute", "reload", "fm",
                                                "stall"}
    # each share is rounded to 4 decimals, so the sum can drift by up to
    # 5e-5 per phase off exactly 1.0
    shares = g["measured_phase_shares"]
    assert abs(sum(shares.values()) - 1.0) < 5e-5 * max(len(shares), 1)
