"""Engine generation paths beyond the single system-test call: EOS
early-stop freezing finished rows, enc-dec cache replay, hybrid ring-buffer
window, and prefix stability of the decode loop (cache padding must never
change earlier tokens)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.serve import Engine, ServeConfig


def _mk(arch, **over):
    cfg = registry.get_smoke_config(arch, dtype="float32", **over)
    fns = registry.model_fns(cfg)
    params = fns.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batch(cfg, B=2, S=6, seed=3):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32)
    return batch


def test_eos_freezes_finished_rows():
    cfg, params = _mk("yi-6b")
    batch = _batch(cfg)
    free = Engine(cfg, params, ServeConfig(max_new_tokens=8)).generate(batch)
    # pick an eos that row 0 emits mid-stream and row 1 never emits
    eos = int(free[0, 3])
    assert eos not in free[1].tolist(), "fixture assumption broke"
    out = Engine(cfg, params, ServeConfig(max_new_tokens=8, eos_id=eos)).generate(batch)
    # row 0: identical up to and including its eos, zero after
    np.testing.assert_array_equal(out[0, :4], free[0, :4])
    assert np.all(out[0, 4:] == 0), f"finished row kept writing: {out[0]}"
    # row 1: untouched by row 0 finishing
    np.testing.assert_array_equal(out[1], free[1])


def test_eos_all_rows_stop_early():
    cfg, params = _mk("yi-6b")
    batch = _batch(cfg)
    free = Engine(cfg, params, ServeConfig(max_new_tokens=4)).generate(batch)
    # greedy first token of every row as eos => everything freezes at t=0
    eng = Engine(cfg, params, ServeConfig(max_new_tokens=4, eos_id=int(free[0, 0])))
    batch1 = {k: v[:1] for k, v in batch.items()}
    out = eng.generate(batch1)
    assert out.shape == (1, 4)
    assert out[0, 0] == free[0, 0] and np.all(out[0, 1:] == 0)


@pytest.mark.parametrize("arch", ["whisper-tiny", "zamba2-1.2b"])
def test_generate_prefix_stable(arch):
    """Tokens must not depend on how far the cache was padded: generate(4)
    must be a prefix of generate(10). Exercises the encdec self-attn cache
    replay (pad + copy path) and the hybrid shared-attn cache sizing."""
    cfg, params = _mk(arch)
    batch = _batch(cfg)
    short = Engine(cfg, params, ServeConfig(max_new_tokens=4)).generate(batch)
    long = Engine(cfg, params, ServeConfig(max_new_tokens=10)).generate(batch)
    np.testing.assert_array_equal(short, long[:, :4])


def test_hybrid_ring_buffer_window():
    """With a window smaller than the total length the hybrid shared-attn
    cache becomes a ring buffer; decoding must stay deterministic and
    prefix-stable while wrapping."""
    cfg, params = _mk("zamba2-1.2b", window=8)
    batch = _batch(cfg, S=6)
    scfg = ServeConfig(max_new_tokens=8)  # total 14 > window 8 => wraps
    eng = Engine(cfg, params, scfg)
    a = eng.generate(batch)
    b = eng.generate(batch)
    np.testing.assert_array_equal(a, b)
    short = Engine(cfg, params, ServeConfig(max_new_tokens=3)).generate(batch)
    np.testing.assert_array_equal(short, a[:, :3])


def test_encdec_generate_deterministic_and_batch_consistent():
    """Whisper: per-row results must not depend on batch composition
    (validates the cross-attn KV replay is per-row independent)."""
    cfg, params = _mk("whisper-tiny")
    batch = _batch(cfg, B=2)
    full = Engine(cfg, params, ServeConfig(max_new_tokens=5)).generate(batch)
    solo = Engine(cfg, params, ServeConfig(max_new_tokens=5)).generate(
        {k: v[:1] for k, v in batch.items()})
    np.testing.assert_array_equal(full[:1], solo)


def test_scfg_not_shared_between_engines():
    """The old `scfg: ServeConfig = ServeConfig()` default was one shared
    instance; mutating one engine's config must not leak into another."""
    cfg, params = _mk("yi-6b")
    e1 = Engine(cfg, params)
    e2 = Engine(cfg, params)
    assert e1.scfg is not e2.scfg
    e1.scfg.max_new_tokens = 99
    assert e2.scfg.max_new_tokens != 99


# ---------------------------------------------------------------------------
# Sampling path (temperature > 0)
# ---------------------------------------------------------------------------


def test_sample_tokens_seeded_determinism():
    """Same (logits, key, config) -> same tokens; the sampling path must be
    exactly reproducible under a fixed seed."""
    from repro.serve.engine import sample_tokens
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((4, 64)) * 3.0, jnp.float32)
    scfg = ServeConfig(temperature=0.8)
    key = jax.random.PRNGKey(42)
    a = np.asarray(sample_tokens(logits, key, scfg))
    b = np.asarray(sample_tokens(logits, key, scfg))
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32 and a.shape == (4,)
    # a different key must be able to change the draw (not a constant fn)
    draws = {tuple(np.asarray(sample_tokens(logits, jax.random.PRNGKey(s),
                                            scfg)).tolist())
             for s in range(8)}
    assert len(draws) > 1
    # temperature <= 0 ignores the key entirely (greedy)
    g1 = np.asarray(sample_tokens(logits, jax.random.PRNGKey(0),
                                  ServeConfig(temperature=0.0)))
    g2 = np.asarray(sample_tokens(logits, jax.random.PRNGKey(7),
                                  ServeConfig(temperature=0.0)))
    np.testing.assert_array_equal(g1, g2)
    np.testing.assert_array_equal(g1, np.asarray(jnp.argmax(logits, -1)))


def test_sample_tokens_temperature_sharpens():
    """As temperature -> 0 the categorical draw must converge to argmax."""
    from repro.serve.engine import sample_tokens
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((8, 32)) * 2.0, jnp.float32)
    cold = np.asarray(sample_tokens(logits, jax.random.PRNGKey(0),
                                    ServeConfig(temperature=1e-4)))
    np.testing.assert_array_equal(cold, np.asarray(jnp.argmax(logits, -1)))


def test_engine_generate_seeded_determinism_at_temperature():
    cfg, params = _mk("yi-6b")
    batch = _batch(cfg)
    scfg = ServeConfig(max_new_tokens=6, temperature=0.9, seed=5)
    a = Engine(cfg, params, scfg).generate(batch)
    b = Engine(cfg, params, scfg).generate(batch)
    np.testing.assert_array_equal(a, b)
    c = Engine(cfg, params, ServeConfig(max_new_tokens=6, temperature=0.9,
                                        seed=6)).generate(batch)
    assert not np.array_equal(a, c), "seed had no effect on sampling"


def test_engine_vs_batch_server_prng_schedules_diverge():
    """Regression pin for the documented divergence (serve.engine
    docstring): Engine and BatchServer only produce identical tokens under
    GREEDY decoding - with temperature > 0 their PRNG key schedules differ
    (per-batch-step splits vs per-slot/admission splits), so the same seed
    yields different (but individually deterministic) streams. If this
    test ever fails on the 'diverge' assert, the schedules were unified -
    update the sample_tokens docstring and drop the caveat."""
    from repro.serve import BatchConfig, BatchServer, Request
    from repro.serve import deployed as DP
    cfg, params = _mk("yi-6b")
    prompt = np.arange(5, dtype=np.int32)
    scfg = ServeConfig(max_new_tokens=8, temperature=0.9, seed=3)
    eng = Engine(cfg, params, scfg).generate(
        {"tokens": jnp.asarray(prompt[None])})[0]
    srv = BatchServer(cfg, DP.from_params(cfg, params),
                      ServeConfig(max_new_tokens=8, temperature=0.9, seed=3),
                      BatchConfig(n_slots=2, block_size=4, n_blocks=16))
    batched = srv.run([Request("r0", prompt, 8)]).outputs["r0"]
    # both deterministic under their own schedule...
    again = srv.run([Request("r0", prompt, 8)]).outputs["r0"]
    np.testing.assert_array_equal(batched, again)
    # ...but the schedules diverge from each other
    assert not np.array_equal(eng, batched), (
        "Engine and BatchServer PRNG schedules now coincide - update the "
        "sample_tokens docstring")
