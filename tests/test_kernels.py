"""Per-kernel validation: shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapping import pack_bsr
from repro.kernels import ops, ref
from repro.kernels.cim_bsr_matmul import bsr_matmul
from repro.kernels.fake_quant import fake_quant
from repro.kernels.quant_matmul import quant_matmul


def _sparse_weight(rng, k, n, bk, bn, density):
    """Random int8-level weight with block sparsity."""
    gi, go = k // bk, n // bn
    keep = rng.random((gi, go)) < density
    w = rng.integers(-7, 8, size=(k, n)).astype(np.int8)
    mask = np.repeat(np.repeat(keep, bk, axis=0), bn, axis=1)
    return (w * mask).astype(np.int8)


BSR_CASES = [
    # (m, k, n, bk, bn, density, xdtype)
    (128, 256, 256, 128, 128, 0.5, jnp.float32),
    (64, 512, 384, 128, 128, 0.3, jnp.float32),  # m needs padding
    (256, 256, 512, 128, 128, 0.0, jnp.float32),  # fully pruned
    (256, 256, 512, 128, 128, 1.0, jnp.bfloat16),  # dense
    (128, 128, 128, 64, 64, 0.6, jnp.float32),  # small blocks
    (32, 768, 256, 128, 128, 0.25, jnp.bfloat16),
    (128, 512, 256, 256, 128, 0.5, jnp.float32),  # rectangular blocks
]


@pytest.mark.parametrize("m,k,n,bk,bn,density,xdtype", BSR_CASES)
def test_bsr_matmul_vs_ref(m, k, n, bk, bn, density, xdtype):
    rng = np.random.default_rng(42 + m + k + n)
    w = _sparse_weight(rng, k, n, bk, bn, density)
    bsr = pack_bsr(w, bk, bn)
    scales = np.full(bsr.row_idx.shape, 1.0 / 8, np.float32)
    x = jnp.asarray(rng.standard_normal((m, k)), xdtype)

    got = bsr_matmul(x, jnp.asarray(bsr.blocks), jnp.asarray(scales),
                     jnp.asarray(bsr.row_idx), jnp.asarray(bsr.nnz),
                     bm=min(128, m), interpret=True)
    want = ref.bsr_matmul_ref(x, bsr.blocks, scales, bsr.row_idx, bsr.nnz)
    tol = 2e-2 if xdtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_bsr_zero_blocks_never_counted():
    """Padding slots must contribute exactly nothing (the CIM skip)."""
    rng = np.random.default_rng(0)
    w = _sparse_weight(rng, 256, 256, 128, 128, 0.5)
    bsr = pack_bsr(w, 128, 128)
    # poison the padding slots: kernel must mask them via nnz
    blocks = np.array(bsr.blocks)
    for j in range(blocks.shape[0]):
        blocks[j, bsr.nnz[j]:] = 99
    scales = np.full(bsr.row_idx.shape, 1.0, np.float32)
    x = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    got = bsr_matmul(x, jnp.asarray(blocks), jnp.asarray(scales),
                     jnp.asarray(bsr.row_idx), jnp.asarray(bsr.nnz), interpret=True)
    want = ref.bsr_matmul_ref(x, bsr.blocks, scales, bsr.row_idx, bsr.nnz)
    # poison leakage would show up at O(99 * |x|); accumulation-order noise
    # is ~1e-6 relative - tolerance separates the two by 5 orders
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


QM_CASES = [
    (128, 256, 256, jnp.float32),
    (100, 200, 300, jnp.float32),  # all dims need padding
    (256, 128, 512, jnp.bfloat16),
    (64, 384, 128, jnp.float32),
]


@pytest.mark.parametrize("m,k,n,xdtype", QM_CASES)
def test_quant_matmul_vs_ref(m, k, n, xdtype):
    rng = np.random.default_rng(m * 7 + n)
    w = rng.integers(-127, 128, size=(k, n)).astype(np.int8)
    scale = (rng.random(n) * 0.1 + 0.01).astype(np.float32)
    x = jnp.asarray(rng.standard_normal((m, k)), xdtype)
    got = quant_matmul(x, jnp.asarray(w), jnp.asarray(scale), interpret=True)
    want = ref.quant_matmul_ref(x, w, scale)
    tol = 5e-2 if xdtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("signed", [False, True])
@pytest.mark.parametrize("shape", [(64, 64), (3, 100, 130), (513,)])
def test_fake_quant_vs_ref(bits, signed, shape):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.standard_normal(shape) * 1.5, jnp.float32)
    if len(shape) == 1:
        x = x[None]
    got = fake_quant(x, bits, signed=signed, interpret=True)
    want = ref.fake_quant_ref(x, bits, signed=signed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)


def test_pack_for_kernel_end_to_end():
    """eq.8 weights -> int8 packing -> kernel == float matmul with the
    quantized weights (the deployment path)."""
    from repro.core import quant as Q

    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (256, 256))
    wq = Q.mars_weight_quant(w, 4, group_size=128)
    # impose block sparsity
    mask = np.zeros((256, 256), np.float32)
    mask[:128, :] = 1.0
    wq = jnp.asarray(np.asarray(wq) * mask)
    packed = ops.pack_for_kernel(np.asarray(wq), bits=4, bk=128, bn=128)
    assert packed["density"] == 0.5
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 256))
    got = ops.bsr_matmul(x, packed, interpret=True)
    want = x @ wq
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


SSD_CASES = [
    # (C, H, l, N, P, dtype)
    (4, 2, 64, 16, 32, jnp.float32),
    (2, 3, 128, 32, 64, jnp.float32),
    (1, 1, 16, 8, 8, jnp.float32),
    (3, 2, 64, 16, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("C,H,l,N,P,dtype", SSD_CASES)
def test_ssd_intra_vs_ref(C, H, l, N, P, dtype):
    """Fused SSD intra-chunk kernel == oracle (the §Perf mamba2 fix)."""
    rng = np.random.default_rng(C * 10 + l)
    a = jnp.asarray(-np.abs(rng.standard_normal((C, H, l))) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((C, l, N)) * 0.3, dtype)
    c = jnp.asarray(rng.standard_normal((C, l, N)) * 0.3, dtype)
    x = jnp.asarray(rng.standard_normal((C, l, H, P)) * 0.3, dtype)
    got = ops.ssd_intra(a, b, c, x, interpret=True)
    want = ref.ssd_intra_ref(a, b, c, x)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_ssd_intra_matches_ssd_chunked_diag():
    """Kernel equals the y_diag term of the pure-JAX ssd_chunked (h0=0,
    single chunk -> full output is the diagonal block)."""
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 64, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P)) * 0.3, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((B, S, H))) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, N)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, S, N)) * 0.3, jnp.float32)
    want, _ = ssd_chunked(x, a, b, c, chunk=S)  # one chunk: y == y_diag
    got = ops.ssd_intra(a.transpose(0, 2, 1)[:, :, :], b, c, x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
